"""Sharding resolver, optimizer (ZeRO) shardings, data pipeline, HLO parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime import sharding as shd
from repro.runtime.hlo_analysis import parse_hlo


def _mesh22():
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Duck-typed mesh (resolve_spec only reads .shape) so divisibility
    logic is testable on a 1-device host."""

    def __init__(self, **shape):
        self.shape = shape


def test_resolve_spec_drops_nondivisible():
    mesh = _FakeMesh(data=4, model=2)
    # batch=3 not divisible by data=4 -> dropped; heads=6 divisible by 2
    spec = shd.resolve_spec(("batch", "heads"), shape=(3, 6), mesh=mesh)
    assert spec == P(None, "model")
    # both divisible -> both kept
    spec = shd.resolve_spec(("batch", "heads"), shape=(8, 6), mesh=mesh)
    assert spec == P("data", "model")


def test_resolve_spec_drops_absent_axis():
    mesh = _FakeMesh(data=2, model=2)  # no "pod" axis
    spec = shd.resolve_spec(("batch",), shape=(8,), mesh=mesh)
    assert spec == P("data")           # ("pod","data") filtered to data


def test_resolve_spec_no_duplicate_axis():
    mesh = _mesh22()
    # "qkv" and "d_ff" both map to model; second use must be dropped
    spec = shd.resolve_spec(("qkv", "d_ff"), shape=(4, 4), mesh=mesh)
    flat = [s for s in spec if s is not None]
    assert len(flat) == len(set(flat))


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, ("batch", "d_model"))
    np.testing.assert_array_equal(x, y)


def test_param_defs_materialize_and_abstract_agree():
    defs = {"w": shd.pdef((4, 8), ("d_model", "d_ff")),
            "b": shd.pdef((8,), ("d_ff",), init="zeros")}
    params = shd.materialize(jax.random.PRNGKey(0), defs, jnp.float32)
    abstract = shd.abstract_params(defs, jnp.float32)
    assert params["w"].shape == abstract["w"].shape
    assert params["b"].dtype == abstract["b"].dtype
    assert float(jnp.sum(jnp.abs(params["b"]))) == 0.0
    assert shd.param_count(defs) == 4 * 8 + 8


def test_optimizer_shardings_add_dp_axis():
    mesh = _mesh22()
    defs = {"w": shd.pdef((4, 8), (None, None))}
    opt = shd.optimizer_shardings(defs, mesh)
    assert opt["w"].spec is not None  # well-formed under degenerate mesh


# ---- data pipeline ----------------------------------------------------------

def test_data_deterministic_and_resumable():
    from repro.data.pipeline import DataConfig, TokenStream
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=4, seed=3)
    a = TokenStream(cfg)
    b1 = next(a)
    b2 = next(a)
    state = a.state()
    b3 = next(a)
    # restore and replay
    c = TokenStream(cfg)
    c.restore(state)
    b3r = next(c)
    np.testing.assert_array_equal(b3["tokens"], b3r["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert b1["tokens"].max() < 101


def test_data_host_sharding_partitions_batch():
    from repro.data.pipeline import DataConfig, TokenStream
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=4, seed=1)
    h0 = next(TokenStream(cfg, host_id=0, num_hosts=2))
    h1 = next(TokenStream(cfg, host_id=1, num_hosts=2))
    assert h0["tokens"].shape == (2, 8)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


# ---- HLO analysis -----------------------------------------------------------

SYNTH = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %w = f32[8,16]{1,0} constant(0)
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %t = (s32[], f32[8,8]) tuple(%p)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %wl = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[8,8]{1,0} copy(%a)
}
"""


def test_hlo_parser_trip_count_multiplication():
    rep = parse_hlo(SYNTH, total_devices=8)
    # dot: 2*8*16*8 = 2048 flops x 5 trips
    assert rep.flops == 2048 * 5
    # all-reduce: 2*(4-1)/4 * 8*16*4 bytes x 5
    assert abs(rep.collective_bytes - 2 * 0.75 * 512 * 5) < 1e-6
    assert rep.collective_count == 1
