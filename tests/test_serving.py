"""Async serving subsystem: metrics histograms, router determinism +
cache affinity, admission reject/shed, concurrent-submit soak (every request
resolves exactly once), engine cancel/shed/backlog probes, and a real-engine
end-to-end smoke through AsyncServer."""
import threading
import time

import numpy as np
import pytest

from repro.core.prefix_cache import token_chain
from repro.core.scheduler import Request
from repro.runtime.fault_tolerance import InstancePool
from repro.serving import (AdmissionController, AsyncServer, Histogram,
                           MetricsRegistry, Rejected, get_router)
from repro.serving.router import LeastBacklogRouter, UserHashRouter


# ---- metrics ----------------------------------------------------------------

def test_histogram_percentiles_uniform():
    h = Histogram(bounds=tuple(np.linspace(0.01, 1.0, 100)))
    rng = np.random.default_rng(0)
    xs = rng.uniform(0, 1, 20_000)
    for x in xs:
        h.observe(x)
    # fixed-bucket interpolation: within one bucket width of the truth
    assert abs(h.percentile(0.50) - 0.50) < 0.02
    assert abs(h.percentile(0.95) - 0.95) < 0.02
    assert abs(h.percentile(0.99) - 0.99) < 0.02
    assert h.count == 20_000
    assert abs(h.mean - 0.5) < 0.01


def test_histogram_small_sample_clamps_to_observed():
    h = Histogram(bounds=(0.1, 1.0, 10.0))
    h.observe(0.35)
    assert h.percentile(0.5) == pytest.approx(0.35)
    assert h.percentile(0.99) == pytest.approx(0.35)
    h2 = Histogram(bounds=(0.1, 1.0, 10.0))
    assert np.isnan(h2.percentile(0.5))


def test_histogram_merge_and_registry_aggregation():
    reg = MetricsRegistry(buckets=(0.1, 1.0, 10.0))
    reg.histogram("lat", "a").observe(0.05)
    reg.histogram("lat", "b").observe(5.0)
    merged = reg.merged_histogram("lat")
    assert merged.count == 2
    assert merged.min == pytest.approx(0.05)
    assert merged.max == pytest.approx(5.0)
    reg.counter("served", "a").inc(3)
    reg.counter("served", "b").inc(4)
    assert reg.total("served") == 7
    text = reg.render()
    assert "served{a} 3" in text and "lat{ALL}" in text


def test_counter_thread_safety():
    reg = MetricsRegistry()
    c = reg.counter("n")
    threads = [threading.Thread(target=lambda: [c.inc() for _ in range(1000)])
               for _ in range(8)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert c.value == 8000


# ---- fake engine (protocol double for router/server tests) ------------------

class FakeEngine:
    """Implements the engine surface AsyncServer/routers rely on; step()
    sleeps sec_per_token per input token."""

    class _ECfg:
        block_size = 16

    ecfg = _ECfg()

    def __init__(self, name, sec_per_token=5e-5, cached_chains=()):
        self.name = name
        self.lock = threading.RLock()
        self.queue = []
        self.results = {}
        self._last = []
        self.a = sec_per_token
        self.cached = {tuple(c) for c in cached_chains}
        self.steps = 0

    def submit(self, tokens, allowed_tokens=None, user_id=None, now=None,
               deadline=None, chain=None):
        r = Request(n_input=len(tokens), arrival=time.perf_counter(),
                    chain=chain or token_chain(tokens,
                                               self.ecfg.block_size),
                    tokens=list(tokens), user_id=user_id, deadline=deadline)
        with self.lock:
            self.queue.append(r)
        return r.req_id

    def cancel(self, rid):
        with self.lock:
            for i, r in enumerate(self.queue):
                if r.req_id == rid:
                    return self.queue.pop(i)
        return None

    def shed_expired(self, now=None):
        now = time.perf_counter() if now is None else now
        shed = []
        with self.lock:
            keep = []
            for r in self.queue:
                doomed = (r.deadline is not None
                          and now + self.a * r.n_input > r.deadline)
                (shed if doomed else keep).append(r)
            self.queue[:] = keep
        return shed

    def pending_jct(self, now=None):
        with self.lock:
            return sum(self.a * r.n_input for r in self.queue)

    def predict_jct(self, n, chain=()):
        return self.a * (n - self.cached_prefix_len(chain))

    def cached_prefix_len(self, chain):
        return (self.ecfg.block_size * len(chain)
                if tuple(chain) in self.cached else 0)

    def step(self):
        with self.lock:
            if not self.queue:
                return None
            r = self.queue.pop(0)
        time.sleep(self.a * r.n_input)
        r.finish_time = time.perf_counter()
        with self.lock:
            self.results[r.req_id] = {
                "req_id": r.req_id, "latency": r.latency, "n_cached": 0,
                "n_input": r.n_input, "token": 0}
            self._last = [r.req_id]
            self.steps += 1
        return r.req_id

    @property
    def last_step_ids(self):
        return list(self._last)

    def stats(self):
        return {"steps": self.steps}


def _fake_pool(n=2, **kw):
    pool = InstancePool(lambda name: FakeEngine(name, **kw))
    pool.scale_to([f"i{k}" for k in range(n)])
    return pool


# ---- router -----------------------------------------------------------------

def test_user_hash_router_deterministic_and_matches_rendezvous():
    from repro.runtime.fault_tolerance import rendezvous_hash
    pool = _fake_pool(3)
    r = UserHashRouter()
    engines = {n: pool.engines[n] for n in pool.live_names()}
    for u in range(20):
        picks = {r.route(user_id=f"u{u}", n_input=10, chain=(),
                         instances=engines) for _ in range(5)}
        assert len(picks) == 1
        assert picks.pop() == rendezvous_hash(f"u{u}", sorted(engines))


def test_least_backlog_routes_to_min_predicted_backlog():
    pool = _fake_pool(2)
    engines = {n: pool.engines[n] for n in pool.live_names()}
    # load i0 with 3 queued requests -> backlog 3*100*a
    for _ in range(3):
        engines["i0"].submit(list(range(100)))
    r = LeastBacklogRouter()
    picks = [r.route(user_id="u", n_input=50, chain=(), instances=engines)
             for _ in range(5)]
    assert picks == ["i1"] * 5                      # deterministic, min backlog


def test_least_backlog_cache_affinity_tie_break():
    tokens = list(range(64))
    chain = token_chain(tokens, 16)
    pool = InstancePool(lambda name: FakeEngine(
        name, cached_chains=[chain] if name == "i1" else []))
    pool.scale_to(["i0", "i1", "i2"])
    engines = {n: pool.engines[n] for n in pool.live_names()}
    r = LeastBacklogRouter()
    # all backlogs equal (empty): the instance holding the prefix wins,
    # repeatably
    assert [r.route(user_id="u9", n_input=64, chain=chain,
                    instances=engines) for _ in range(5)] == ["i1"] * 5
    # unknown chain: falls back to rendezvous (deterministic across calls)
    picks = {r.route(user_id="u9", n_input=64, chain=(), instances=engines)
             for _ in range(5)}
    assert len(picks) == 1


def test_get_router_factory():
    assert isinstance(get_router("user_hash"), UserHashRouter)
    assert isinstance(get_router("least_backlog"), LeastBacklogRouter)
    with pytest.raises(KeyError):
        get_router("nope")


# ---- admission --------------------------------------------------------------

def test_admission_mil_reject():
    ctrl = AdmissionController(max_input_tokens=100)
    rej = ctrl.check(101, None, 0.0, 0.0, 0.0, user_id="u")
    assert rej is not None and rej.reason == "infeasible"
    assert ctrl.check(100, None, 0.0, 0.0, 0.0) is None
    assert ctrl.rejected_infeasible == 1


def test_admission_mil_from_memory_model():
    from repro.configs import get_config
    from repro.core.kv_policy import MemoryModel
    mm = MemoryModel(get_config("llama3.1-8b"))
    ctrl = AdmissionController(memory_model=mm)
    assert ctrl.max_input_tokens == mm.max_input_length("hybrid", 2048)
    assert ctrl.check(ctrl.max_input_tokens + 1, None, 0, 0, 0).reason \
        == "infeasible"


def test_admission_deadline_reject_and_slack():
    ctrl = AdmissionController()
    now = 100.0
    # predicted wait 2 + jct 1 = finish at 103 > deadline 102 -> reject
    rej = ctrl.check(10, 102.0, now, 2.0, 1.0)
    assert rej is not None and rej.reason == "deadline"
    assert rej.predicted_wait == 2.0 and rej.predicted_jct == 1.0
    # feasible deadline admits
    assert ctrl.check(10, 104.0, now, 2.0, 1.0) is None
    # slack 2.0 doubles the predicted time -> 104 no longer feasible
    tight = AdmissionController(deadline_slack=2.0)
    assert tight.check(10, 104.0, now, 2.0, 1.0) is not None


# ---- server (fake engines) --------------------------------------------------

def test_server_serves_and_rejects_typed():
    pool = _fake_pool(2)
    srv = AsyncServer(pool, router=get_router("least_backlog"),
                      admission=AdmissionController(max_input_tokens=500))
    srv.start()
    try:
        ok = [srv.submit(f"u{i}", list(range(20 + i))) for i in range(10)]
        bad = srv.submit("big", list(range(501)))
        late = srv.submit("late", list(range(50)),
                          deadline=time.perf_counter() - 1.0)
        assert srv.drain(timeout=10)
        for f in ok:
            res = f.result(timeout=1)
            assert not isinstance(res, Rejected) and "latency" in res
        assert bad.result(timeout=1).reason == "infeasible"
        assert late.result(timeout=1).reason == "deadline"
        assert srv.metrics.total("requests_served") == 10
        assert srv.metrics.total("requests_rejected") == 2
    finally:
        srv.shutdown()


def test_server_sheds_queued_requests_whose_deadline_becomes_unreachable():
    # slow engine: 10ms/token, one instance -> queue builds
    pool = _fake_pool(1, sec_per_token=1e-2)
    srv = AsyncServer(pool, router=get_router("user_hash"),
                      admission=AdmissionController())
    srv.start()
    try:
        now = time.perf_counter()
        # each takes 1s; deadline 1.5s from now: the first is feasible at
        # admission (wait 0), the rest become doomed once the queue builds
        futs = [srv.submit("u", list(range(100)), deadline=now + 1.5)
                for _ in range(4)]
        assert srv.drain(timeout=15)
        outcomes = [f.result(timeout=1) for f in futs]
        served = [o for o in outcomes if not isinstance(o, Rejected)]
        rejected = [o for o in outcomes if isinstance(o, Rejected)]
        assert served and rejected
        assert {o.reason for o in rejected} <= {"shed", "deadline"}
    finally:
        srv.shutdown()


def test_server_cancel_queued_request():
    pool = _fake_pool(1, sec_per_token=1e-2)
    srv = AsyncServer(pool, router=get_router("user_hash"))
    srv.start()
    try:
        futs = [srv.submit("u", list(range(100))) for _ in range(3)]
        with pool.engines["i0"].lock:
            queued = [r.req_id for r in pool.engines["i0"].queue]
        assert queued and srv.cancel(queued[-1])
        assert srv.drain(timeout=15)
        outcomes = [f.result(timeout=1) for f in futs]
        cancelled = [o for o in outcomes if isinstance(o, Rejected)]
        assert len(cancelled) == 1 and cancelled[0].reason == "cancelled"
    finally:
        srv.shutdown()


def test_server_mark_failed_requeues_to_peers():
    pool = _fake_pool(3, sec_per_token=2e-3)
    srv = AsyncServer(pool, router=get_router("user_hash"))
    srv.start()
    try:
        futs = [srv.submit(f"u{i}", list(range(60))) for i in range(24)]
        victim = pool.live_names()[0]
        srv.mark_failed(victim)
        assert srv.drain(timeout=20)
        for f in futs:
            res = f.result(timeout=1)
            assert not isinstance(res, Rejected)
    finally:
        srv.shutdown()


def test_mark_failed_with_no_peers_rejects_stranded_futures():
    """Failing the LAST instance must resolve its queued futures as
    Rejected('no_instances') instead of hanging drain() forever."""
    pool = _fake_pool(1, sec_per_token=1e-2)
    srv = AsyncServer(pool, router=get_router("user_hash"))
    srv.start()
    try:
        futs = [srv.submit("u", list(range(100))) for _ in range(4)]
        srv.mark_failed("i0")
        assert srv.drain(timeout=10)
        outcomes = [f.result(timeout=5) for f in futs]
        rejected = [o for o in outcomes if isinstance(o, Rejected)]
        assert rejected and all(o.reason == "no_instances" for o in rejected)
    finally:
        srv.shutdown()


def test_server_worker_crash_fails_instance_and_requeues():
    """An engine raising inside step() must not strand futures: the worker
    marks the instance failed; queued work requeues to the healthy peer."""
    pool = _fake_pool(2, sec_per_token=5e-3)

    class Boom(Exception):
        pass

    crashing = pool.engines["i0"]
    orig_step = crashing.step

    def bad_step():
        if crashing.queue:
            raise Boom("chip fell over")
        return orig_step()

    crashing.step = bad_step
    srv = AsyncServer(pool, router=get_router("user_hash"))
    srv.start()
    try:
        futs = [srv.submit(f"u{i}", list(range(40))) for i in range(12)]
        assert srv.drain(timeout=20)
        outcomes = [f.result(timeout=1) for f in futs]
        assert all(not isinstance(o, Rejected) for o in outcomes)
        assert "i0" not in pool.live_names()
        assert srv.metrics.total("engine_errors") == 1
    finally:
        srv.shutdown()


def test_server_scale_down_rehomes_queued_requests():
    """Shrinking the pool must re-home queued work to survivors — every
    future still resolves with a served result, none re-routed back onto
    the instance being removed."""
    pool = _fake_pool(2, sec_per_token=1e-2)
    srv = AsyncServer(pool, router=get_router("user_hash"))
    srv.start()
    try:
        futs = [srv.submit(f"u{i}", list(range(50))) for i in range(8)]
        srv.scale_to(["i0"])
        assert "i1" not in pool.engines
        assert srv.drain(timeout=20)
        for f in futs:
            assert not isinstance(f.result(timeout=1), Rejected)
    finally:
        srv.shutdown()


def test_server_scale_to_empty_rejects_stranded_futures():
    """Removing the LAST instance must resolve its queued futures as
    Rejected('no_instances') instead of hanging drain() forever."""
    pool = _fake_pool(1, sec_per_token=1e-2)
    srv = AsyncServer(pool, router=get_router("user_hash"))
    srv.start()
    try:
        futs = [srv.submit("u", list(range(100))) for _ in range(4)]
        srv.scale_to([])
        assert srv.drain(timeout=10)
        outcomes = [f.result(timeout=5) for f in futs]
        rejected = [o for o in outcomes if isinstance(o, Rejected)]
        assert rejected and all(o.reason == "no_instances" for o in rejected)
    finally:
        srv.shutdown()


def test_submit_chain_cut_at_routed_engines_block_size():
    """Heterogeneous pool: the enqueued request's prefix chain must be cut
    at the CHOSEN engine's block size, not an arbitrary peer's."""
    from repro.runtime.fault_tolerance import rendezvous_hash
    pool = _fake_pool(2)
    pool.engines["i1"].ecfg = _BS8()      # i0 keeps block_size 16
    uid = next(u for u in (f"u{i}" for i in range(50))
               if rendezvous_hash(u, ["i0", "i1"]) == "i1")
    srv = AsyncServer(pool, router=get_router("user_hash"))
    srv._accepting = True                 # accept without starting workers
    tokens = list(range(32))
    srv.submit(uid, tokens)
    r = pool.engines["i1"].queue[0]
    assert tuple(r.chain) == token_chain(tokens, 8)


class _BS8:
    block_size = 8


def test_least_backlog_probes_with_per_blocksize_chains():
    """Heterogeneous pool: each engine must be probed with the chain cut at
    ITS block size, or the warm instance's cache match never fires."""
    tokens = list(range(64))
    pool = _fake_pool(2)
    warm = pool.engines["i1"]
    warm.ecfg = _BS8()                    # i0 keeps block_size 16
    chain8 = token_chain(tokens, 8)
    warm.cached.add(tuple(chain8))
    engines = {n: pool.engines[n] for n in pool.live_names()}
    chains = {16: token_chain(tokens, 16), 8: chain8}
    r = LeastBacklogRouter()
    assert r.route(user_id="u", n_input=64, chain=chains[16],
                   instances=engines, chains=chains) == "i1"
    # probed with only the bs-16 chain, i1's cache would never match
    assert warm.cached_prefix_len(chains[16]) == 0
    assert warm.cached_prefix_len(chain8) == 64


def test_drain_rechains_requests_across_block_sizes():
    """A request re-homed onto a peer with a different block size must get
    its chain re-cut at the peer's block size (a stale-granularity chain
    would corrupt the peer's prefix cache)."""
    pool = _fake_pool(2, sec_per_token=1e-2)
    pool.engines["i1"].ecfg = _BS8()
    tokens = list(range(32))
    pool.engines["i0"].submit(tokens, chain=token_chain(tokens, 16))
    pool.mark_failed("i0")
    r = pool.engines["i1"].queue[0]
    assert tuple(r.chain) == token_chain(tokens, 8)


def test_server_shutdown_drain_timeout_rejects_queued():
    """shutdown(drain=True, timeout=...) whose drain times out must still
    resolve every queued future (Rejected('shutdown')), not strand them."""
    pool = _fake_pool(1, sec_per_token=1e-2)
    srv = AsyncServer(pool, router=get_router("user_hash"))
    srv.start()
    futs = [srv.submit("u", list(range(100))) for _ in range(6)]
    srv.shutdown(drain=True, timeout=0.05)
    outcomes = [f.result(timeout=5) for f in futs]
    assert all(f.done() for f in futs)
    assert any(isinstance(o, Rejected) and o.reason == "shutdown"
               for o in outcomes)


def test_server_shutdown_without_drain_rejects_queued():
    pool = _fake_pool(1, sec_per_token=1e-2)
    srv = AsyncServer(pool, router=get_router("user_hash"))
    srv.start()
    futs = [srv.submit("u", list(range(100))) for _ in range(5)]
    srv.shutdown(drain=False)
    outcomes = [f.result(timeout=5) for f in futs]
    assert any(isinstance(o, Rejected) and o.reason == "shutdown"
               for o in outcomes)
    # post-shutdown submits reject immediately
    assert srv.submit("u", [1, 2]).result(timeout=1).reason == "shutdown"


def test_concurrent_submit_soak_every_request_resolves_exactly_once():
    """4 submitter threads x 60 requests against 3 instances; every future
    resolves exactly once with a result or a typed rejection."""
    pool = _fake_pool(3, sec_per_token=2e-5)
    srv = AsyncServer(pool, router=get_router("least_backlog"),
                      admission=AdmissionController(max_input_tokens=400))
    srv.start()
    resolutions = []
    res_lock = threading.Lock()
    futs = []
    futs_lock = threading.Lock()

    def on_done(f):
        with res_lock:
            resolutions.append(f.result(timeout=0))

    def submitter(tid):
        rng = np.random.default_rng(tid)
        for i in range(60):
            n = int(rng.integers(10, 300))
            if i % 17 == 0:
                n = 450                     # infeasible -> typed reject
            deadline = (time.perf_counter() - 1.0) if i % 23 == 0 else None
            f = srv.submit(f"u{tid}_{i % 7}", list(range(n)),
                           deadline=deadline)
            f.add_done_callback(on_done)
            with futs_lock:
                futs.append(f)

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(4)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    try:
        assert srv.drain(timeout=30), "soak drain timed out"
        assert len(futs) == 240
        for f in futs:
            assert f.done()
        # exactly once: every future fired its done-callback exactly once
        assert len(resolutions) == 240
        served = [r for r in resolutions if not isinstance(r, Rejected)]
        rejected = [r for r in resolutions if isinstance(r, Rejected)]
        assert len(served) + len(rejected) == 240
        assert len(rejected) >= 4 * (60 // 17)      # at least the infeasibles
        assert srv.metrics.total("requests_served") == len(served)
        assert srv.metrics.total("requests_rejected") == len(rejected)
    finally:
        srv.shutdown()


# ---- engine-level serving hooks (real engine) -------------------------------

@pytest.fixture(scope="module")
def setup():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduce_config
    from repro.models.model import build
    from repro.runtime.sharding import materialize
    cfg = reduce_config(get_config("qwen1.5-0.5b"), hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    return cfg, params


def _engine(cfg, params, **kw):
    from repro.core.engine import EngineConfig, PrefillOnlyEngine
    return PrefillOnlyEngine(cfg, params, EngineConfig(**kw))


def test_engine_config_not_shared_between_engines(setup):
    cfg, params = setup
    from repro.core.engine import PrefillOnlyEngine
    a = PrefillOnlyEngine(cfg, params)
    b = PrefillOnlyEngine(cfg, params)
    assert a.ecfg is not b.ecfg
    a.ecfg.pack_token_budget = 1
    assert b.ecfg.pack_token_budget != 1


def test_engine_cancel_and_shed(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    eng.jct_model.a, eng.jct_model.b = 1e-3, 0.0
    rid1 = eng.submit(list(range(40)))
    rid2 = eng.submit(list(range(40)), deadline=time.perf_counter() - 1.0)
    rid3 = eng.submit(list(range(40)),
                      deadline=time.perf_counter() + 1000.0)
    assert eng.cancel(rid1) is not None
    assert eng.cancel(rid1) is None                  # already gone
    shed = eng.shed_expired()
    assert [r.req_id for r in shed] == [rid2]
    assert [r.req_id for r in eng.queue] == [rid3]


def test_engine_pending_and_predict_jct_track_cache(setup):
    cfg, params = setup
    eng = _engine(cfg, params, cache_capacity_tokens=4096)
    eng.jct_model.a, eng.jct_model.b = 1.0, 0.0
    toks = list(np.random.default_rng(0).integers(0, cfg.vocab_size, 80))
    chain = token_chain(toks, eng.ecfg.block_size)
    assert eng.predict_jct(80, chain) == pytest.approx(80.0)
    eng.submit(toks)
    assert eng.pending_jct() == pytest.approx(80.0)
    eng.step()                                       # now the prefix is cached
    assert eng.cached_prefix_len(chain) == 80
    # hit-aware probe: predicts against the USABLE prefix a forward would
    # reuse (reuse granularity 4 blocks = 64 tokens, never the whole
    # request), not the raw 80-token match — the truthful backlog signal
    assert eng.predict_jct(80, chain) == pytest.approx(16.0)
    assert eng.pending_jct() == pytest.approx(0.0)   # queue empty


def test_engine_autotune_packing_formula(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    # a*S + b <= inflation * (a*ref + b) with inflation=2, ref=512:
    # S <= 1024 + b/a = 1024 + 1000 -> largest bucket <= 2024 is 1024
    eng.jct_model.a, eng.jct_model.b = 1e-4, 1e-1
    budget, n_max = eng.autotune_packing(ref_len=512)
    assert budget == 1024
    assert n_max == 1024 // 64
    assert eng.ecfg.pack_token_budget == 1024
    # overhead-free host: budget collapses to the inflation bound
    eng.jct_model.b = 0.0
    budget, _ = eng.autotune_packing(ref_len=512)
    assert budget == 1024                            # S <= 2*512


def test_async_server_end_to_end_real_engines(setup):
    cfg, params = setup
    pool = InstancePool(lambda name: _engine(cfg, params,
                                             cache_capacity_tokens=2048))
    pool.scale_to(["a", "b"])
    srv = AsyncServer(pool, router=get_router("least_backlog"),
                      admission=AdmissionController())
    srv.start()
    try:
        rng = np.random.default_rng(1)
        futs = [srv.submit(f"u{i % 3}",
                           rng.integers(0, cfg.vocab_size, 48).tolist(),
                           allowed_tokens=(5, 9)) for i in range(6)]
        assert srv.drain(timeout=120)
        for f in futs:
            res = f.result(timeout=1)
            assert not isinstance(res, Rejected)
            assert set(res["scores"]) == {5, 9}
            assert abs(sum(res["scores"].values()) - 1.0) < 1e-6
        assert srv.metrics.total("requests_served") == 6
        assert srv.metrics.merged_histogram("latency_seconds").count == 6
    finally:
        srv.shutdown()


# ---- admission feedback loop ------------------------------------------------

def test_admission_slack_tightens_on_shed_rate():
    reg = MetricsRegistry()
    ctrl = AdmissionController(deadline_slack=1.0, adapt_window=10,
                               shed_target=0.1, adapt_rate=2.0,
                               max_slack=4.0, metrics=reg)
    # 8 served + 2 shed = 20% shed rate over the window -> tighten
    for _ in range(8):
        ctrl.record_outcome(shed=False)
    for _ in range(2):
        ctrl.record_outcome(shed=True)
    assert ctrl.deadline_slack == pytest.approx(2.0)
    assert ctrl.slack_adjustments == 1
    assert reg.counter("admission_slack_tightened").value == 1
    assert reg.gauge("admission_deadline_slack").value == pytest.approx(2.0)
    # window cleared: the same burst is not double-counted
    assert len(ctrl._outcomes) == 0
    # a clean window relaxes back toward the configured floor (never below)
    for _ in range(10):
        ctrl.record_outcome(shed=False)
    assert ctrl.deadline_slack == pytest.approx(1.0)
    for _ in range(10):
        ctrl.record_outcome(shed=False)
    assert ctrl.deadline_slack == pytest.approx(1.0)   # floor holds
    assert reg.counter("admission_slack_relaxed").value == 1


def test_admission_slack_respects_max_and_disabled():
    ctrl = AdmissionController(deadline_slack=3.0, adapt_window=4,
                               shed_target=0.0, adapt_rate=10.0,
                               max_slack=4.0)
    for _ in range(8):
        ctrl.record_outcome(shed=True)
    assert ctrl.deadline_slack == pytest.approx(4.0)   # clamped at max
    off = AdmissionController(deadline_slack=1.0, adapt=False,
                              adapt_window=2)
    for _ in range(10):
        off.record_outcome(shed=True)
    assert off.deadline_slack == 1.0                    # feedback disabled


def test_server_feeds_shed_outcomes_back_to_admission(setup):
    """End-to-end: a served with-deadline request reports shed=False; a
    queued request shed by the worker reports shed=True, and enough sheds
    in the window tighten ``deadline_slack`` (counter + gauge recorded)."""
    cfg, params = setup
    pool = InstancePool(lambda name: _engine(cfg, params))
    pool.scale_to(["a"])
    ctrl = AdmissionController(adapt_window=2, shed_target=0.0,
                               adapt_rate=1.5)
    srv = AsyncServer(pool, admission=ctrl)
    assert ctrl.metrics is srv.metrics       # registry auto-attached
    eng = pool.engines["a"]
    srv.start()
    try:
        rng = np.random.default_rng(2)
        f = srv.submit("u", rng.integers(0, cfg.vocab_size, 32).tolist(),
                       allowed_tokens=(5, 9),
                       deadline=time.perf_counter() + 300.0)
        assert srv.drain(timeout=120)
        assert not isinstance(f.result(timeout=1), Rejected)
        assert list(ctrl._outcomes) == [False]
        # already-expired requests enqueued behind the server's back (no
        # admission gate) are shed in-queue and recorded as shed=True:
        # window [served, shed] -> 50% shed rate -> tighten
        for _ in range(2):
            eng.submit(rng.integers(0, cfg.vocab_size, 16).tolist(),
                       deadline=time.perf_counter() - 1.0)
        stop = time.time() + 30
        while ctrl.slack_adjustments == 0 and time.time() < stop:
            time.sleep(0.01)
        assert ctrl.slack_adjustments >= 1
        assert ctrl.deadline_slack > 1.0
        assert srv.metrics.counter("admission_slack_tightened").value >= 1
        assert srv.metrics.gauge("admission_deadline_slack").value > 1.0
    finally:
        srv.shutdown(drain=False)


# ---- Prometheus exposition --------------------------------------------------

def test_render_prometheus_format():
    reg = MetricsRegistry(buckets=(0.1, 1.0))
    reg.counter("requests_served", "a").inc(3)
    reg.counter("requests_served", "b").inc(2)
    reg.gauge("queue_depth", "a").set(5)
    reg.counter("requests_rejected").inc()          # global, unlabelled
    h = reg.histogram("latency_seconds", "a")
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    txt = reg.render_prometheus()
    assert "# TYPE prefillonly_requests_served counter" in txt
    assert 'prefillonly_requests_served{instance="a"} 3' in txt
    assert 'prefillonly_requests_served{instance="b"} 2' in txt
    assert "prefillonly_requests_rejected 1" in txt  # no instance label
    assert "# TYPE prefillonly_latency_seconds histogram" in txt
    # cumulative buckets: 1 below 0.1, 2 below 1.0, all 3 at +Inf
    assert 'prefillonly_latency_seconds_bucket{instance="a",le="0.1"} 1' in txt
    assert 'prefillonly_latency_seconds_bucket{instance="a",le="1"} 2' in txt
    assert ('prefillonly_latency_seconds_bucket{instance="a",le="+Inf"} 3'
            in txt)
    assert 'prefillonly_latency_seconds_count{instance="a"} 3' in txt
    assert txt.endswith("\n")


def test_metrics_http_endpoint():
    import urllib.request
    from repro.launch.serve import start_metrics_server
    reg = MetricsRegistry()
    reg.counter("requests_served", "a").inc(7)
    server = start_metrics_server(reg, port=0)
    try:
        host, port = server.server_address
        with urllib.request.urlopen(
                f"http://{host}:{port}/metrics", timeout=5) as resp:
            assert resp.status == 200
            assert "text/plain" in resp.headers["Content-Type"]
            body = resp.read().decode()
        assert 'prefillonly_requests_served{instance="a"} 7' in body
        # non-metrics paths 404
        import urllib.error
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"http://{host}:{port}/nope", timeout=5)
    finally:
        server.shutdown()
        server.server_close()   # release the socket, not just the loop
