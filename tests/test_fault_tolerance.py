"""Fault-tolerance machinery: watchdog, NaN guard, rendezvous routing,
instance pool re-dispatch, preemption flag."""
import numpy as np
from hypothesis import given, strategies as st

from repro.runtime.fault_tolerance import (InstancePool, NaNGuard,
                                           PreemptionHandler, StepWatchdog,
                                           rendezvous_hash)


def test_watchdog_trips_on_straggler():
    w = StepWatchdog(window=20, factor=3.0, min_history=5)
    for _ in range(10):
        assert not w.observe(0.1)
    assert w.observe(1.0)          # 10x p95
    assert w.trips == 1


def test_nan_guard_policy():
    g = NaNGuard(limit=2)
    assert g.observe(1.0) == "ok"
    assert g.observe(float("nan")) == "skip"
    assert g.observe(float("nan")) == "reload"
    assert g.observe(0.5) == "ok"
    assert g.consecutive == 0


def test_preemption_flag():
    import os
    import signal
    h = PreemptionHandler().install()
    assert not h.requested
    os.kill(os.getpid(), signal.SIGTERM)
    assert h.requested
    h.uninstall()


@given(st.lists(st.text(min_size=1, max_size=8), min_size=2, max_size=6,
                unique=True))
def test_rendezvous_minimal_remap(instances):
    """Removing one instance only remaps users that were ON that instance."""
    users = [f"user{i}" for i in range(40)]
    before = {u: rendezvous_hash(u, instances) for u in users}
    removed = instances[0]
    after = {u: rendezvous_hash(u, instances[1:]) for u in users}
    for u in users:
        if before[u] != removed:
            assert after[u] == before[u], "stable user was remapped"


def test_rendezvous_balance():
    instances = [f"inst{i}" for i in range(4)]
    counts = {i: 0 for i in instances}
    for u in range(400):
        counts[rendezvous_hash(f"user{u}", instances)] += 1
    # no instance should be starved or hot beyond 2x fair share
    assert min(counts.values()) > 100 / 2
    assert max(counts.values()) < 100 * 2


class _FakeEngine:
    def __init__(self, name):
        self.name = name
        self.queue = []
        self.done = []

    def submit(self, tokens, user_id=None, **kw):
        class R:
            pass
        r = R()
        r.user_id = user_id
        r.req_id = len(self.queue)
        self.queue.append(r)
        return r.req_id

    def step(self):
        if self.queue:
            self.done.append(self.queue.pop(0))


def test_pool_redispatch_on_failure():
    pool = InstancePool(_FakeEngine)
    pool.scale_to(["a", "b", "c"])
    for u in range(30):
        pool.submit(f"user{u}", [1, 2, 3])
    queued_before = sum(len(e.queue) for e in pool.engines.values())
    victim = pool.live_names()[0]
    n_victim = len(pool.engines[victim].queue)
    pool.mark_failed(victim)
    assert victim not in pool.live_names()
    queued_after = sum(len(pool.engines[n].queue)
                       for n in pool.live_names())
    assert queued_after == queued_before  # nothing lost
    assert pool.redispatched == n_victim


def test_pool_scale_down_rehomes_queued_to_survivors():
    pool = InstancePool(_FakeEngine)
    pool.scale_to(["a", "b", "c"])
    for u in range(30):
        pool.submit(f"user{u}", [1, 2, 3])
    queued_before = sum(len(e.queue) for e in pool.engines.values())
    dropped = pool.scale_to(["a"])
    assert dropped == []                  # every request found a survivor
    assert set(pool.engines) == {"a"}
    assert len(pool.engines["a"].queue) == queued_before  # nothing lost
    # shrink to nothing: no healthy peer -> requests come back to the caller
    dropped = pool.scale_to([])
    assert len(dropped) == queued_before
    assert pool.live_names() == []


def test_pool_elastic_scale_up_down():
    pool = InstancePool(_FakeEngine)
    pool.scale_to(["a", "b"])
    routes2 = {f"u{i}": pool.route(f"u{i}") for i in range(20)}
    pool.scale_to(["a", "b", "c"])
    routes3 = {f"u{i}": pool.route(f"u{i}") for i in range(20)}
    moved = sum(1 for u in routes2 if routes2[u] != routes3[u])
    assert moved <= 20 * 0.7  # rendezvous: ~1/3 expected, never most
    pool.scale_to(["a", "b"])
    routes2b = {f"u{i}": pool.route(f"u{i}") for i in range(20)}
    assert routes2b == routes2  # scale-down restores prior mapping
