"""Property tests for prefix-aware packed attention: for ANY segment layout
(random segment count, suffix lengths, per-segment prefix offsets — including
zero-prefix misses mixed with hits), the positioned segment-restricted mask
in both the Pallas kernel and the XLA oracle equals the naive ground truth,
and each segment's rows equal a standalone prefix-attention call."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.models.layers import PAD_POS, blocked_attention

layouts = st.lists(
    st.tuples(st.integers(min_value=0, max_value=24),    # prefix len
              st.integers(min_value=1, max_value=16)),   # suffix len
    min_size=1, max_size=4)


def _arrays(plens, slens, key, H=4, KV=2, d=8):
    S, P = sum(slens), sum(plens)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (1, S, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, KV, d), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, KV, d), jnp.float32)
    pk = jax.random.normal(ks[3], (1, max(P, 1), KV, d),
                           jnp.float32)[:, :P]
    pv = jax.random.normal(ks[4], (1, max(P, 1), KV, d),
                           jnp.float32)[:, :P]
    seg = np.full((1, S), -1, np.int32)
    pos = np.zeros((1, S), np.int32)
    pseg = np.full((1, P), -1, np.int32)
    ppos = np.full((1, P), PAD_POS, np.int32)
    off = poff = 0
    for n, (p, s) in enumerate(zip(plens, slens)):
        seg[0, off:off + s] = n
        pos[0, off:off + s] = p + np.arange(s)
        pseg[0, poff:poff + p] = n
        ppos[0, poff:poff + p] = np.arange(p)
        off += s
        poff += p
    return (q, k, v, pk, pv, jnp.asarray(seg), jnp.asarray(pos),
            jnp.asarray(pseg), jnp.asarray(ppos))


@settings(max_examples=20, deadline=None)
@given(layout=layouts, seed=st.integers(min_value=0, max_value=2**16))
def test_positioned_segment_mask_matches_ground_truth(layout, seed):
    plens = tuple(p for p, _ in layout)
    slens = tuple(s for _, s in layout)
    q, k, v, pk, pv, seg, pos, pseg, ppos = _arrays(
        plens, slens, jax.random.PRNGKey(seed))
    got = ops.packed_flash_attention(
        q, k, v, seg, prefix_k=pk, prefix_v=pv, prefix_seg=pseg,
        positions=pos, prefix_positions=ppos, block_q=16, block_k=16)
    want = ref.packed_prefix_attention_ref(
        q.transpose(0, 2, 1, 3),
        jnp.concatenate([pk, k], axis=1).transpose(0, 2, 1, 3),
        jnp.concatenate([pv, v], axis=1).transpose(0, 2, 1, 3),
        seg, jnp.concatenate([pseg, seg], axis=1),
        pos, jnp.concatenate([ppos, pos], axis=1)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-4, rtol=3e-4)


@settings(max_examples=20, deadline=None)
@given(layout=layouts, seed=st.integers(min_value=0, max_value=2**16))
def test_oracle_segments_match_standalone_prefix_attention(layout, seed):
    """Every segment of the positioned oracle equals its own standalone
    concat(prefix, suffix) attention with a scalar q_offset — the exact
    solo-suffix path the engine falls back to."""
    plens = tuple(p for p, _ in layout)
    slens = tuple(s for _, s in layout)
    q, k, v, pk, pv, seg, pos, pseg, ppos = _arrays(
        plens, slens, jax.random.PRNGKey(seed))
    got = blocked_attention(
        q, jnp.concatenate([pk, k], axis=1),
        jnp.concatenate([pv, v], axis=1), seg_ids=seg,
        seg_ids_k=jnp.concatenate([pseg, seg], axis=1),
        pos_q=pos, pos_k=jnp.concatenate([ppos, pos], axis=1),
        q_block=16, kv_block=16)
    off = 0
    for n, (p, s) in enumerate(zip(plens, slens)):
        poff = sum(plens[:n])
        ksolo = jnp.concatenate([pk[:, poff:poff + p], k[:, off:off + s]],
                                axis=1)
        vsolo = jnp.concatenate([pv[:, poff:poff + p], v[:, off:off + s]],
                                axis=1)
        solo = blocked_attention(q[:, off:off + s], ksolo, vsolo,
                                 q_offset=p, q_block=16, kv_block=16)
        np.testing.assert_allclose(np.asarray(got[:, off:off + s]),
                                   np.asarray(solo), atol=3e-4, rtol=3e-4)
        off += s
