"""Prefix-cache unit + property tests (invariants from the module docstring)."""
from hypothesis import given, strategies as st

from repro.core.prefix_cache import PrefixCache, token_chain


def chain_of(n_tokens, seed=0, block=4):
    toks = [(seed * 1000 + i) % 97 for i in range(n_tokens)]
    return token_chain(toks, block), toks


def test_match_and_insert_basic():
    c = PrefixCache(capacity_blocks=8, block_size=4)
    chain, toks = chain_of(20)
    assert c.match_len(chain) == 0
    c.insert(chain, n_keep_tokens=20)
    assert c.match_len(chain) == 20  # 5 blocks
    # shared prefix of 8 tokens
    toks2 = toks[:8] + [999] * 8
    chain2 = token_chain(toks2, 4)
    assert c.match_len(chain2) == 8


def test_suffix_discard_budget():
    c = PrefixCache(capacity_blocks=100, block_size=4)
    chain, _ = chain_of(40)
    c.insert(chain, n_keep_tokens=12)      # suffix discard at 12 tokens
    assert c.match_len(chain) == 12
    assert c.used_blocks == 3


def test_lru_leaf_eviction_preserves_prefix_invariant():
    c = PrefixCache(capacity_blocks=4, block_size=4)
    a, _ = chain_of(16, seed=1)
    c.insert(a, 16, now=1.0)
    b, _ = chain_of(16, seed=2)
    c.insert(b, 16, now=2.0)               # evicts a's blocks leaf-first
    assert c.used_blocks <= 4
    # invariant: every resident block's parent is resident
    for h, blk in c.blocks.items():
        assert blk.parent == 0 or blk.parent in c.blocks


def test_pinned_blocks_survive_eviction():
    c = PrefixCache(capacity_blocks=4, block_size=4)
    a, _ = chain_of(16, seed=1)
    c.insert(a, 16, now=1.0)
    c.pin(a, 4)
    b, _ = chain_of(32, seed=2)
    c.insert(b, 32, now=2.0)               # can't evict pinned a
    assert c.match_len(a) == 16
    c.unpin(a, 4)
    c.insert(b, 32, now=3.0)
    assert c.match_len(b) > 0


def test_zero_capacity_cache_never_stores():
    c = PrefixCache(capacity_blocks=0, block_size=4)
    a, _ = chain_of(16)
    c.insert(a, 16)
    assert c.used_blocks == 0
    assert c.match_len(a) == 0


@given(st.lists(st.tuples(st.integers(0, 9), st.integers(1, 48),
                          st.integers(0, 48)), min_size=1, max_size=40),
       st.integers(1, 10))
def test_cache_invariants_under_random_ops(ops, capacity):
    """Random insert/match sequences: capacity bound + parent-resident
    invariant + match consistency always hold."""
    c = PrefixCache(capacity_blocks=capacity, block_size=4)
    now = 0.0
    for seed, length, keep in ops:
        chain, _ = chain_of(length, seed=seed)
        now += 1.0
        c.insert(chain, keep, now=now)
        assert c.used_blocks <= capacity
        for h, blk in c.blocks.items():
            assert blk.parent == 0 or blk.parent in c.blocks, \
                "orphan block (parent evicted before child)"
        # match is block-granular and bounded by the chain itself
        m = c.match_len(chain)
        assert m % 4 == 0 and m <= (length // 4) * 4


@given(st.integers(1, 60), st.integers(1, 60), st.integers(1, 8))
def test_match_is_block_granular_common_prefix(n1, n2, block):
    toks1 = list(range(n1))
    toks2 = list(range(min(n1, n2))) + [777] * max(0, n2 - n1)
    c = PrefixCache(capacity_blocks=100, block_size=block)
    ch1 = token_chain(toks1, block)
    ch2 = token_chain(toks2, block)
    c.insert(ch1, n1)
    m = c.match_len(ch2)
    common = min(n1, n2) if n2 <= n1 else min(n1, n2)
    assert m <= (common // block) * block
    assert m % block == 0
