"""Per-arch smoke tests (assignment requirement): reduced config of the SAME
family, one forward/train step on CPU, asserting shapes + finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, reduce_config
from repro.models.model import build, make_batch
from repro.runtime.sharding import materialize

ARCHS = list_archs()  # the assigned 10


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduce_config(get_config(arch))
            api = build(cfg)
            params = materialize(jax.random.PRNGKey(0), api.defs(),
                                 jnp.float32)
            cache[arch] = (cfg, api, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch, built):
    cfg, api, params = built(arch)
    batch = make_batch(cfg, 2, 64, jax.random.PRNGKey(1))
    loss = api.train_loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # gradients flow
    g = jax.grad(lambda p: api.train_loss(p, batch))(params)
    gnorm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
    assert gnorm > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_shapes_and_finite(arch, built):
    cfg, api, params = built(arch)
    batch = make_batch(cfg, 2, 64, jax.random.PRNGKey(2), kind="prefill")
    logits, aux = api.prefill(params, batch, kv_keep=32)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    assert aux is not None


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes_and_finite(arch, built):
    cfg, api, params = built(arch)
    cache = api.init_cache(2, 128)
    tok = jnp.array([1, 2], jnp.int32)
    logits, cache2 = api.decode_step(params, tok, cache,
                                     jnp.zeros(2, jnp.int32))
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)
