"""Chaos-hardened serving: seeded fault injection, JCT-deadline watchdog,
idempotent retry, brownout ladder, and the exactly-once soak.

The invariants under test (ISSUE 6 acceptance):
  * every submitted future resolves EXACTLY once, under any seeded schedule
    of step crashes, hangs, stragglers, NaN corruption, and submit failures
  * no future hangs past the watchdog deadline (bounded drain)
  * >= 90% of retry-eligible requests resolve with a SERVED result
  * late results from confiscated (watchdog-tripped) batches are dropped,
    never double-delivered
"""
import threading
import time

import numpy as np
import pytest

from repro.core.scheduler import Request
from repro.runtime.fault_tolerance import (InstancePool, JCTDeadlineWatchdog,
                                           NaNGuard)
from repro.serving import (AdmissionController, AsyncServer,
                           BrownoutController, ChaosConfig, FaultPlan,
                           Rejected, RetryPolicy, wrap_pool)
from repro.serving.server import _Tracked  # noqa: F401  (import sanity)


# ---- fakes -------------------------------------------------------------------

class FakeServingEngine:
    """Protocol double with the full robustness surface: in-flight
    accounting (``_inflight``/``inflight_snapshot``), finite scores, and the
    brownout ``set_degraded`` hook. step() sleeps sec_per_token per token."""

    class _ECfg:
        block_size = 16

    ecfg = _ECfg()

    def __init__(self, name, sec_per_token=2e-4):
        self.name = name
        self.lock = threading.RLock()
        self.queue = []
        self.results = {}
        self._last = []
        self.a = sec_per_token
        self.steps = 0
        self._inflight = []
        self._inflight_pred = 0.0
        self._inflight_t0 = 0.0
        self.degraded = False

    def submit(self, tokens, allowed_tokens=None, user_id=None, now=None,
               deadline=None, chain=None):
        r = Request(n_input=len(tokens), arrival=time.perf_counter(),
                    chain=chain or (), tokens=list(tokens), user_id=user_id,
                    allowed_tokens=tuple(allowed_tokens)
                    if allowed_tokens else None, deadline=deadline)
        with self.lock:
            self.queue.append(r)
        return r.req_id

    def cancel(self, rid):
        with self.lock:
            for i, r in enumerate(self.queue):
                if r.req_id == rid:
                    return self.queue.pop(i)
        return None

    def shed_expired(self, now=None):
        now = time.perf_counter() if now is None else now
        shed = []
        with self.lock:
            keep = []
            for r in self.queue:
                doomed = (r.deadline is not None
                          and now + self.a * r.n_input > r.deadline)
                (shed if doomed else keep).append(r)
            self.queue[:] = keep
        return shed

    def pending_jct(self, now=None):
        with self.lock:
            return sum(self.a * r.n_input for r in self.queue)

    def predict_jct(self, n, chain=()):
        return self.a * n

    def cached_prefix_len(self, chain):
        return 0

    def inflight_snapshot(self):
        with self.lock:
            return (list(self._inflight), self._inflight_pred,
                    self._inflight_t0)

    def set_degraded(self, flag):
        self.degraded = bool(flag)

    def step(self):
        with self.lock:
            if not self.queue:
                return None
            r = self.queue.pop(0)
            self._inflight = [r.req_id]
            self._inflight_pred = self.a * r.n_input
            self._inflight_t0 = time.perf_counter()
        time.sleep(self.a * r.n_input)
        r.finish_time = time.perf_counter()
        with self.lock:
            res = {"req_id": r.req_id, "latency": r.latency, "n_cached": 0,
                   "n_input": r.n_input, "deadline": r.deadline, "token": 5}
            if r.allowed_tokens:
                res["scores"] = {int(t): 1.0 / len(r.allowed_tokens)
                                 for t in r.allowed_tokens}
            self.results[r.req_id] = res
            self._last = [r.req_id]
            self._inflight = []
            self._inflight_pred = 0.0
            self.steps += 1
        return r.req_id

    @property
    def last_step_ids(self):
        return list(self._last)

    def stats(self):
        return {"steps": self.steps}


class FirstRouter:
    """Deterministic: always the alphabetically-first live instance — makes
    'which instance got the request / which peer got the retry' exact."""

    def route(self, user_id=None, n_input=0, chain=(), instances=None,
              chains=None):
        return sorted(instances)[0]


def _pool(n=2, plan=None, cls=FakeServingEngine, **kw):
    pool = InstancePool(lambda name: cls(name, **kw))
    pool.scale_to([f"i{k}" for k in range(n)])
    if plan is not None:
        wrap_pool(pool, plan)
    return pool


def _server(pool, retry=None, watchdog=None, brownout=None, admission=None,
            router=None):
    return AsyncServer(pool, router=router or FirstRouter(),
                       admission=admission,
                       retry=retry if retry is not None
                       else RetryPolicy(budget=2, backoff=0.0),
                       watchdog=watchdog, brownout=brownout).start()


def _count_resolutions(futs):
    """Attach done-callbacks; returns a dict rid->count updated as futures
    resolve (exactly-once means every count lands at exactly 1)."""
    counts = {}
    lock = threading.Lock()
    for i, f in enumerate(futs):
        def cb(fut, i=i):
            with lock:
                counts[i] = counts.get(i, 0) + 1
        counts.setdefault(i, 0)
        f.add_done_callback(cb)
    return counts


# ---- fault plan --------------------------------------------------------------

def test_fault_plan_deterministic_across_instances_and_runs():
    cfg = ChaosConfig(seed=7, step_error=0.1, hang=0.1, nan_score=0.1,
                      straggler=0.1)
    seq1 = [FaultPlan(cfg).draw("a", "step") for _ in range(1)]  # noqa: F841
    p1, p2 = FaultPlan(cfg), FaultPlan(cfg)
    s1 = [p1.draw("a", "step") for _ in range(200)]
    s2 = [p2.draw("a", "step") for _ in range(200)]
    assert s1 == s2                          # replayable
    assert any(s1)                           # something actually fires
    # per-instance streams are independent but each deterministic
    assert [p1.draw("b", "step") for _ in range(50)] == \
           [p2.draw("b", "step") for _ in range(50)]


def test_fault_plan_schedule_fires_at_exact_op_index():
    cfg = ChaosConfig(schedule=[("a", 2, "hang"), ("a", 0, "submit_error")])
    p = FaultPlan(cfg)
    assert p.draw("a", "submit") == "submit_error"
    assert [p.draw("a", "step") for _ in range(4)] == \
           [None, None, "hang", None]
    assert p.counts() == {"submit_error": 1, "hang": 1}


def test_fault_plan_max_faults_bounds_total():
    p = FaultPlan(ChaosConfig(step_error=1.0, max_faults=2))
    kinds = [p.draw("a", "step") for _ in range(5)]
    assert kinds == ["step_error", "step_error", None, None, None]


def test_fault_plan_rejects_unknown_schedule_kind():
    with pytest.raises(AssertionError):
        ChaosConfig(schedule=[("a", 0, "meteor_strike")])


# ---- watchdog unit -----------------------------------------------------------

def test_jct_deadline_watchdog_floors():
    wd = JCTDeadlineWatchdog(factor=4.0, min_deadline=0.5)
    assert wd.batch_deadline(1.0) == pytest.approx(4.0)
    assert wd.batch_deadline(0.0) == pytest.approx(0.5)   # absolute floor
    for _ in range(20):
        wd.observe(0.2)
    # running-p95 floor covers a cold/degenerate JCT fit (predicted ~0)
    assert wd.batch_deadline(0.0) == pytest.approx(0.8)
    assert wd.batch_deadline(1.0) == pytest.approx(4.0)


# ---- retry paths -------------------------------------------------------------

def test_step_crash_retries_on_peer_and_serves():
    plan = FaultPlan(ChaosConfig(schedule=[("i0", 0, "step_error")]))
    pool = _pool(2, plan)
    srv = _server(pool)
    fut = srv.submit("u", list(range(40)), allowed_tokens=(5, 9))
    res = fut.result(timeout=10)
    assert not isinstance(res, Rejected)     # transparently re-served
    assert srv.metrics.total("requests_retried") == 1
    assert srv.metrics.total("engine_errors") == 1
    assert pool.healthy["i0"] is False and pool.healthy["i1"] is True
    srv.shutdown(drain=True, timeout=5)


def test_retry_budget_exhausted_resolves_rejected_error():
    # both instances crash their first step: attempt 0 dies on i0, the
    # retry dies on i1, and with no live peer left the future must resolve
    # Rejected("error") — never hang
    plan = FaultPlan(ChaosConfig(schedule=[("i0", 0, "step_error"),
                                           ("i1", 0, "step_error")]))
    pool = _pool(2, plan)
    srv = _server(pool)
    res = srv.submit("u", list(range(40))).result(timeout=10)
    assert isinstance(res, Rejected) and res.reason == "error"
    assert srv.metrics.total("requests_retried") >= 1
    srv.shutdown(drain=True, timeout=5)


def test_retry_disabled_rejects_lost_inflight():
    plan = FaultPlan(ChaosConfig(schedule=[("i0", 0, "step_error")]))
    pool = _pool(2, plan)
    srv = _server(pool, retry=RetryPolicy(budget=0))
    res = srv.submit("u", list(range(40))).result(timeout=10)
    assert isinstance(res, Rejected) and res.reason == "error"
    assert srv.metrics.total("requests_retried") == 0
    srv.shutdown(drain=True, timeout=5)


def test_transient_submit_failure_falls_back_to_peer():
    plan = FaultPlan(ChaosConfig(schedule=[("i0", 0, "submit_error")]))
    pool = _pool(2, plan)
    srv = _server(pool)
    res = srv.submit("u", list(range(40))).result(timeout=10)
    assert not isinstance(res, Rejected)
    assert srv.metrics.counter("submit_failures", "i0").value == 1
    assert pool.engines["i1"].steps == 1     # the fallback peer served it
    srv.shutdown(drain=True, timeout=5)


def test_nan_corruption_quarantined_and_retried():
    plan = FaultPlan(ChaosConfig(schedule=[("i0", 0, "nan_score")]))
    pool = _pool(2, plan)
    srv = _server(pool)
    res = srv.submit("u", list(range(40)), allowed_tokens=(5, 9)) \
        .result(timeout=10)
    assert not isinstance(res, Rejected)
    assert all(np.isfinite(v) for v in res["scores"].values())
    assert srv.metrics.total("results_quarantined") == 1
    assert srv.metrics.total("requests_retried") == 1
    # quarantine is NOT a crash: the producing instance stays healthy
    assert pool.healthy["i0"] is True
    srv.shutdown(drain=True, timeout=5)


# ---- watchdog + exactly-once -------------------------------------------------

def test_hang_trips_watchdog_and_late_result_is_dropped():
    plan = FaultPlan(ChaosConfig(schedule=[("i0", 0, "hang")],
                                 hang_seconds=0.8))
    pool = _pool(2, plan)
    wd = JCTDeadlineWatchdog(factor=4.0, min_deadline=0.12, interval=0.02)
    srv = _server(pool, watchdog=wd)
    fut = srv.submit("u", list(range(40)), allowed_tokens=(5, 9))
    counts = _count_resolutions([fut])
    t0 = time.perf_counter()
    res = fut.result(timeout=10)
    resolved_in = time.perf_counter() - t0
    assert not isinstance(res, Rejected)
    # the future resolved via the retry path WELL before the hang released
    assert resolved_in < 0.6, resolved_in
    assert srv.metrics.total("watchdog_trips") >= 1
    assert pool.healthy["i0"] is False
    # once the hang releases, i0's worker harvests the stale batch — the
    # tombstone must swallow it (exactly-once), counted as a late drop
    deadline = time.monotonic() + 5
    while (srv.metrics.total("late_results_dropped") < 1
           and time.monotonic() < deadline):
        time.sleep(0.02)
    assert srv.metrics.total("late_results_dropped") == 1
    assert counts[0] == 1
    srv.shutdown(drain=True, timeout=5)


def test_straggler_below_deadline_does_not_trip():
    plan = FaultPlan(ChaosConfig(schedule=[("i0", 0, "straggler")],
                                 straggler_seconds=0.05))
    pool = _pool(2, plan)
    wd = JCTDeadlineWatchdog(factor=4.0, min_deadline=0.5, interval=0.02)
    srv = _server(pool, watchdog=wd)
    res = srv.submit("u", list(range(40))).result(timeout=10)
    assert not isinstance(res, Rejected)
    assert srv.metrics.total("watchdog_trips") == 0
    assert srv.metrics.total("requests_retried") == 0
    assert pool.healthy["i0"] is True        # slow is not dead
    srv.shutdown(drain=True, timeout=5)


# ---- races (satellite S4) ----------------------------------------------------

def test_cancel_racing_inflight_step_still_serves_exactly_once():
    pool = _pool(1, sec_per_token=0.01)      # ~0.4s step
    srv = _server(pool, watchdog=None)
    fut = srv.submit("u", list(range(40)))
    counts = _count_resolutions([fut])
    eng = pool.engines["i0"]
    deadline = time.monotonic() + 5
    while not eng._inflight and time.monotonic() < deadline:
        time.sleep(0.005)
    assert eng._inflight, "step never started"
    rid = eng._inflight[0]
    # cancel() is queued-only by contract: an executing request cannot be
    # recalled, so this returns False and the future still serves
    assert srv.cancel(rid) is False
    res = fut.result(timeout=10)
    assert not isinstance(res, Rejected)
    assert counts[0] == 1
    srv.shutdown(drain=True, timeout=5)


def test_cancel_queued_behind_inflight_step():
    pool = _pool(1, sec_per_token=0.01)
    srv = _server(pool, watchdog=None)
    fut1 = srv.submit("u", list(range(40)))
    fut2 = srv.submit("u", list(range(40)))
    eng = pool.engines["i0"]
    deadline = time.monotonic() + 5
    while not eng._inflight and time.monotonic() < deadline:
        time.sleep(0.005)
    with eng.lock:
        queued = [r.req_id for r in eng.queue]
    assert len(queued) == 1
    assert srv.cancel(queued[0]) is True
    res2 = fut2.result(timeout=10)
    assert isinstance(res2, Rejected) and res2.reason == "cancelled"
    assert not isinstance(fut1.result(timeout=10), Rejected)
    srv.shutdown(drain=True, timeout=5)


def test_submit_races_mark_failed_under_injector():
    """Submitting threads race a chaos-monkey thread that fails and
    resurrects instances while transient submit faults fire — every future
    must resolve exactly once, and the pool must keep serving."""
    plan = FaultPlan(ChaosConfig(seed=3, submit_error=0.1))
    pool = _pool(3, plan)
    srv = _server(pool, retry=RetryPolicy(budget=3, backoff=0.0))
    futs, flock = [], threading.Lock()
    stop = threading.Event()

    def submitter(k):
        for j in range(40):
            f = srv.submit(f"u{k}-{j}", list(range(30)))
            with flock:
                futs.append(f)
            time.sleep(0.001)

    def monkey():
        names = ["i0", "i1", "i2"]
        k = 0
        while not stop.is_set():
            victim = names[k % 3]
            k += 1
            srv.mark_failed(victim)
            time.sleep(0.01)
            alive = [n for n in names if pool.healthy.get(n)]
            srv.scale_to(alive)              # remove the corpse...
            srv.scale_to(names)              # ...and resurrect it fresh
            time.sleep(0.02)

    threads = [threading.Thread(target=submitter, args=(k,))
               for k in range(3)]
    mk = threading.Thread(target=monkey)
    [t.start() for t in threads]
    mk.start()
    [t.join() for t in threads]
    stop.set()
    mk.join()
    counts = _count_resolutions(futs)
    assert srv.drain(timeout=30), "futures hung after chaos"
    assert len(futs) == 120
    assert all(f.done() for f in futs)
    assert set(counts.values()) == {1}       # exactly once, every future
    outcomes = [f.result() for f in futs]
    served = [o for o in outcomes if not isinstance(o, Rejected)]
    assert len(served) >= 0.9 * len(futs)
    srv.shutdown(drain=True, timeout=5)


# ---- worker harvest regression (satellite S1) --------------------------------

class VanishingResultEngine(FakeServingEngine):
    """First step's result disappears between completion and harvest — the
    window a concurrent confiscation/cancellation leaves behind."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._vanish_once = True

    def step(self):
        rid = super().step()
        if rid is not None and self._vanish_once:
            self._vanish_once = False
            with self.lock:
                self.results.pop(rid, None)
        return rid


def test_worker_survives_missing_result_id():
    """Regression: harvest used ``results.pop(i)`` — a missing id raised
    KeyError inside the worker, misclassifying the ENGINE as failed."""
    pool = _pool(1, cls=VanishingResultEngine)
    srv = _server(pool, retry=None)
    srv.submit("u", list(range(20)))         # result vanishes pre-harvest
    fut2 = srv.submit("u", list(range(20)))  # must still be served
    res2 = fut2.result(timeout=10)
    assert not isinstance(res2, Rejected)
    assert srv.metrics.total("engine_errors") == 0
    assert pool.healthy["i0"] is True
    srv.shutdown(drain=False)


# ---- engine non-finite guard (satellite S3) ----------------------------------

def test_engine_score_flags_nonfinite_logits():
    from repro.core.engine import PrefillOnlyEngine
    eng = object.__new__(PrefillOnlyEngine)  # _score only touches the guard
    eng.result_guard = NaNGuard(3)
    eng.nonfinite_results = 0
    r = Request(n_input=4, arrival=0.0, chain=(), tokens=[1, 2, 3, 4],
                allowed_tokens=(5, 9))
    r.finish_time = 1.0
    logits = np.zeros((1, 16))
    out = PrefillOnlyEngine._score(eng, logits, r)
    assert "corrupt" not in out
    assert sum(out["scores"].values()) == pytest.approx(1.0)
    logits[0, 5] = np.nan
    out = PrefillOnlyEngine._score(eng, logits, r)
    assert out["corrupt"] == "nonfinite_logits" and out["token"] == -1
    assert eng.nonfinite_results == 1
    # unconstrained argmax tolerates -inf ("never this token")...
    r2 = Request(n_input=4, arrival=0.0, chain=(), tokens=[1, 2, 3, 4])
    r2.finish_time = 1.0
    logits2 = np.zeros((1, 16))
    logits2[0, 3] = -np.inf
    assert "corrupt" not in PrefillOnlyEngine._score(eng, logits2, r2)
    # ...but not NaN, and not an all-non-finite row
    logits2[0, 7] = np.nan
    assert PrefillOnlyEngine._score(eng, logits2, r2)["corrupt"] \
        == "nonfinite_logits"
    assert PrefillOnlyEngine._score(
        eng, np.full((1, 16), -np.inf), r2)["corrupt"] == "nonfinite_logits"
    assert eng.nonfinite_results == 3
    # the clean -inf result in between reset the guard's consecutive count
    # (NaNGuard policy: only CONSECUTIVE corruption escalates to reload)
    assert eng.result_guard.consecutive == 2
    assert eng.result_guard.total_skipped == 3


# ---- brownout ----------------------------------------------------------------

def test_brownout_ladder_escalation_and_hysteresis():
    b = BrownoutController(enter=(2, 6, 12), exit=(1, 3, 6), hold=2)
    assert b.evaluate(0.5) == 0
    assert b.evaluate(13.0) == 3             # escalation is immediate
    assert b.escalations == 1
    assert b.evaluate(7.0) == 3              # below enter[2] but above exit[2]
    assert b.evaluate(4.0) == 3              # calm 1 of 2
    assert b.evaluate(4.0) == 2              # calm 2 -> step down ONE level
    assert b.evaluate(2.5) == 2              # calm 1 (exit[1]=3)
    assert b.evaluate(3.5) == 2              # interrupted: calm resets
    assert b.evaluate(2.5) == 2
    assert b.evaluate(2.5) == 1
    assert b.pressure() == pytest.approx(b.slack_factor)
    assert b.state() == "tighten"
    # shed-rate maps onto the backlog axis
    assert b.signal(0.0, 0.5) == pytest.approx(0.5 * b.shed_to_seconds)


def test_brownout_levels_apply_to_server():
    pool = _pool(2, sec_per_token=0.004)     # 100-token requests ~0.4s
    b = BrownoutController(enter=(0.2, 0.5, 1.0), exit=(0.05, 0.1, 0.2),
                           hold=2, slack_factor=1.5)
    ctrl = AdmissionController(adapt=False)
    srv = _server(pool, brownout=b, admission=ctrl)
    futs = [srv.submit(f"u{i}", list(range(100))) for i in range(12)]
    deadline = time.monotonic() + 5
    while b.level < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert b.level == 3, "backlog never escalated the ladder"
    late = srv.submit("u-late", list(range(100)))
    rej = late.result(timeout=2)
    assert isinstance(rej, Rejected) and rej.reason == "brownout"
    assert ctrl.pressure == pytest.approx(1.5)
    assert any(pool.engines[n].degraded for n in pool.live_names())
    assert srv.metrics.gauge("brownout_level").value == 3
    assert srv.metrics.state_gauge(
        "brownout_state", BrownoutController.LEVELS).state == "shed"
    assert srv.drain(timeout=30)
    deadline = time.monotonic() + 10         # backlog gone: ladder descends
    while b.level > 0 and time.monotonic() < deadline:
        time.sleep(0.02)
    assert b.level == 0
    assert ctrl.pressure == pytest.approx(1.0)
    assert not any(pool.engines[n].degraded for n in pool.live_names())
    assert all(f.done() for f in futs)
    srv.shutdown(drain=True, timeout=5)


# ---- the acceptance soak -----------------------------------------------------

def _soak_round(seed):
    """One seeded chaos trial: 40 requests through a 3-instance pool under
    a mixed fault schedule, with a healer resurrecting failed instances.
    Returns (plan, futures, resolution counts, drained)."""
    if seed == 0:
        # fully scheduled round: all five fault kinds fire deterministically
        cfg = ChaosConfig(seed=0, hang_seconds=0.4, straggler_seconds=0.04,
                          schedule=[("i0", 0, "submit_error"),
                                    ("i0", 1, "step_error"),
                                    ("i1", 0, "nan_score"),
                                    ("i2", 0, "straggler"),
                                    ("i1", 1, "hang")])
    else:
        cfg = ChaosConfig(seed=seed, step_error=0.03, hang=0.02,
                          hang_seconds=0.4, straggler=0.03,
                          straggler_seconds=0.04, nan_score=0.04,
                          submit_error=0.06, max_faults=8)
    plan = FaultPlan(cfg)
    pool = _pool(3, plan, sec_per_token=2e-4)
    wd = JCTDeadlineWatchdog(factor=4.0, min_deadline=0.15, interval=0.02)
    srv = AsyncServer(pool, retry=RetryPolicy(budget=3, backoff=0.002),
                      watchdog=wd).start()

    stop = threading.Event()

    def healer():
        names = ["i0", "i1", "i2"]
        while not stop.is_set():
            if any(not pool.healthy.get(n, False) for n in names):
                alive = [n for n in names if pool.healthy.get(n)]
                srv.scale_to(alive)
                srv.scale_to(names)
            stop.wait(0.05)

    hl = threading.Thread(target=healer)
    hl.start()
    futs = []
    rng = np.random.default_rng(seed)
    for j in range(40):
        futs.append(srv.submit(f"u{int(rng.integers(8))}",
                               list(range(30 + int(rng.integers(30)))),
                               allowed_tokens=(5, 9)))
        time.sleep(0.002)
    counts = _count_resolutions(futs)
    drained = srv.drain(timeout=30)
    stop.set()
    hl.join()
    srv.shutdown(drain=True, timeout=5)
    return plan, futs, counts, drained


def test_chaos_soak_exactly_once_and_mostly_served():
    """ISSUE 6 acceptance: >= 5 fault kinds across seeded trials, 200+
    futures, every one resolves exactly once, none hangs past the watchdog
    deadline (bounded drain), and >= 90% resolve SERVED."""
    all_kinds = set()
    total, served_total = 0, 0
    for seed in range(6):
        plan, futs, counts, drained = _soak_round(seed)
        assert drained, f"seed {seed}: futures hung past the drain bound"
        assert all(f.done() for f in futs), f"seed {seed}: unresolved future"
        assert set(counts.values()) == {1}, \
            f"seed {seed}: exactly-once violated: {counts}"
        outcomes = [f.result() for f in futs]
        for o in outcomes:
            if isinstance(o, Rejected):
                # the only legitimate terminal rejections under chaos
                assert o.reason in ("error", "no_instances"), o
            else:
                assert all(np.isfinite(v)
                           for v in o.get("scores", {}).values()), \
                    f"seed {seed}: NaN delivered"
        total += len(outcomes)
        served_total += sum(1 for o in outcomes
                            if not isinstance(o, Rejected))
        all_kinds |= set(plan.counts())
    assert total >= 200
    assert len(all_kinds) >= 5, all_kinds
    assert served_total >= 0.9 * total, (served_total, total)
