import os
import pathlib

# Smoke tests must see the single real CPU device (the dry-run sets its own
# 512-device flag in a separate process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # hypothesis is a dev extra (requirements-dev.txt). Without it, skip the
    # property-test modules instead of dying at collection time — tier-1 must
    # still run every non-hypothesis test. Match actual import statements,
    # not a bare substring (a docstring mentioning hypothesis must not
    # silently drop a module from collection).
    import re
    _IMPORT = re.compile(r"^\s*(from|import)\s+hypothesis\b", re.MULTILINE)
    collect_ignore = sorted(
        p.name for p in pathlib.Path(__file__).parent.glob("test_*.py")
        if _IMPORT.search(p.read_text()))
else:
    settings.register_profile("ci", deadline=None, max_examples=25,
                              derandomize=True)
    settings.load_profile("ci")
