import os

# Smoke tests must see the single real CPU device (the dry-run sets its own
# 512-device flag in a separate process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", deadline=None, max_examples=25,
                          derandomize=True)
settings.load_profile("ci")
