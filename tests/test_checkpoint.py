"""Checkpoint store: roundtrip, atomicity, corruption detection, retention,
async saver, resume-from-restore."""
import json
import shutil
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 7, tree)
    step, restored = restore_checkpoint(tmp_path, tree)
    assert step == 7
    np.testing.assert_array_equal(restored["params"]["w"],
                                  tree["params"]["w"])
    assert int(restored["step"]) == 7


def test_latest_and_retention(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, tree, keep_last=2)
    assert latest_step(tmp_path) == 5
    kept = sorted(d.name for d in Path(tmp_path).glob("step_*"))
    assert len(kept) == 2


def test_uncommitted_directories_are_ignored(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 1, tree)
    # simulate a crash mid-save at step 2: directory without sentinel
    crash = Path(tmp_path) / "step_00000002"
    crash.mkdir()
    (crash / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 1


def test_corruption_detected(tmp_path):
    tree = _tree()
    d = save_checkpoint(tmp_path, 3, tree)
    # flip bytes in one shard
    target = next(d.glob("arr_*.npy"))
    raw = bytearray(target.read_bytes())
    raw[-1] ^= 0xFF
    target.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="CRC"):
        restore_checkpoint(tmp_path, tree)


def test_tree_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 1, _tree())
    with pytest.raises(ValueError, match="leaves"):
        restore_checkpoint(tmp_path, {"only": jnp.zeros(3)})


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    tree = _tree()
    ck.save(10, tree)
    ck.wait()
    assert latest_step(tmp_path) == 10
    _, restored = restore_checkpoint(tmp_path, tree)
    np.testing.assert_array_equal(restored["params"]["b"],
                                  tree["params"]["b"])


def test_elastic_restore_with_shardings(tmp_path):
    """Restore re-places arrays under new shardings (single-device here,
    but exercises the device_put path the 512-chip restore uses)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    tree = _tree()
    save_checkpoint(tmp_path, 1, tree)
    mesh = make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P())
    shardings = {"params": {"w": sh, "b": sh}, "step": sh}
    _, restored = restore_checkpoint(tmp_path, tree, shardings=shardings)
    assert restored["params"]["w"].sharding == sh
