"""End-to-end engine tests: real forwards, prefix reuse exactness, suffix
discard budgets, constrained output scoring, profile run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.models.model import build
from repro.runtime.sharding import materialize


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen1.5-0.5b"), hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    return cfg, params


def test_cache_hit_scores_match_fresh(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    profile = rng.integers(0, cfg.vocab_size, 80).tolist()
    post = rng.integers(0, cfg.vocab_size, 20).tolist()

    warm = PrefillOnlyEngine(cfg, params,
                             EngineConfig(cache_capacity_tokens=2048))
    warm.submit(profile + rng.integers(0, cfg.vocab_size, 20).tolist(),
                allowed_tokens=(5, 9))
    warm.submit(profile + post, allowed_tokens=(5, 9))
    ids = warm.run_until_drained()
    hit_res = warm.results[ids[1]]
    assert hit_res["n_cached"] > 0

    cold = PrefillOnlyEngine(cfg, params,
                             EngineConfig(cache_capacity_tokens=0))
    j = cold.submit(profile + post, allowed_tokens=(5, 9))
    cold.run_until_drained()
    ref = cold.results[j]["scores"]
    got = hit_res["scores"]
    for t in ref:
        assert abs(ref[t] - got[t]) < 2e-2


def test_scores_are_normalized_probabilities(setup):
    cfg, params = setup
    eng = PrefillOnlyEngine(cfg, params, EngineConfig())
    rng = np.random.default_rng(1)
    i = eng.submit(rng.integers(0, cfg.vocab_size, 40).tolist(),
                   allowed_tokens=(3, 7, 11))
    eng.run_until_drained()
    scores = eng.results[i]["scores"]
    assert len(scores) == 3
    assert abs(sum(scores.values()) - 1.0) < 1e-6
    assert all(0 <= v <= 1 for v in scores.values())


def test_suffix_discard_budget_bounds_cache(setup):
    cfg, params = setup
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(
        cache_capacity_tokens=1024, kv_keep_tokens=32))
    rng = np.random.default_rng(2)
    eng.submit(rng.integers(0, cfg.vocab_size, 100).tolist())
    eng.run_until_drained()
    # only 32 tokens (2 blocks) of prefix KV may be resident
    assert eng.cache.used_blocks <= 32 // eng.ecfg.block_size


def test_scheduling_order_prioritizes_cache_hits(setup):
    cfg, params = setup
    eng = PrefillOnlyEngine(cfg, params,
                            EngineConfig(cache_capacity_tokens=4096, lam=0.0))
    eng.jct_model.a, eng.jct_model.b = 1.0, 0.0   # deterministic JCT
    rng = np.random.default_rng(3)
    profile = rng.integers(0, cfg.vocab_size, 64).tolist()
    first = eng.submit(profile + [1] * 8)
    eng.step()                                    # primes the cache
    # submit: an unrelated short request and a longer profile-sharing one
    short = eng.submit(rng.integers(0, cfg.vocab_size, 40).tolist())
    shared = eng.submit(profile + [2] * 16)       # 80 tokens, 64 cached
    done = eng.run_until_drained()
    assert done[0] == shared                      # miss 16 < 40


def test_profile_run_fits_linear_model(setup):
    cfg, params = setup
    eng = PrefillOnlyEngine(cfg, params, EngineConfig())
    r = eng.profile((32, 64, 128))
    assert eng.jct_model.a > 0
    assert np.isfinite(r)
