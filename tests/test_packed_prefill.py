"""Prepacked prefill: segment-restricted attention equivalence across every
layer of the stack (kernel -> model oracle -> transformer -> engine), the
cross-segment tile-skip guarantee, and the padding-path regressions that
prepacking relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine, _bucket
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as raw_flash
from repro.models import transformer as tfm
from repro.models.layers import blocked_attention
from repro.models.model import build
from repro.runtime.sharding import materialize


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


def _segments(lens, S, B=1):
    """Per-token segment ids for requests of ``lens`` packed into S slots."""
    seg = np.full((B, S), -1, np.int32)
    off = 0
    for n, L in enumerate(lens):
        seg[:, off:off + L] = n
        off += L
    return jnp.asarray(seg)


# --------------------------------------------------------------------------
# kernel layer
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("lens,H,KV,d,window,softcap", [
    ((40, 30, 26), 4, 4, 16, 0, 0.0),       # MHA
    ((40, 30, 26), 4, 2, 16, 0, 0.0),       # GQA
    ((25, 45, 20), 4, 2, 16, 13, 0.0),      # GQA + SWA
    ((33, 33, 30), 8, 2, 32, 0, 50.0),      # softcap (gemma2)
    ((7, 80, 9), 2, 1, 8, 5, 30.0),         # everything, skewed lengths
])
def test_packed_kernel_matches_ref(lens, H, KV, d, window, softcap, dtype):
    S = sum(lens)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, S, H, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (2, S, KV, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (2, S, KV, d), jnp.float32).astype(dtype)
    seg = jnp.broadcast_to(_segments(lens, S), (2, S))
    got = ops.packed_flash_attention(q, k, v, seg, window=window,
                                     softcap=softcap, block_q=32, block_k=32)
    want = ref.packed_flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), seg, window=window, softcap=softcap
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_packed_kernel_segments_match_independent_causal():
    """Each packed segment's rows equal a standalone causal call over it."""
    lens = (40, 30, 26)
    S = sum(lens)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, S, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, 2, 16), jnp.float32)
    got = ops.packed_flash_attention(q, k, v, _segments(lens, S),
                                     block_q=32, block_k=32)
    off = 0
    for L in lens:
        solo = ops.flash_attention(q[:, off:off + L], k[:, off:off + L],
                                   v[:, off:off + L], block_q=32, block_k=32)
        np.testing.assert_allclose(np.asarray(got[:, off:off + L]),
                                   np.asarray(solo), atol=2e-4, rtol=2e-4)
        off += L


def test_cross_segment_tiles_are_skipped():
    """The tile map proves segment-disjoint (q-block, kv-block) tiles never
    execute — the 0-FLOP structural skip, not just element masking."""
    lens = (40, 30, 26)          # boundaries at 40 and 70; 32-wide tiles
    S = sum(lens)
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 4, S, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, S, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, S, 16), jnp.float32)
    seg = _segments(lens, S)
    _, tmap = raw_flash(q, k, v, causal=True, seg_q=seg, seg_k=seg,
                        block_q=32, block_k=32, debug_tile_map=True)
    tmap = np.asarray(tmap[0])
    seg_np = np.asarray(seg[0])
    nq = nk = S // 32
    for i in range(nq):
        for j in range(nk):
            qs = seg_np[i * 32:(i + 1) * 32]
            kss = seg_np[j * 32:(j + 1) * 32]
            causal_live = j * 32 <= i * 32 + 31
            overlap = (qs.min() <= kss.max()) and (qs.max() >= kss.min())
            assert tmap[i, j] == int(causal_live and overlap), (i, j, tmap)
    # the packing must actually skip something beyond the causal triangle:
    # q-block 2 (segments 1/2) x kv-block 0 (segment 0) is causally live
    assert tmap[2, 0] == 0


def test_noncausal_padded_kv_masked():
    """Regression: causal=False with a ragged Sk must not attend to the
    zero-padding the wrapper adds to reach a block multiple."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 70, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 70, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 70, 2, 16), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=False).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


# --------------------------------------------------------------------------
# model oracle layer
# --------------------------------------------------------------------------

@pytest.mark.parametrize("window,softcap", [(0, 0.0), (13, 0.0), (0, 50.0)])
def test_blocked_attention_segments_match_independent(window, softcap):
    lens = (40, 30, 26)
    S = sum(lens)
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (1, S, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, 2, 16), jnp.float32)
    got = blocked_attention(q, k, v, window=window, softcap=softcap,
                            seg_ids=_segments(lens, S), q_block=32,
                            kv_block=32)
    off = 0
    for L in lens:
        solo = blocked_attention(q[:, off:off + L], k[:, off:off + L],
                                 v[:, off:off + L], window=window,
                                 softcap=softcap, q_block=32, kv_block=32)
        np.testing.assert_allclose(np.asarray(got[:, off:off + L]),
                                   np.asarray(solo), atol=2e-4, rtol=2e-4)
        off += L


# --------------------------------------------------------------------------
# transformer layer: prefill_packed == N independent prefills
# --------------------------------------------------------------------------

def _pack(reqs, S):
    toks = np.zeros((1, S), np.int32)
    segs = np.full((1, S), -1, np.int32)
    pos = np.zeros((1, S), np.int32)
    last = np.zeros((len(reqs),), np.int32)
    off = 0
    for n, t in enumerate(reqs):
        L = len(t)
        toks[0, off:off + L] = t
        segs[0, off:off + L] = n
        pos[0, off:off + L] = np.arange(L)
        last[n] = off + L - 1
        off += L
    return (jnp.asarray(toks), jnp.asarray(segs), jnp.asarray(pos),
            jnp.asarray(last))


@pytest.mark.parametrize("arch,dtype", [
    ("qwen1.5-0.5b", "float32"),         # GQA
    ("qwen1.5-0.5b", "bfloat16"),
    ("gemma2-9b", "float32"),            # local/global SWA + both softcaps
])
def test_prefill_packed_matches_independent(arch, dtype):
    cfg = reduce_config(get_config(arch), hybrid_chunk=0, dtype=dtype,
                        param_dtype=dtype)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    rng = np.random.default_rng(0)
    lens = (37, 61, 12, 50)
    reqs = [rng.integers(0, cfg.vocab_size, n).tolist() for n in lens]
    S = 192                                  # packed bucket incl. slack
    toks, segs, pos, last = _pack(reqs, S)
    logits, kv = tfm.prefill_packed(params, cfg, toks, segs, pos, last,
                                    kv_keep=S)
    assert logits.shape == (len(reqs), cfg.vocab_size)
    off = 0
    for n, t in enumerate(reqs):
        want, solo_kv = tfm.prefill(params, cfg,
                                    {"tokens": jnp.asarray([t], jnp.int32)},
                                    kv_keep=len(t))
        got = np.asarray(logits[n], np.float32)
        ref_l = np.asarray(want[0], np.float32)
        if dtype == "bfloat16":
            # bf16 forward: compare constrained-output probabilities (what
            # the engine consumes) rather than raw logit ULPs
            ga = np.exp(got - got.max()); ga /= ga.sum()
            ra = np.exp(ref_l - ref_l.max()); ra /= ra.sum()
            np.testing.assert_allclose(ga, ra, atol=2e-2)
        else:
            np.testing.assert_allclose(got, ref_l, atol=2e-3, rtol=2e-3)
            # packed KV slices == solo KV (what the prefix cache stores)
            for key in solo_kv:
                np.testing.assert_allclose(
                    np.asarray(kv[key][:, :, off:off + len(t)], np.float32),
                    np.asarray(solo_kv[key], np.float32),
                    atol=2e-3, rtol=2e-3)
        off += len(t)


# --------------------------------------------------------------------------
# engine layer
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen1.5-0.5b"), hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    return cfg, params


def test_bucket_grows_geometrically_past_table():
    assert _bucket(50, (64, 128)) == 64
    assert _bucket(128, (64, 128)) == 128
    assert _bucket(129, (64, 128)) == 256
    assert _bucket(3000, (64, 128)) == 4096


def test_engine_handles_request_longer_than_largest_bucket(setup):
    cfg, params = setup
    eng = PrefillOnlyEngine(cfg, params,
                            EngineConfig(suffix_buckets=(64, 128)))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, 300).tolist()
    i = eng.submit(toks, allowed_tokens=(5, 9))
    eng.run_until_drained()
    assert i in eng.results
    assert abs(sum(eng.results[i]["scores"].values()) - 1.0) < 1e-6


def test_packed_engine_matches_solo_engine(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = [rng.integers(0, cfg.vocab_size, n).tolist()
            for n in (37, 61, 12, 50, 29)]
    packed = PrefillOnlyEngine(cfg, params,
                               EngineConfig(pack_token_budget=256))
    ids = [packed.submit(t, allowed_tokens=(5, 9)) for t in reqs]
    done = packed.run_until_drained()
    assert sorted(done) == sorted(ids)      # one id per served request
    assert packed.packed_steps >= 1
    assert packed.packed_requests == len(reqs)
    solo = PrefillOnlyEngine(cfg, params,
                             EngineConfig(max_pack_requests=1,
                                          cache_capacity_tokens=0))
    ids2 = [solo.submit(t, allowed_tokens=(5, 9)) for t in reqs]
    solo.run_until_drained()
    for i, j in zip(ids, ids2):
        a, b = packed.results[i]["scores"], solo.results[j]["scores"]
        for t in a:
            assert abs(a[t] - b[t]) < 2e-2


def test_packed_kv_insert_serves_later_cache_hits(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    a = rng.integers(0, cfg.vocab_size, 80).tolist()
    b = rng.integers(0, cfg.vocab_size, 90).tolist()
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(pack_token_budget=512))
    eng.submit(a, allowed_tokens=(5, 9))
    eng.submit(b)
    eng.run_until_drained()
    assert eng.packed_steps == 1
    shared = a + rng.integers(0, cfg.vocab_size, 20).tolist()
    k = eng.submit(shared, allowed_tokens=(5, 9))
    eng.run_until_drained()
    assert eng.results[k]["n_cached"] == 64     # packed KV was inserted
    cold = PrefillOnlyEngine(cfg, params,
                             EngineConfig(cache_capacity_tokens=0,
                                          max_pack_requests=1))
    j = cold.submit(shared, allowed_tokens=(5, 9))
    cold.run_until_drained()
    for t in cold.results[j]["scores"]:
        assert abs(cold.results[j]["scores"][t]
                   - eng.results[k]["scores"][t]) < 2e-2


def test_batch_formation_respects_budget_and_anchor(setup):
    cfg, params = setup
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(
        pack_token_budget=128, max_pack_requests=4, lam=0.0))
    eng.jct_model.a, eng.jct_model.b = 1.0, 0.0
    eng.jct_model.refit_every = 10**9            # freeze for determinism
    rng = np.random.default_rng(3)
    short = eng.submit(rng.integers(0, cfg.vocab_size, 30).tolist())
    long1 = eng.submit(rng.integers(0, cfg.vocab_size, 90).tolist())
    long2 = eng.submit(rng.integers(0, cfg.vocab_size, 100).tolist())
    # anchor = short (lowest JCT); backfill fits only one long request
    anchor = eng.step()
    assert anchor == short
    assert eng.packed_requests == 2              # 30 + 90 <= 128, +100 not
    assert long1 in eng.results and long2 not in eng.results
    eng.run_until_drained()
    assert long2 in eng.results


def test_packed_suffix_discard_bounds_kv(setup):
    """kv_keep_tokens bounds the packed path's cache footprint per request
    (the forward gathers only each segment's keep window), and the kept
    windows are genuine KV usable by later cache hits."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    a = rng.integers(0, cfg.vocab_size, 80).tolist()
    b = rng.integers(0, cfg.vocab_size, 90).tolist()
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(
        pack_token_budget=512, kv_keep_tokens=32, prefix_bucket_blocks=2))
    eng.submit(a)
    eng.submit(b)
    eng.run_until_drained()
    assert eng.packed_steps == 1
    assert eng.cache.used_blocks <= 2 * (32 // eng.ecfg.block_size)
    shared = a + rng.integers(0, cfg.vocab_size, 20).tolist()
    k = eng.submit(shared, allowed_tokens=(5, 9))
    eng.run_until_drained()
    assert eng.results[k]["n_cached"] == 32
    cold = PrefillOnlyEngine(cfg, params,
                             EngineConfig(cache_capacity_tokens=0,
                                          max_pack_requests=1))
    j = cold.submit(shared, allowed_tokens=(5, 9))
    cold.run_until_drained()
    for t in cold.results[j]["scores"]:
        assert abs(cold.results[j]["scores"][t]
                   - eng.results[k]["scores"][t]) < 2e-2


def test_prefix_sharers_are_not_copacked(setup):
    """Requests sharing a prefix root run sequentially (KV reuse beats the
    packing win), so the later one still hits the earlier one's cache."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    profile = rng.integers(0, cfg.vocab_size, 80).tolist()
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(pack_token_budget=512))
    a = eng.submit(profile + rng.integers(0, cfg.vocab_size, 20).tolist())
    b = eng.submit(profile + rng.integers(0, cfg.vocab_size, 20).tolist())
    eng.run_until_drained()
    assert eng.packed_steps == 0
    assert eng.results[b]["n_cached"] > 0


def test_jct_observes_packed_steps(setup):
    cfg, params = setup
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(
        pack_token_budget=256, cache_capacity_tokens=0))
    eng.jct_model.refit_every = 2
    rng = np.random.default_rng(4)
    for rep in range(2):
        for n in (20, 25, 30, 35, 40, 45):
            eng.submit(rng.integers(0, cfg.vocab_size, n).tolist())
        eng.run_until_drained()
        if rep == 0:
            # every first-pass step compiled a fresh shape: those wall times
            # are jit-compile cost, not serving cost, and must NOT calibrate
            assert len(eng.jct_model._recent) == 0
    assert len(eng.jct_model._recent) >= 1       # warm packed samples only
    assert eng.jct_model.a > 0
