"""Prefix-aware packed prefill (the packed cache-HIT path): kernel ->
oracle -> transformer -> engine equivalence against the solo suffix path,
the prefix-tile-skip guarantee, TPU lowering of the positioned kernel, and
the engine's {solo suffix, packed miss, packed hit} cost model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as raw_flash
from repro.models import transformer as tfm
from repro.models.layers import PAD_POS, blocked_attention
from repro.models.model import build
from repro.runtime.sharding import materialize


def _layout(plens, slens, B=1):
    """Packed arrays for suffixes ``slens`` over cached prefixes ``plens``:
    (seg, pos) for the fresh side, (pseg, ppos) for the prefix buffer."""
    S, P = sum(slens), sum(plens)
    seg = np.full((B, S), -1, np.int32)
    pos = np.zeros((B, S), np.int32)
    pseg = np.full((B, max(P, 1)), -1, np.int32)[:, :P]
    ppos = np.full((B, max(P, 1)), PAD_POS, np.int32)[:, :P]
    off = 0
    for n, L in enumerate(slens):
        seg[:, off:off + L] = n
        pos[:, off:off + L] = plens[n] + np.arange(L)
        off += L
    off = 0
    for n, L in enumerate(plens):
        pseg[:, off:off + L] = n
        ppos[:, off:off + L] = np.arange(L)
        off += L
    return (jnp.asarray(seg), jnp.asarray(pos), jnp.asarray(pseg),
            jnp.asarray(ppos))


# --------------------------------------------------------------------------
# kernel layer
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("plens,slens,H,KV,d,window,softcap", [
    ((32, 0, 48), (20, 30, 10), 4, 4, 16, 0, 0.0),   # MHA, one miss segment
    ((32, 16, 48), (20, 30, 10), 4, 2, 16, 0, 0.0),  # GQA, all hits
    ((48, 32), (25, 13), 4, 2, 16, 13, 0.0),         # GQA + SWA
    ((16, 64), (33, 30), 8, 2, 32, 0, 50.0),         # softcap (gemma2)
    ((0, 0, 0), (40, 30, 26), 2, 1, 8, 0, 0.0),      # degenerate: no prefix
])
def test_prefix_kernel_matches_ref(plens, slens, H, KV, d, window, softcap,
                                   dtype):
    S, P = sum(slens), sum(plens)
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (2, S, H, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (2, S, KV, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (2, S, KV, d), jnp.float32).astype(dtype)
    pk = jax.random.normal(ks[3], (2, max(P, 1), KV, d),
                           jnp.float32).astype(dtype)[:, :P]
    pv = jax.random.normal(ks[4], (2, max(P, 1), KV, d),
                           jnp.float32).astype(dtype)[:, :P]
    seg, pos, pseg, ppos = _layout(plens, slens, B=1)
    seg, pos = (jnp.broadcast_to(a, (2, S)) for a in (seg, pos))
    pseg, ppos = (jnp.broadcast_to(a, (2, P)) for a in (pseg, ppos))
    got = ops.packed_flash_attention(
        q, k, v, seg, window=window, softcap=softcap, prefix_k=pk,
        prefix_v=pv, prefix_seg=pseg, positions=pos, prefix_positions=ppos,
        block_q=32, block_k=32)
    want = ref.packed_prefix_attention_ref(
        q.transpose(0, 2, 1, 3),
        jnp.concatenate([pk, k], axis=1).transpose(0, 2, 1, 3),
        jnp.concatenate([pv, v], axis=1).transpose(0, 2, 1, 3),
        seg, jnp.concatenate([pseg, seg], axis=1),
        pos, jnp.concatenate([ppos, pos], axis=1),
        window=window, softcap=softcap).transpose(0, 2, 1, 3)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_prefix_kernel_segments_match_independent_prefix_attention():
    """Each packed segment's rows equal a standalone call over
    concat(its own prefix, its own suffix) — the hit-path ground truth."""
    plens, slens = (32, 48, 0), (20, 12, 30)
    S = sum(slens)
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (1, S, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, 2, 16), jnp.float32)
    pk = jax.random.normal(ks[3], (1, sum(plens), 2, 16), jnp.float32)
    pv = jax.random.normal(ks[4], (1, sum(plens), 2, 16), jnp.float32)
    seg, pos, pseg, ppos = _layout(plens, slens)
    got = ops.packed_flash_attention(
        q, k, v, seg, prefix_k=pk, prefix_v=pv, prefix_seg=pseg,
        positions=pos, prefix_positions=ppos, block_q=32, block_k=32)
    off = 0
    for n, L in enumerate(slens):
        poff = sum(plens[:n])
        pl_ = plens[n]
        ksolo = jnp.concatenate([pk[:, poff:poff + pl_], k[:, off:off + L]],
                                axis=1)
        vsolo = jnp.concatenate([pv[:, poff:poff + pl_], v[:, off:off + L]],
                                axis=1)
        solo = blocked_attention(q[:, off:off + L], ksolo, vsolo,
                                 q_offset=pl_, q_block=32, kv_block=32)
        np.testing.assert_allclose(np.asarray(got[:, off:off + L]),
                                   np.asarray(solo), atol=2e-4, rtol=2e-4)
        off += L


def test_prefix_tiles_of_other_segments_are_skipped():
    """The tile map proves a query block never executes another segment's
    prefix tiles — 0-FLOP structural skip over the gathered prefix buffer,
    not just element masking."""
    plens, slens = (64, 64), (32, 32)
    S, P = sum(slens), sum(plens)
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = jax.random.normal(ks[0], (1, 4, S, 16), jnp.float32)
    kf = jax.random.normal(ks[1], (1, 2, P + S, 16), jnp.float32)
    vf = jax.random.normal(ks[2], (1, 2, P + S, 16), jnp.float32)
    seg, pos, pseg, ppos = _layout(plens, slens)
    seg_k = jnp.concatenate([pseg, seg], axis=1)
    pos_k = jnp.concatenate([ppos, pos], axis=1)
    _, tmap = raw_flash(q, kf, vf, causal=True, seg_q=seg, seg_k=seg_k,
                        pos_q=pos, pos_k=pos_k, block_q=32, block_k=32,
                        debug_tile_map=True)
    tmap = np.asarray(tmap[0])
    seg_q_np, seg_k_np = np.asarray(seg[0]), np.asarray(seg_k[0])
    pos_q_np, pos_k_np = np.asarray(pos[0]), np.asarray(pos_k[0])
    for i in range(tmap.shape[0]):
        for j in range(tmap.shape[1]):
            qs = seg_q_np[i * 32:(i + 1) * 32]
            kss = seg_k_np[j * 32:(j + 1) * 32]
            causal_live = (pos_k_np[j * 32:(j + 1) * 32].min()
                           <= pos_q_np[i * 32:(i + 1) * 32].max())
            overlap = (qs.min() <= kss.max()) and (qs.max() >= kss.min())
            assert tmap[i, j] == int(causal_live and overlap), (i, j, tmap)
    # segment 0's q-block (0) must skip segment 1's prefix tiles (2, 3) and
    # segment 1's q-block (1) must skip segment 0's prefix tiles (0, 1)
    assert tmap[0, 2] == 0 and tmap[0, 3] == 0
    assert tmap[1, 0] == 0 and tmap[1, 1] == 0
    # ...while each hits its OWN prefix tiles
    assert tmap[0, 0] == 1 and tmap[0, 1] == 1
    assert tmap[1, 2] == 1 and tmap[1, 3] == 1


def test_positioned_kernel_lowers_for_tpu():
    """The positioned (prefix-aware) and segmented kernels both lower to a
    Mosaic TPU custom call — the f32 tile-skip reductions keep Mosaic's
    no-integer-reductions constraint satisfied. (Execution on real TPU
    remains a ROADMAP item; lowering structure is validated here.)"""
    q = jnp.zeros((1, 2, 256, 128), jnp.float32)
    k = v = jnp.zeros((1, 1, 256, 128), jnp.float32)
    seg = jnp.zeros((1, 256), jnp.int32)
    pos = jnp.zeros((1, 256), jnp.int32)

    def positioned(q, k, v):
        return raw_flash(q, k, v, seg_q=seg, seg_k=seg, pos_q=pos,
                         pos_k=pos, block_q=128, block_k=128,
                         interpret=False)

    def segmented(q, k, v):
        return raw_flash(q, k, v, seg_q=seg, seg_k=seg, block_q=128,
                         block_k=128, interpret=False)

    for fn in (positioned, segmented):
        txt = jax.jit(fn).trace(q, k, v).lower(
            lowering_platforms=("tpu",)).as_text()
        assert "tpu_custom_call" in txt


# --------------------------------------------------------------------------
# model oracle layer
# --------------------------------------------------------------------------

@pytest.mark.parametrize("window,softcap", [(0, 0.0), (13, 0.0), (0, 50.0)])
def test_blocked_attention_prefix_matches_ref(window, softcap):
    plens, slens = (32, 16, 0), (20, 30, 10)
    S, P = sum(slens), sum(plens)
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (1, S, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, 2, 16), jnp.float32)
    pk = jax.random.normal(ks[3], (1, P, 2, 16), jnp.float32)
    pv = jax.random.normal(ks[4], (1, P, 2, 16), jnp.float32)
    seg, pos, pseg, ppos = _layout(plens, slens)
    k_full = jnp.concatenate([pk, k], axis=1)
    v_full = jnp.concatenate([pv, v], axis=1)
    seg_k = jnp.concatenate([pseg, seg], axis=1)
    pos_k = jnp.concatenate([ppos, pos], axis=1)
    got = blocked_attention(q, k_full, v_full, window=window,
                            softcap=softcap, seg_ids=seg, seg_ids_k=seg_k,
                            pos_q=pos, pos_k=pos_k, q_block=32, kv_block=32)
    want = ref.packed_prefix_attention_ref(
        q.transpose(0, 2, 1, 3), k_full.transpose(0, 2, 1, 3),
        v_full.transpose(0, 2, 1, 3), seg, seg_k, pos, pos_k,
        window=window, softcap=softcap).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


# --------------------------------------------------------------------------
# transformer layer: prefill_packed_with_prefix == N x prefill_with_prefix
# --------------------------------------------------------------------------

def _softcap_cfg(cfg):
    """Dense config with both softcaps on — exercises the capped-logit path
    without the local/global stack (which the suffix path doesn't cover)."""
    return dataclasses.replace(cfg, attn_softcap=30.0, final_softcap=10.0,
                               name=cfg.name + "-softcap")


def _batched_layout(plens, slens, pmax, smax):
    """Engine-style batched-hit arrays: (prefix_pos, seg_qidx, inv_idx,
    packed positions) for suffixes ``slens`` over prefixes ``plens``."""
    from repro.models.layers import PAD_POS as _PP
    N, S = len(slens), sum(slens)
    pos = np.zeros((1, S), np.int32)
    ppos = np.full((N, pmax), _PP, np.int32)
    seg_qidx = np.full((N, smax), -1, np.int32)
    inv_idx = np.zeros((S,), np.int32)
    off = 0
    for n, (p, s) in enumerate(zip(plens, slens)):
        pos[0, off:off + s] = p + np.arange(s)
        ppos[n, :p] = np.arange(p)
        seg_qidx[n, :s] = off + np.arange(s)
        inv_idx[off:off + s] = n * smax + np.arange(s)
        off += s
    return (jnp.asarray(pos), jnp.asarray(ppos), jnp.asarray(seg_qidx),
            jnp.asarray(inv_idx))


@pytest.mark.parametrize("variant", ["dense", "softcap"])
def test_prefill_packed_with_prefix_matches_solo_suffix(variant):
    cfg = reduce_config(get_config("qwen1.5-0.5b"), hybrid_chunk=0,
                        dtype="float32", param_dtype="float32")
    if variant == "softcap":
        cfg = _softcap_cfg(cfg)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    rng = np.random.default_rng(0)
    plens, slens = (32, 0, 48), (21, 30, 9)
    pmax, smax = 64, 32        # padded rows, engine-style
    reqs = [rng.integers(0, cfg.vocab_size, p + s).tolist()
            for p, s in zip(plens, slens)]
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    prefix_kvs = []
    for t, p in zip(reqs, plens):
        if p:
            _, kv = tfm.prefill(
                params, cfg, {"tokens": jnp.asarray([t[:p]], jnp.int32)},
                kv_keep=p)
        else:
            kv = {"k": jnp.zeros((cfg.num_layers, 1, 0, KV, hd)),
                  "v": jnp.zeros((cfg.num_layers, 1, 0, KV, hd))}
        prefix_kvs.append(kv)
    S = sum(slens)
    pos, ppos, seg_qidx, inv_idx = _batched_layout(plens, slens, pmax, smax)
    toks = np.zeros((1, S), np.int32)
    last = np.zeros((len(reqs),), np.int32)
    off = 0
    for n, (t, p, s) in enumerate(zip(reqs, plens, slens)):
        toks[0, off:off + s] = t[p:]
        last[n] = off + s - 1
        off += s
    pk = jnp.concatenate(
        [jnp.pad(kv["k"], ((0, 0), (0, 0), (0, pmax - p), (0, 0), (0, 0)))
         for kv, p in zip(prefix_kvs, plens)], axis=1)
    pv = jnp.concatenate(
        [jnp.pad(kv["v"], ((0, 0), (0, 0), (0, pmax - p), (0, 0), (0, 0)))
         for kv, p in zip(prefix_kvs, plens)], axis=1)
    logits, kv = tfm.prefill_packed_with_prefix(
        params, cfg, jnp.asarray(toks), pos, jnp.asarray(last),
        {"k": pk, "v": pv}, ppos, seg_qidx, inv_idx,
        kv_indices=jnp.arange(S, dtype=jnp.int32))
    assert logits.shape == (len(reqs), cfg.vocab_size)
    off = 0
    for n, (t, p, s) in enumerate(zip(reqs, plens, slens)):
        if p:
            want, solo_kv = tfm.prefill_with_prefix(
                params, cfg, {"tokens": jnp.asarray([t[p:]], jnp.int32)},
                prefix_kvs[n], p, kv_keep=p + s)
        else:
            want, solo_kv = tfm.prefill(
                params, cfg, {"tokens": jnp.asarray([t], jnp.int32)},
                kv_keep=s)
        np.testing.assert_allclose(np.asarray(logits[n], np.float32),
                                   np.asarray(want[0], np.float32),
                                   atol=2e-3, rtol=2e-3)
        # packed fresh-KV slices == the solo suffix KV the cache stores
        for key in solo_kv:
            np.testing.assert_allclose(
                np.asarray(kv[key][:, :, off:off + s], np.float32),
                np.asarray(solo_kv[key], np.float32), atol=2e-3, rtol=2e-3)
        off += s


# --------------------------------------------------------------------------
# engine layer
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen1.5-0.5b"), hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    return cfg, params


def test_cached_sharers_copack_and_match_solo(setup):
    """Prefix sharers whose shared prefix is ALREADY cached co-pack into one
    packed-hit step and score identically to cold solo runs."""
    cfg, params = setup
    rng = np.random.default_rng(10)
    profile = rng.integers(0, cfg.vocab_size, 80).tolist()
    sufs = [rng.integers(0, cfg.vocab_size, 20).tolist() for _ in range(3)]
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(pack_token_budget=512))
    eng.submit(profile + sufs[0], allowed_tokens=(5, 9))
    eng.run_until_drained()          # warm: inserts the shared profile KV
    assert eng.packed_steps == 0
    ids = [eng.submit(profile + s, allowed_tokens=(5, 9)) for s in sufs]
    eng.run_until_drained()
    assert eng.packed_steps == 1                 # one packed-hit step
    assert eng.packed_hit_requests == 3
    for i in ids:
        assert eng.results[i]["n_cached"] == 64  # all rode the cached prefix
    cold = PrefillOnlyEngine(cfg, params,
                             EngineConfig(max_pack_requests=1,
                                          cache_capacity_tokens=0))
    ids2 = [cold.submit(profile + s, allowed_tokens=(5, 9)) for s in sufs]
    cold.run_until_drained()
    for i, j in zip(ids, ids2):
        a, b = eng.results[i]["scores"], cold.results[j]["scores"]
        for t in a:
            assert abs(a[t] - b[t]) < 2e-2


def test_uncached_sharers_still_run_sequentially(setup):
    """A miss sharing a prefix root must NOT co-pack — running sequentially
    lets the later request hit the earlier one's freshly inserted KV."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    profile = rng.integers(0, cfg.vocab_size, 80).tolist()
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(pack_token_budget=512))
    a = eng.submit(profile + rng.integers(0, cfg.vocab_size, 20).tolist())
    b = eng.submit(profile + rng.integers(0, cfg.vocab_size, 20).tolist())
    eng.run_until_drained()
    assert eng.packed_steps == 0
    assert eng.results[b]["n_cached"] > 0


def test_mixed_hit_miss_batch_matches_solo(setup):
    """One packed step carrying a cache hit AND unrelated cache misses
    produces solo-path scores for every member, and every member's KV lands
    in the cache under its own chain."""
    cfg, params = setup
    rng = np.random.default_rng(12)
    profile = rng.integers(0, cfg.vocab_size, 80).tolist()
    hit_req = profile + rng.integers(0, cfg.vocab_size, 20).tolist()
    miss1 = rng.integers(0, cfg.vocab_size, 40).tolist()
    miss2 = rng.integers(0, cfg.vocab_size, 30).tolist()
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(pack_token_budget=512))
    eng.submit(profile, allowed_tokens=(5, 9))
    eng.run_until_drained()                      # warm the shared prefix
    ids = [eng.submit(t, allowed_tokens=(5, 9))
           for t in (hit_req, miss1, miss2)]
    eng.run_until_drained()
    assert eng.packed_steps == 1
    assert eng.packed_hit_requests == 1
    assert eng.results[ids[0]]["n_cached"] == 64
    assert eng.results[ids[1]]["n_cached"] == 0
    cold = PrefillOnlyEngine(cfg, params,
                             EngineConfig(max_pack_requests=1,
                                          cache_capacity_tokens=0))
    ids2 = [cold.submit(t, allowed_tokens=(5, 9))
            for t in (hit_req, miss1, miss2)]
    cold.run_until_drained()
    for i, j in zip(ids, ids2):
        a, b = eng.results[i]["scores"], cold.results[j]["scores"]
        for t in a:
            assert abs(a[t] - b[t]) < 2e-2
    # the hit's chain extended past the prefix, and the misses inserted too
    from repro.core.prefix_cache import token_chain
    for t in (hit_req, miss1, miss2):
        chain = token_chain(t, eng.ecfg.block_size)
        assert eng.cache.match_len(chain) >= (len(t) // 16) * 16 - 16


def test_packed_hit_kv_insert_serves_later_hits(setup):
    """Suffix KV gathered out of a packed-hit forward must be genuine: a
    later request extending one co-packed sharer's tokens hits the deeper
    cache entry and still scores like a cold run."""
    cfg, params = setup
    rng = np.random.default_rng(13)
    profile = rng.integers(0, cfg.vocab_size, 64).tolist()
    sufs = [rng.integers(0, cfg.vocab_size, 32).tolist() for _ in range(2)]
    eng = PrefillOnlyEngine(cfg, params,
                            EngineConfig(pack_token_budget=512,
                                         prefix_bucket_blocks=2))
    eng.submit(profile)
    eng.run_until_drained()
    eng.submit(profile + sufs[0])
    eng.submit(profile + sufs[1])
    eng.run_until_drained()
    assert eng.packed_hit_requests == 2
    ext = profile + sufs[0] + rng.integers(0, cfg.vocab_size, 16).tolist()
    k = eng.submit(ext, allowed_tokens=(5, 9))
    eng.run_until_drained()
    assert eng.results[k]["n_cached"] > 64      # hit past the shared prefix
    cold = PrefillOnlyEngine(cfg, params,
                             EngineConfig(max_pack_requests=1,
                                          cache_capacity_tokens=0))
    j = cold.submit(ext, allowed_tokens=(5, 9))
    cold.run_until_drained()
    for t in cold.results[j]["scores"]:
        assert abs(cold.results[j]["scores"][t]
                   - eng.results[k]["scores"][t]) < 2e-2


def test_cost_model_rejects_bucket_tipping_candidate(setup):
    """A candidate that tips the packed forward into the next bucket while
    saving no step overhead must be left for a sequential run."""
    cfg, params = setup
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(
        pack_token_budget=4096, max_pack_requests=8, lam=0.0))
    eng.jct_model.a, eng.jct_model.b = 1.0, 0.0    # zero per-step overhead
    eng.jct_model.refit_every = 10**9
    rng = np.random.default_rng(14)
    r1 = eng.submit(rng.integers(0, cfg.vocab_size, 60).tolist())
    r2 = eng.submit(rng.integers(0, cfg.vocab_size, 60).tolist())
    eng.step()
    # bucket(120) = 128 = bucket(60) + bucket(60): tie admits -> packed
    assert eng.packed_requests == 2
    eng.run_until_drained()
    r3 = eng.submit(rng.integers(0, cfg.vocab_size, 60).tolist())
    r4 = eng.submit(rng.integers(0, cfg.vocab_size, 80).tolist())
    eng.step()
    # anchor 60 + cand 80 -> bucket(140) = 256 > bucket(60)+bucket(80) = 192
    # with b = 0: packing strictly loses, candidate must be rejected
    assert eng.packed_steps == 1                   # no second packed step
    assert (r3 in eng.results) != (r4 in eng.results)
    eng.run_until_drained()


def test_long_prefix_candidate_does_not_inflate_batch_pmax(setup):
    """A hit candidate whose cached prefix dwarfs the batch's computed work
    must NOT co-pack: the batched hit forward pads EVERY row's prefix
    attention to the batch max, a cost the token-linear JCT fit can't see."""
    cfg, params = setup
    rng = np.random.default_rng(17)
    small = rng.integers(0, cfg.vocab_size, 64).tolist()      # 64-tok prefix
    big = rng.integers(0, cfg.vocab_size, 640).tolist()       # 640-tok prefix
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(
        pack_token_budget=512, pack_prefix_budget=10**6,
        cache_capacity_tokens=32768))
    eng.submit(small)
    eng.submit(big)
    eng.run_until_drained()                    # warm both prefixes
    a = eng.submit(small + rng.integers(0, cfg.vocab_size, 20).tolist())
    b = eng.submit(small + rng.integers(0, cfg.vocab_size, 24).tolist())
    c = eng.submit(big + rng.integers(0, cfg.vocab_size, 20).tolist())
    eng.run_until_drained()
    # the two small-prefix hits co-pack; the 640-token-prefix hit runs
    # alone — admitting it would raise pmax to 1024 for every row, and the
    # shape model's marginal price for that padding exceeds its solo cost
    # (the priced rule that replaced the old pb > 2*pmax_b heuristic)
    assert eng.packed_steps == 1
    assert eng.packed_hit_requests == 2
    assert a in eng.results and b in eng.results and c in eng.results
    assert eng.results[c]["n_cached"] >= 576


def test_jct_observes_computed_tokens_on_hit_path(setup):
    """Packed-hit steps must calibrate on COMPUTED (suffix) tokens, not the
    total packed token count — a hit's cached prefix costs ~nothing."""
    cfg, params = setup
    rng = np.random.default_rng(15)
    profile = rng.integers(0, cfg.vocab_size, 128).tolist()
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(pack_token_budget=512))
    eng.jct_model.refit_every = 10**9              # inspect raw samples
    eng.submit(profile)
    eng.run_until_drained()
    sufs = [rng.integers(0, cfg.vocab_size, 24).tolist() for _ in range(2)]
    # rep 0 compiles the insert-path shape, rep 1 the resident-fast-path
    # shape (K=0 — nothing left to insert); rep 2 is warm and observes
    for rep in range(3):
        for s in sufs:
            eng.submit(profile + s)
        eng.run_until_drained()
    assert eng.packed_hit_requests >= 2
    assert eng.jct_model._recent, "warm packed step must observe"
    n_obs, cached_obs, _ = eng.jct_model._recent[-1]
    # 2 suffixes of (128+24) - 128 cached = 24+24 computed tokens
    assert n_obs == 48 and cached_obs == 0


def test_probes_are_hit_aware(setup):
    """predict_jct / pending_jct must predict against the bucketed USABLE
    prefix (what a forward actually reuses), not the raw token match."""
    cfg, params = setup
    from repro.core.prefix_cache import token_chain
    eng = PrefillOnlyEngine(cfg, params, EngineConfig())
    eng.jct_model.a, eng.jct_model.b = 1e-3, 0.0
    eng.jct_model.refit_every = 10**9
    rng = np.random.default_rng(16)
    toks = rng.integers(0, cfg.vocab_size, 80).tolist()
    eng.submit(toks)
    eng.run_until_drained()
    chain = token_chain(toks + [1] * 40, eng.ecfg.block_size)
    # raw match = 64 tokens (4 blocks); usable (gran 4 blocks) = 64 -> same
    assert eng.predict_jct(120, chain) == pytest.approx(1e-3 * (120 - 64))
    # raw match on the request ITSELF would consume every token; usable
    # prefix backs off so the last token's logits are still computed
    own = token_chain(toks, eng.ecfg.block_size)
    assert eng.predict_jct(80, own) == pytest.approx(
        1e-3 * (80 - 64))                          # not a * 0
    # pending_jct applies the same arithmetic to the arrival-time match
    eng.submit(toks)
    assert eng.pending_jct(now=0.0) == pytest.approx(1e-3 * (80 - 64))
    eng.queue.clear()
