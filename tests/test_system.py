"""System-level integration: the train driver (loss ↓, checkpoint-resume
continuity) and the serving driver (pool + routing + real engines)."""
import numpy as np
import pytest


def test_train_loss_decreases(tmp_path):
    from repro.launch.train import train
    losses = train("qwen1.5-0.5b", steps=8, seq_len=64, global_batch=2,
                   ckpt_dir=None, log_every=100)
    assert len(losses) == 8
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_train_checkpoint_resume(tmp_path):
    from repro.launch.train import train
    d = str(tmp_path / "ck")
    # constant schedule so the stitched run is step-for-step comparable
    first = train("qwen1.5-0.5b", steps=4, seq_len=64, global_batch=2,
                  ckpt_dir=d, ckpt_every=2, log_every=100,
                  schedule="constant")
    resumed = train("qwen1.5-0.5b", steps=6, seq_len=64, global_batch=2,
                    ckpt_dir=d, ckpt_every=2, log_every=100,
                    schedule="constant")
    # resume starts from step 4's checkpoint: only 2 fresh steps
    assert len(resumed) == 2
    # the full run from scratch matches the stitched run on the same stream
    scratch = train("qwen1.5-0.5b", steps=6, seq_len=64, global_batch=2,
                    ckpt_dir=None, log_every=100, schedule="constant")
    np.testing.assert_allclose(scratch[4:], resumed, rtol=1e-3)


def test_serve_trace_end_to_end():
    from repro.launch.serve import serve_trace
    out = serve_trace(qps=50.0, n_instances=2, scale_tokens=0.01,
                      max_requests=12)
    assert out["requests"] == 12
    assert out["throughput_rps"] > 0
    assert 0 <= out["token_hit_rate"] <= 1
    assert out["mean_latency"] > 0


def test_serve_policies_rank_by_hit_rate():
    """With the same trace/instances, calibrated SRJF should harvest at
    least as many prefix hits as FIFO (usually strictly more)."""
    from repro.launch.serve import serve_trace
    cal = serve_trace(qps=100.0, n_instances=1, scale_tokens=0.008,
                      policy="srjf_calibrated", max_requests=16)
    fifo = serve_trace(qps=100.0, n_instances=1, scale_tokens=0.008,
                       policy="fifo", max_requests=16)
    assert cal["token_hit_rate"] >= fifo["token_hit_rate"] - 1e-9
