"""Hybrid prefilling invariants (paper §4): chunking token-wise layers is
EXACT — property-tested, plus the chunked-loss / last-token-logits twins."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core.hybrid_prefill import (chunked_map, chunked_softmax_xent,
                                       last_token_logits)


@given(st.integers(1, 64), st.integers(1, 17), st.integers(1, 3))
def test_chunked_map_exact(seq, chunk, batch):
    x = jax.random.normal(jax.random.PRNGKey(seq * 100 + chunk),
                          (batch, seq, 8), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(7), (8, 5), jnp.float32)
    fn = lambda c: jnp.tanh(c @ w)
    got = chunked_map(fn, x, chunk)
    want = fn(x)
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)


@given(st.integers(1, 40), st.integers(0, 16))
def test_chunked_xent_matches_full(seq, chunk):
    key = jax.random.PRNGKey(seq * 31 + chunk)
    k1, k2, k3 = jax.random.split(key, 3)
    V, D = 23, 8
    h = jax.random.normal(k1, (2, seq, D), jnp.float32)
    w = jax.random.normal(k2, (D, V), jnp.float32)
    labels = jax.random.randint(k3, (2, seq), 0, V)
    loss_c, cnt_c = chunked_softmax_xent(h, w, labels, chunk)
    loss_f, cnt_f = chunked_softmax_xent(h, w, labels, 0)
    assert cnt_c == cnt_f == 2 * seq
    np.testing.assert_allclose(loss_c, loss_f, rtol=1e-5)


def test_chunked_xent_gradients_match():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    h = jax.random.normal(k1, (2, 32, 8), jnp.float32)
    w = jax.random.normal(k2, (8, 23), jnp.float32)
    labels = jax.random.randint(k3, (2, 32), 0, 23)

    def loss(hh, chunk):
        l, c = chunked_softmax_xent(hh, w, labels, chunk)
        return l / c

    g_c = jax.grad(lambda hh: loss(hh, 8))(h)
    g_f = jax.grad(lambda hh: loss(hh, 0))(h)
    np.testing.assert_allclose(g_c, g_f, atol=1e-5, rtol=1e-5)


def test_chunked_xent_softcap_and_mask():
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    h = jax.random.normal(k1, (1, 16, 8), jnp.float32)
    w = jax.random.normal(k2, (8, 11), jnp.float32)
    labels = jax.random.randint(k3, (1, 16), 0, 11)
    valid = jnp.zeros((1, 16)).at[0, :5].set(1.0)
    loss, cnt = chunked_softmax_xent(h, w, labels, 4, final_softcap=10.0,
                                     valid=valid)
    assert cnt == 5
    assert np.isfinite(float(loss))


def test_last_token_logits_selects_position():
    h = jnp.stack([jnp.full((4, 3), i, jnp.float32) for i in range(2)])
    w = jnp.eye(3)
    # default: last position
    out = last_token_logits(h, w)
    assert out.shape == (2, 3)
    # explicit index (the engine's padded-bucket path)
    idx = jnp.array([1, 2], jnp.int32)
    out_idx = last_token_logits(h, w, last_index=idx)
    np.testing.assert_allclose(out_idx, out)  # rows are constant per batch


def test_model_level_hybrid_equivalence():
    """A dense model produces identical prefill logits with chunking on/off —
    the paper's 'hybrid prefilling does not change results' claim."""
    import dataclasses
    from repro.configs import get_config, reduce_config
    from repro.models.model import build, make_batch
    from repro.runtime.sharding import materialize

    base = reduce_config(get_config("qwen1.5-0.5b"))
    cfg_chunked = dataclasses.replace(base, hybrid_chunk=16)
    cfg_full = dataclasses.replace(base, hybrid_chunk=0)
    api_c, api_f = build(cfg_chunked), build(cfg_full)
    params = materialize(jax.random.PRNGKey(0), api_c.defs(), jnp.float32)
    batch = make_batch(base, 2, 48, jax.random.PRNGKey(1), kind="prefill")
    log_c, _ = api_c.prefill(params, batch, kv_keep=16)
    log_f, _ = api_f.prefill(params, batch, kv_keep=16)
    np.testing.assert_allclose(np.asarray(log_c), np.asarray(log_f),
                               atol=2e-2, rtol=2e-2)


def test_suffix_discard_does_not_change_logits():
    """kv_keep only controls what is RETURNED, never the computation."""
    from repro.configs import get_config, reduce_config
    from repro.models.model import build, make_batch
    from repro.runtime.sharding import materialize

    cfg = reduce_config(get_config("granite-3-8b"))
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    batch = make_batch(cfg, 1, 64, jax.random.PRNGKey(1), kind="prefill")
    logits_all, kv_all = api.prefill(params, batch, kv_keep=64)
    logits_few, kv_few = api.prefill(params, batch, kv_keep=16)
    np.testing.assert_allclose(np.asarray(logits_all),
                               np.asarray(logits_few), atol=1e-5)
    assert kv_all["k"].shape[2] == 64 and kv_few["k"].shape[2] == 16
    np.testing.assert_allclose(np.asarray(kv_all["k"][:, :, :16]),
                               np.asarray(kv_few["k"]), atol=1e-6)
