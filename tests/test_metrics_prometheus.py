"""Prometheus text-exposition conformance for the serving MetricsRegistry.

The scrape payload is parsed with the same STRICT parser the CI serve-smoke
uses (``repro.launch.smoke``): HELP/TYPE comments, sample/label syntax,
cumulative ``le`` buckets terminated by ``+Inf``, ``_sum``/``_count``
consistency, one 0/1 series per StateGauge state. Concurrency soaks pin the
thread-safety contract: writer threads and scraping readers never corrupt a
value or produce an unparseable payload.
"""
import math
import threading

import pytest

from repro.launch.smoke import parse_prometheus, validate_histograms
from repro.serving.metrics import (DEFAULT_BUCKETS, Gauge, MetricsRegistry,
                                   StateGauge)


def test_help_and_type_comments():
    reg = MetricsRegistry()
    reg.counter("requests_served", help="Requests resolved with a result")
    reg.gauge("queue_depth", "i0", help="Queued requests per instance")
    text = reg.render_prometheus()
    assert ("# HELP prefillonly_requests_served "
            "Requests resolved with a result") in text
    assert "# TYPE prefillonly_requests_served counter" in text
    assert "# TYPE prefillonly_queue_depth gauge" in text
    # HELP precedes TYPE for the same family
    lines = text.splitlines()
    h = lines.index("# HELP prefillonly_queue_depth "
                    "Queued requests per instance")
    assert lines[h + 1] == "# TYPE prefillonly_queue_depth gauge"
    parse_prometheus(text)                   # strict parse passes


def test_help_first_writer_wins_and_is_escaped():
    reg = MetricsRegistry()
    reg.describe("odd", "line1\nline2 with \\ backslash")
    reg.describe("odd", "a later, different help text")
    reg.counter("odd").inc()
    text = reg.render_prometheus()
    assert r"# HELP prefillonly_odd line1\nline2 with \\ backslash" in text
    assert "a later, different help text" not in text
    parse_prometheus(text)


def test_label_escaping_round_trips_strict_parser():
    reg = MetricsRegistry()
    nasty = 'in"st\\ance\nwith everything'
    reg.counter("requests_served", nasty).inc(3)
    text = reg.render_prometheus()
    assert r'instance="in\"st\\ance\nwith everything"' in text
    series = parse_prometheus(text)
    (s,) = series["prefillonly_requests_served"]
    assert s["value"] == 3.0


def test_histogram_exposition_cumulative_and_consistent():
    reg = MetricsRegistry(buckets=(0.1, 1.0, 10.0))
    h = reg.histogram("jct_residual_seconds", "i0")
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):    # one lands past the last edge
        h.observe(v)
    text = reg.render_prometheus()
    series = parse_prometheus(text)
    fams = validate_histograms(series)       # cumulative + _sum/_count check
    assert fams == ["prefillonly_jct_residual_seconds"]
    buckets = series["prefillonly_jct_residual_seconds_bucket"]
    assert [b["labels"]["le"] for b in buckets] == \
        ["0.1", "1", "10", "+Inf"]
    assert [b["value"] for b in buckets] == [1, 3, 4, 5]
    (cnt,) = series["prefillonly_jct_residual_seconds_count"]
    (ssum,) = series["prefillonly_jct_residual_seconds_sum"]
    assert cnt["value"] == 5 and ssum["value"] == pytest.approx(56.05)
    assert cnt["labels"] == {"instance": "i0"}


def test_default_bucket_table_renders_parseable():
    reg = MetricsRegistry()
    reg.histogram("latency_seconds").observe(0.123)
    series = parse_prometheus(reg.render_prometheus())
    validate_histograms(series)
    # 26 finite edges + +Inf
    assert len(series["prefillonly_latency_seconds_bucket"]) == \
        len(DEFAULT_BUCKETS) + 1


def test_state_gauge_one_series_per_state():
    reg = MetricsRegistry()
    sg = reg.state_gauge("brownout_state",
                         ("normal", "tighten", "degrade", "shed"), "i0")
    sg.set(2)
    series = parse_prometheus(reg.render_prometheus())
    rows = series["prefillonly_brownout_state"]
    by_state = {r["labels"]["state"]: r["value"] for r in rows}
    assert by_state == {"normal": 0, "tighten": 0, "degrade": 1, "shed": 0}
    assert all(r["labels"]["instance"] == "i0" for r in rows)
    assert sg.state == "degrade"


def test_aggregate_instance_renders_unlabelled():
    reg = MetricsRegistry()
    reg.counter("requests_served").inc(2)             # global view
    reg.counter("requests_served", "i0").inc(5)
    series = parse_prometheus(reg.render_prometheus())
    rows = series["prefillonly_requests_served"]
    assert {frozenset(r["labels"].items()): r["value"]
            for r in rows} == {frozenset(): 2.0,
                               frozenset({("instance", "i0")}): 5.0}
    assert reg.total("requests_served") == 7.0


def test_gauge_add_is_atomic_under_threads():
    g = Gauge()
    n, per = 8, 2000

    def worker():
        for _ in range(per):
            g.add(1.0)

    threads = [threading.Thread(target=worker) for _ in range(n)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert g.value == n * per                # no torn read-modify-write


def test_state_gauge_set_under_threads_stays_in_range():
    sg = StateGauge(("a", "b", "c"))
    stop = threading.Event()

    def flipper(i):
        while not stop.is_set():
            sg.set(i)

    threads = [threading.Thread(target=flipper, args=(i,)) for i in range(3)]
    [t.start() for t in threads]
    for _ in range(2000):
        assert sg.state in ("a", "b", "c")
    stop.set()
    [t.join() for t in threads]


def test_registry_readers_vs_writers_soak():
    """Writers hammer counters/gauges/histograms on several instance labels
    while readers scrape continuously: every scrape must parse strictly and
    the final totals must be exact."""
    reg = MetricsRegistry(buckets=(0.01, 0.1, 1.0))
    reg.describe("requests_served", "served")
    n_writers, per = 4, 1500
    errors = []
    stop = threading.Event()

    def writer(k):
        inst = f"i{k % 2}"
        for j in range(per):
            reg.counter("requests_served", inst).inc()
            reg.gauge("queue_depth", inst).add(1.0)
            reg.histogram("latency_seconds", inst).observe(0.001 * (j % 7))
            reg.state_gauge("brownout_state", ("normal", "shed"),
                            inst).set(j % 2)

    def reader():
        while not stop.is_set():
            try:
                series = parse_prometheus(reg.render_prometheus())
                validate_histograms(series)
                reg.render()
            except Exception as e:           # surfaced after join
                errors.append(e)
                return

    ws = [threading.Thread(target=writer, args=(k,))
          for k in range(n_writers)]
    rs = [threading.Thread(target=reader) for _ in range(2)]
    [t.start() for t in rs]
    [t.start() for t in ws]
    [t.join() for t in ws]
    stop.set()
    [t.join() for t in rs]
    assert not errors, errors
    assert reg.total("requests_served") == n_writers * per
    assert sum(g.value for _, g in reg._named("gauge", "queue_depth")) == \
        n_writers * per
    merged = reg.merged_histogram("latency_seconds")
    assert merged.count == n_writers * per and math.isfinite(merged.sum)
    parse_prometheus(reg.render_prometheus())
