"""Shape-aware packed-batch cost model (ISSUE 10): PackedShapeJCT fit and
prior, priced marginal-cost batch formation + skew splitting, the
pick_backfill scheduler hook, per-pack-class calibration residuals, and the
satellite JCT-model fixes (pearson on degenerate input, clamped-fit counter,
GridJCT / RooflineJCT coverage)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.core.jct import (GridJCT, LinearProxyJCT, PackedShapeJCT,
                            RooflineJCT, SHAPE_FEATURES,
                            _causal_context_sum, pearson, step_features,
                            tp_comm_bytes_per_token)
from repro.core.scheduler import Request, Scheduler
from repro.models.model import build
from repro.runtime.sharding import materialize
from repro.serving.metrics import MetricsRegistry
from repro.serving.tracing import JCTCalibrationMonitor


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen1.5-0.5b"), hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    return cfg, params


# --------------------------------------------------------------------------
# satellite fixes: pearson degenerate input, clamped-fit counter
# --------------------------------------------------------------------------

def test_pearson_zero_variance_returns_zero():
    """A degenerate fit must not report perfect correlation to the
    jct_pearson_r gauge."""
    assert pearson([5.0, 5.0, 5.0], [1.0, 2.0, 3.0]) == 0.0
    assert pearson([1.0, 2.0, 3.0], [7.0, 7.0, 7.0]) == 0.0
    assert pearson([1.0], [1.0]) == 0.0
    assert pearson([], []) == 0.0
    # non-degenerate input still correlates
    assert pearson([1.0, 2.0, 3.0], [2.0, 4.0, 6.0]) == pytest.approx(1.0)


def test_linear_fit_counts_clamped_intercepts():
    """fit() clamps a negative intercept to 0 — silently, before this
    counter: calibration drift from a mis-specified model must be visible."""
    m = LinearProxyJCT()
    assert m.clamped_fits == 0
    # perfectly linear data with a POSITIVE intercept: no clamp
    m.fit([(n, 0, 1e-4 * n + 0.05) for n in range(100, 1000, 100)])
    assert m.clamped_fits == 0
    # data whose least-squares intercept is negative: clamped and counted
    m.fit([(n, 0, 2e-4 * n - 0.05) for n in range(1000, 9000, 1000)])
    assert m.clamped_fits == 1
    assert m.b == 0.0


# --------------------------------------------------------------------------
# satellite coverage: GridJCT / RooflineJCT / helpers
# --------------------------------------------------------------------------

def test_grid_jct_fit_predict_roundtrip():
    """GridJCT recovers a planted bilinear+quadratic cost surface."""
    def true_jct(n, c):
        return 0.01 + 3e-5 * (n - c) + 1e-6 * c + 2e-3 * (n**2 - c**2) * 1e-6

    samples = [(n, c, true_jct(n, c))
               for n in range(1000, 16000, 1000) for c in (0, n // 4, n // 2)]
    g = GridJCT().fit(samples)
    for n, c in ((2500, 0), (7000, 3500), (15000, 7000)):
        assert g.predict(n, c) == pytest.approx(true_jct(n, c), rel=1e-6)


def test_roofline_jct_monotone_and_hit_discount():
    """More miss tokens cost more; a cached prefix strictly discounts."""
    cfg = get_config("qwen1.5-0.5b")
    r = RooflineJCT(cfg)
    assert r.predict(4000) > r.predict(2000) > 0
    assert r.predict(4000, 2000) < r.predict(4000)
    # the profile grid covers every (n, c) pair at the grid granularity
    grid = r.samples(3000, granularity=1000)
    assert len(grid) == 1 + 2 + 3
    assert all(t > 0 for _, _, t in grid)


def test_causal_context_sum_arithmetic():
    """Closed-form vs brute force over full/windowed/local-global cases."""
    def brute(n_input, n_cached, window, local_global=False):
        total = 0.0
        for i in range(n_cached, n_input):
            full = i + 1
            win = min(i + 1, window) if window else full
            if local_global:
                total += 0.5 * (full + win)
            elif window:
                total += win
            else:
                total += full
        return total

    for n, c in ((10, 0), (10, 4), (100, 37)):
        assert _causal_context_sum(n, c, 0) == brute(n, c, 0)
        for w in (3, 8, 50, 200):
            assert _causal_context_sum(n, c, w) == brute(n, c, w)
            assert _causal_context_sum(n, c, w, local_global=True) == \
                brute(n, c, w, local_global=True)


def test_tp_comm_bytes_per_token():
    cfg = get_config("qwen1.5-0.5b")
    assert tp_comm_bytes_per_token(cfg, 1) == 0.0
    payload = 2 * cfg.num_layers * cfg.d_model * 2
    assert tp_comm_bytes_per_token(cfg, 2) == pytest.approx(1.0 * payload)
    assert tp_comm_bytes_per_token(cfg, 4) == pytest.approx(1.5 * payload)
    # ring all-reduce cost saturates at 2x payload as k grows
    assert tp_comm_bytes_per_token(cfg, 64) < 2.0 * payload


# --------------------------------------------------------------------------
# PackedShapeJCT: canonical features, prior, NNLS fit
# --------------------------------------------------------------------------

def test_step_features_canonicalize_step_kinds():
    """Solo-miss, solo-suffix, and packed shapes land on one feature basis
    so formation-time pricing matches BatchRecord observations."""
    # fresh solo: no rows, no padded dims
    f = step_features(60, 64, 0, 0, 0)
    assert f[1:] == (60.0, 64.0, 0.0, 0.0, 0.0)
    # solo-suffix: one implicit row of (S, exact prefix)
    f = step_features(36, 64, 0, 0, 128)
    assert f[3] == 64.0            # row_tokens = 1 * S
    assert f[4] == 128.0           # prefix_slots = 1 * pref
    # packed hit: Nb rows padded to (smax, pmax)
    f = step_features(100, 128, 4, 48, 256)
    assert f[3] == 4 * 48 and f[4] == 4 * 256
    assert f[5] == pytest.approx(4 * 48 * (48 + 256) * 1e-6)


def test_packed_shape_fit_recovers_nonnegative_coefficients():
    """NNLS over synthetic shaped steps recovers the planted rates; all
    coefficients stay >= 0 so marginal pack costs are monotone."""
    rng = np.random.default_rng(0)
    m = PackedShapeJCT(min_samples=8)
    a_c, a_row, a_pref = 1e-4, 2e-5, 1e-5
    for _ in range(64):
        Nb = int(rng.choice([1, 2, 4, 8]))
        smax = int(rng.choice([32, 48, 64]))
        pmax = int(rng.choice([0, 128, 256]))
        comp = int(rng.integers(32, 512))
        S = comp
        wall = (0.01 + a_c * comp + a_row * Nb * smax + a_pref * Nb * pmax)
        m.observe(comp, S, Nb, smax, pmax, wall)
    m.refit_recent()
    assert m.fitted
    assert all(c >= 0.0 for c in m.coef)
    pred = m.predict(256, 256, 4, 64, 256)
    want = 0.01 + a_c * 256 + a_row * 4 * 64 + a_pref * 4 * 256
    assert pred == pytest.approx(want, rel=0.15)
    assert set(m.coefficients()) == set(SHAPE_FEATURES)


def test_packed_shape_prior_charges_padding():
    """Before enough warm samples, the prior prices computed tokens at the
    linear proxy's rate plus a discounted rent on padded slots."""
    fb = LinearProxyJCT(a=1e-4, b=0.01)
    m = PackedShapeJCT(fallback=fb, pad_discount=0.25)
    assert not m.fitted
    # no padding: exactly the linear proxy
    assert m.predict(64, 64, 0, 0, 0, pad_slots=0) == pytest.approx(
        1e-4 * 64 + 0.01)
    # 100 padded slots at 0.25 * a
    assert m.predict(64, 64, 2, 48, 128, pad_slots=100) == pytest.approx(
        1e-4 * (64 + 25) + 0.01)


# --------------------------------------------------------------------------
# scheduler hook
# --------------------------------------------------------------------------

def test_pick_backfill_prefers_largest_benefit():
    sched = Scheduler("fifo", LinearProxyJCT())
    rs = [Request(n_input=64, arrival=float(i)) for i in range(4)]
    cands = [(r, 0) for r in rs]
    gains = {rs[0].req_id: 1.0, rs[1].req_id: 3.0,
             rs[2].req_id: None, rs[3].req_id: 3.0}
    picked = sched.pick_backfill(cands, lambda r, p: gains[r.req_id])
    assert picked == 1                 # largest benefit, earliest arrival
    # all ineligible -> None
    assert sched.pick_backfill(cands, lambda r, p: None) is None
    # negative benefits are still RETURNED (caller decides to close)
    assert sched.pick_backfill(cands, lambda r, p: -1.0) == 0


# --------------------------------------------------------------------------
# engine: priced marginal admission replaces the magic pmax gate
# --------------------------------------------------------------------------

def _warm_two_prefixes(cfg, params, rng, small_len=64, big_len=640):
    small = rng.integers(0, cfg.vocab_size, small_len).tolist()
    big = rng.integers(0, cfg.vocab_size, big_len).tolist()
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(
        pack_token_budget=512, pack_prefix_budget=10**6,
        cache_capacity_tokens=32768))
    eng.submit(small)
    eng.submit(big)
    eng.run_until_drained()
    return eng, small, big


def test_long_prefix_rejected_by_price_not_constant(setup):
    """The old ``pb > 2*pmax_b`` heuristic is gone: the same long-prefix
    candidate is admitted or rejected purely by the shape model's marginal
    price. With prefix slots priced FREE it co-packs; with the real
    (positive) prefix rate it is left for its own step."""
    cfg, params = setup
    rng = np.random.default_rng(23)
    eng, small, big = _warm_two_prefixes(cfg, params, rng)
    # force a FITTED shape model whose prefix_slots rate is zero: prefix
    # padding costs nothing, so price-based admission must now accept the
    # 640-token-prefix candidate the old magic gate would have rejected
    eng.shape_jct.coef = np.array([5e-3, 1e-5, 0.0, 0.0, 0.0, 0.0])
    eng.shape_jct.fits = 1
    eng.shape_jct.window = 0           # keep observe() from refitting
    eng.shape_jct.refit_every = 10**9
    a = eng.submit(small + rng.integers(0, cfg.vocab_size, 20).tolist())
    b = eng.submit(small + rng.integers(0, cfg.vocab_size, 24).tolist())
    c = eng.submit(big + rng.integers(0, cfg.vocab_size, 20).tolist())
    eng.run_until_drained()
    assert eng.packed_hit_requests == 3, \
        "prefix-free pricing must admit the long-prefix candidate"

    # same workload, same fitted model but with a REAL prefix_slots rate:
    # raising pmax to 1024 re-prices every row, the marginal exceeds the
    # candidate's solo cost, and the pack closes without it
    eng2, small2, big2 = _warm_two_prefixes(cfg, params, rng)
    eng2.shape_jct.coef = np.array([5e-3, 1e-5, 0.0, 0.0, 1e-5, 0.0])
    eng2.shape_jct.fits = 1
    eng2.shape_jct.window = 0
    eng2.shape_jct.refit_every = 10**9
    eng2.submit(small2 + rng.integers(0, cfg.vocab_size, 20).tolist())
    eng2.submit(small2 + rng.integers(0, cfg.vocab_size, 24).tolist())
    eng2.submit(big2 + rng.integers(0, cfg.vocab_size, 20).tolist())
    splits0 = eng2.pack_skew_splits
    eng2.run_until_drained()
    assert eng2.packed_hit_requests == 2, \
        "priced prefix padding must reject the long-prefix candidate"
    assert eng2.pack_skew_splits > splits0, \
        "rejecting the best remaining candidate is a skew split"


def test_skew_split_closes_pack_and_requeues(setup):
    """When the best remaining candidate prices negative, the pack closes
    (counted) and the candidate is served in a later step, not dropped."""
    cfg, params = setup
    rng = np.random.default_rng(29)
    eng, small, big = _warm_two_prefixes(cfg, params, rng)
    eng.shape_jct.coef = np.array([5e-3, 1e-5, 0.0, 0.0, 1e-5, 0.0])
    eng.shape_jct.fits = 1
    eng.shape_jct.window = 0
    eng.shape_jct.refit_every = 10**9
    steps0 = eng.steps
    ids = [eng.submit(small + rng.integers(0, cfg.vocab_size, 20).tolist()),
           eng.submit(big + rng.integers(0, cfg.vocab_size, 20).tolist())]
    done = eng.run_until_drained()
    assert sorted(done) == sorted(ids)             # nothing dropped
    assert eng.pack_skew_splits >= 1
    assert eng.steps == steps0 + 2                 # split into two steps
    assert eng.stats()["pack_skew_splits"] == eng.pack_skew_splits


def test_formed_cost_feeds_predicted_jct(setup):
    """BatchRecord.predicted_jct (and the watchdog's inflight prediction)
    must be the shape-priced cost the pack was ADMITTED against."""
    cfg, params = setup
    rng = np.random.default_rng(31)
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(pack_token_budget=512))
    eng.submit(rng.integers(0, cfg.vocab_size, 60).tolist())
    eng.submit(rng.integers(0, cfg.vocab_size, 40).tolist())
    eng.step()
    rec = eng.batch_records[-1]
    rows = [(100, 0)]
    # two misses co-pack into one flat 100-token step: predicted_jct is the
    # shape model's price for that realized shape
    assert rec.n_requests == 2
    assert rec.predicted_jct == pytest.approx(eng._pack_cost(rows))


# --------------------------------------------------------------------------
# calibration monitor: per-pack-class residuals + new gauges
# --------------------------------------------------------------------------

def test_monitor_tracks_residuals_per_pack_class():
    model = LinearProxyJCT()
    shape = PackedShapeJCT(fallback=model)
    mon = JCTCalibrationMonitor(model, buckets=(64, 256),
                                shape_model=shape)
    reg = MetricsRegistry()
    mon.bind(reg, "t")
    mon.observe(0.010, 0.012, 60, kind="solo")
    mon.observe(0.020, 0.025, 200, kind="hit")
    mon.observe(0.020, 0.021, 200, kind="hit")
    s = mon.summary()
    assert s["by_class"]["solo"]["count"] == 1
    assert s["by_class"]["hit"]["count"] == 2
    assert s["by_class"]["hit"]["mean_abs"] == pytest.approx(0.003)
    # shape-model block rides along in the summary
    assert "shape" in s and s["shape"]["fitted"] is False
    text = reg.render()
    assert "jct_residual_hit_seconds" in text
    assert "jct_fit_clamped" in text
    assert "jct_shape_computed" in text


def test_monitor_drift_refits_shape_model():
    model = LinearProxyJCT(a=1e-6, b=0.0)     # badly mis-fitted on purpose
    model.refit_every = 10**9
    shape = PackedShapeJCT(fallback=model, min_samples=4,
                           refit_every=10**9)
    mon = JCTCalibrationMonitor(model, window=8, drift_threshold=0.5,
                                drift_min=4, cooldown=4, shape_model=shape)
    rng = np.random.default_rng(3)
    for i in range(16):
        n = int(rng.integers(64, 512))
        actual = 1e-4 * n + 0.01
        model.observe(n, 0, actual)
        shape.observe(n, n, 0, 0, 0, actual)
        mon.observe(model.predict(n), actual, n, kind="miss")
    assert mon.drift_refits >= 1
    assert shape.fits >= 1, "drift must refit the shape model too"
