"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes.

All kernels execute in interpret mode on CPU (the TPU target is exercised by
``.lower()`` structure, not by execution here).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,D,F,bt,bf", [
    (64, 32, 96, 32, 32),
    (100, 64, 150, 32, 64),      # ragged T and F (padding path)
    (16, 16, 16, 16, 16),        # single block
])
def test_fused_mlp_matches_ref(T, D, F, bt, bf, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (T, D), jnp.float32).astype(dtype)
    wg = (jax.random.normal(ks[1], (D, F), jnp.float32) * 0.1).astype(dtype)
    wu = (jax.random.normal(ks[2], (D, F), jnp.float32) * 0.1).astype(dtype)
    wd = (jax.random.normal(ks[3], (F, D), jnp.float32) * 0.1).astype(dtype)
    got = ops.fused_mlp(x, wg, wu, wd, block_t=bt, block_f=bf)
    want = ref.fused_mlp_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_fused_mlp_batched_layout():
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (2, 40, 32), jnp.float32)
    wg = jax.random.normal(ks[1], (32, 64), jnp.float32) * 0.1
    wu = jax.random.normal(ks[2], (32, 64), jnp.float32) * 0.1
    wd = jax.random.normal(ks[3], (64, 32), jnp.float32) * 0.1
    got = ops.fused_mlp(x, wg, wu, wd, block_t=16, block_f=32)
    want = ref.fused_mlp_ref(x.reshape(-1, 32), wg, wu, wd).reshape(2, 40, 32)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Sq,H,KV,d,window,softcap", [
    (64, 4, 4, 16, 0, 0.0),          # MHA full causal
    (64, 4, 2, 16, 0, 0.0),          # GQA
    (70, 4, 2, 16, 13, 0.0),         # SWA + ragged seq
    (64, 8, 2, 32, 0, 50.0),         # softcap (gemma2)
    (33, 2, 1, 8, 7, 30.0),          # everything at once, tiny blocks
])
def test_flash_attention_matches_ref(Sq, H, KV, d, window, softcap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, Sq, H, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (2, Sq, KV, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (2, Sq, KV, d), jnp.float32).astype(dtype)
    got = ops.flash_attention(q, k, v, window=window, softcap=softcap,
                              block_q=32, block_k=32)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), window=window, softcap=softcap
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,H,KV,d,block_s", [
    (96, 4, 2, 16, 32),
    (64, 4, 4, 32, 64),
    (100, 8, 2, 16, 32),             # ragged cache length
])
def test_decode_attention_matches_ref(S, H, KV, d, block_s, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 1, H, d), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (2, S, KV, d), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (2, S, KV, d), jnp.float32).astype(dtype)
    kv_len = jnp.array([S // 3, S], jnp.int32)
    got = ops.decode_attention(q, kc, vc, kv_len, block_s=block_s)
    want = ref.decode_attention_ref(q.reshape(2, KV, H // KV, d), kc, vc,
                                    kv_len).reshape(2, 1, H, d)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_matches_model_layer_oracle():
    """The model's blocked_attention (pure JAX) and the Pallas kernel agree."""
    from repro.models.layers import blocked_attention
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (2, 48, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 48, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 48, 2, 16), jnp.float32)
    got = ops.flash_attention(q, k, v, block_q=16, block_k=16)
    want = blocked_attention(q, k, v, q_block=16, kv_block=16)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("S,bb,softcap", [(96, 16, 0.0), (100, 32, 0.0),
                                          (64, 16, 30.0)])
def test_packed_causal_matches_blocked(S, bb, softcap):
    """The exact-causal tile-packing schedule (perf hillclimb C1) is
    bit-compatible with the naive blocked schedule."""
    from repro.models.layers import blocked_attention, packed_causal_attention
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (2, S, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, S, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, S, 2, 16), jnp.float32)
    want = blocked_attention(q, k, v, q_block=bb, kv_block=bb,
                             softcap=softcap)
    got = packed_causal_attention(q, k, v, block=bb, softcap=softcap)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,D,bt", [(64, 32, 32), (100, 48, 32), (8, 16, 8)])
def test_rmsnorm_matches_ref(T, D, bt, dtype):
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    x = jax.random.normal(ks[0], (T, D), jnp.float32).astype(dtype)
    w = (jax.random.normal(ks[1], (D,), jnp.float32) * 0.1).astype(dtype)
    got = ops.rmsnorm(x, w, block_t=bt)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_rmsnorm_matches_model_layer():
    from repro.models.layers import rms_norm
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 24, 32), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(8), (32,), jnp.float32) * 0.1
    got = ops.rmsnorm(x, w)
    want = rms_norm(x, w)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
