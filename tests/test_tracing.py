"""Request-lifecycle tracing + JCT-calibration observability plane.

Covers the ISSUE 7 acceptance surface that is testable without a real
model: ring-buffer bounds, deterministic sampling, the orphan buffer,
retry rebind (late results land on the SAME timeline), Chrome-trace
nesting, and — through the chaos fakes — retry / watchdog / brownout
events appearing on the affected requests' timelines. The calibration
monitor's drift detector and Prometheus export are exercised against the
real ``LinearProxyJCT``.
"""
import json
import time

import pytest

from repro.core.jct import LinearProxyJCT
from repro.launch.smoke import (parse_prometheus, validate_chrome,
                                validate_trace_jsonl)
from repro.runtime.fault_tolerance import JCTDeadlineWatchdog
from repro.serving import (AdmissionController, AsyncServer, BatchRecord,
                           BrownoutController, ChaosConfig, FaultPlan,
                           JCTCalibrationMonitor, Rejected, RetryPolicy,
                           SpanTracer)
from repro.serving.metrics import MetricsRegistry
from test_chaos import FirstRouter, _pool


# ---- ring / sampling / orphan bounds ----------------------------------------

def test_ring_bounds_and_counters():
    tr = SpanTracer(capacity=4)
    for i in range(10):
        ctx = tr.begin(rid=i, user_id=f"u{i}")
        tr.finish(ctx, "delivered")
    s = tr.stats()
    assert s["begun"] == 10 and s["finished"] == 10
    assert s["retained"] == 4 and s["active"] == 0
    kept = [r["req_id"] for r in tr.snapshot()]
    assert kept == [6, 7, 8, 9]              # oldest fell off the ring


def test_sampling_is_deterministic_and_no_op():
    tr = SpanTracer(sample=0.25)
    ctxs = [tr.begin() for _ in range(20)]
    live = [c for c in ctxs if c != SpanTracer._NOSAMPLE]
    assert len(live) == 5                    # every 4th, not probabilistic
    # unsampled contexts are no-ops end to end, never raising
    dead = next(c for c in ctxs if c == SpanTracer._NOSAMPLE)
    tr.event(dead, "route")
    tr.bind(dead, 99)
    tr.finish(dead, "delivered")
    s = tr.stats()
    assert s["sampled_out"] == 15 and s["begun"] == 5
    assert s["finished"] == 0 and 99 not in tr._by_rid


def test_orphan_buffer_merges_at_bind():
    tr = SpanTracer()
    # the engine can touch a rid before submit() finished binding it
    tr.event_rid(7, "batch", kind="miss")
    tr.span_rid(7, "execute", 1.0, 2.0, pack="miss")
    tr.event_rid(8, "batch")                 # different rid: must stay
    assert tr.stats()["orphaned"] == 3
    ctx = tr.begin(rid=7)
    assert tr.stats()["orphaned"] == 1       # rid-7 events merged
    tr.finish(ctx, "delivered")
    rec = tr.snapshot()[0]
    assert [e["name"] for e in rec["events"]] == ["submit", "batch",
                                                  "finish"]
    (sp,) = rec["spans"]
    assert (sp["name"], sp["t0"], sp["t1"], sp["pack"]) == \
        ("execute", 1.0, 2.0, "miss")


def test_orphan_buffer_is_bounded():
    tr = SpanTracer(orphan_capacity=4)
    for i in range(10):
        tr.event_rid(1000 + i, "batch")
    assert tr.stats()["orphaned"] == 4


def test_rebind_keeps_old_rid_on_same_timeline():
    tr = SpanTracer()
    tr.begin(rid=1, user_id="u")
    tr.rebind(1, 2)                          # retry re-keyed the request
    tr.event_rid(2, "retry", attempt=1)
    tr.event_rid(1, "tombstone_drop")        # late result of the old attempt
    tr.finish_rid(2, "delivered")
    (rec,) = tr.snapshot()
    assert rec["rids"] == [1, 2] and rec["attempts"] == 2
    names = [e["name"] for e in rec["events"]]
    assert names == ["submit", "retry", "tombstone_drop", "finish"]
    # finish unmapped BOTH rids; later events orphan instead of resurrecting
    tr.event_rid(1, "stale")
    assert tr.stats()["orphaned"] == 1


def test_broadcast_hits_only_active_traces():
    tr = SpanTracer()
    done = tr.begin(rid=1)
    tr.finish(done, "delivered")
    live = tr.begin(rid=2)
    tr.broadcast("brownout", level=2)
    tr.finish(live, "delivered")
    recs = {r["req_id"]: r for r in tr.snapshot()}
    assert "brownout" in [e["name"] for e in recs[2]["events"]]
    assert "brownout" not in [e["name"] for e in recs[1]["events"]]


# ---- export formats ---------------------------------------------------------

def _one_full_trace(tr, rid=5, instance="i0"):
    # span timestamps must sit INSIDE [begin, finish] for the Perfetto
    # nesting check, so capture t after begin and sleep past the last span
    ctx = tr.begin(rid=rid, user_id="u", n_input=40)
    t = time.perf_counter()
    tr.event(ctx, "route", instance=instance, predicted_jct=0.01)
    tr.event(ctx, "enqueue", instance=instance, req_id=rid)
    time.sleep(0.005)
    tr.span_rid(rid, "queue", t, t + 0.001, instance=instance)
    tr.span_rid(rid, "execute", t + 0.001, t + 0.003, instance=instance,
                pack="solo")
    tr.record_batch(BatchRecord(step=0, ts=t + 0.003, instance=instance,
                                kind="solo", req_ids=(rid,),
                                computed_tokens=40, padded_tokens=64,
                                S=64, jit_path="fresh", jit_key=(64, True),
                                compiled=True, predicted_jct=0.01,
                                wall=0.002))
    tr.finish(ctx, "delivered")


def test_dump_jsonl_round_trips_and_validates():
    tr = SpanTracer()
    _one_full_trace(tr)
    text = tr.dump_jsonl()
    rows = [json.loads(line) for line in text.splitlines()]
    assert [r["type"] for r in rows] == ["request", "batch"]
    rec = validate_trace_jsonl(text)         # the CI smoke's strict check
    assert rec["outcome"] == "delivered" and rec["req_id"] == 5
    assert rows[1]["padding_waste"] == pytest.approx(1 - 40 / 64)


def test_chrome_trace_nests_phases_inside_request():
    tr = SpanTracer()
    _one_full_trace(tr)
    obj = tr.chrome_trace()
    json.dumps(obj)                          # serializable
    assert validate_chrome(obj) == 2         # queue + execute nested
    names = {e["name"] for e in obj["traceEvents"]}
    assert {"request delivered", "queue", "execute", "step solo",
            "process_name", "thread_name"} <= names


def test_batch_record_padding_waste_edges():
    assert BatchRecord(step=0, ts=0.0).padding_waste == 0.0
    b = BatchRecord(step=0, ts=0.0, computed_tokens=100, padded_tokens=80)
    assert b.padding_waste == 0.0            # clamped, never negative
    d = BatchRecord(step=1, ts=0.0, computed_tokens=30,
                    padded_tokens=120).to_dict()
    assert d["type"] == "batch" and d["padding_waste"] == 0.75


# ---- JCT calibration monitor ------------------------------------------------

def test_jct_monitor_exports_histograms_and_coefficients():
    model = LinearProxyJCT(a=1e-3, b=0.01)
    mon = JCTCalibrationMonitor(model, buckets=(64, 256))
    reg = MetricsRegistry()
    mon.bind(reg, "i0")
    # gauges present from bind — a scrape before any warm step sees the fit
    assert reg.gauge("jct_coef_a", "i0").value == pytest.approx(1e-3)
    for n in (40, 40, 200, 200):
        mon.observe(model.predict(n), model.predict(n) + 0.002, n)
    series = parse_prometheus(reg.render_prometheus())
    assert "prefillonly_jct_residual_seconds_bucket" in series
    assert "prefillonly_jct_relative_error_bucket" in series
    s = mon.summary()
    assert s["observed"] == 4 and set(s["by_bucket"]) == {64, 256}
    assert s["residual_p50"] == pytest.approx(0.002, rel=1e-6)
    assert s["a"] == pytest.approx(1e-3)


def test_jct_monitor_drift_triggers_refit():
    # model whose sliding window holds the TRUE relationship but whose
    # current coefficients are badly stale (10x) — predictions will miss
    # until the drift detector forces a refit from the window
    model = LinearProxyJCT(a=1e-3, b=0.0, refit_every=10_000)
    model._recent = [(n, 0, 1e-4 * n) for n in range(50, 300, 10)]
    mon = JCTCalibrationMonitor(model, window=32, drift_threshold=0.5,
                                drift_min=8, cooldown=16)
    reg = MetricsRegistry()
    mon.bind(reg, "i0")
    for _ in range(16):
        mon.observe(model.predict(100), 1e-4 * 100, 100)   # ~10x over
    assert mon.drift_refits == 1
    assert model.a == pytest.approx(1e-4, rel=1e-6)        # refit corrected
    assert reg.counter("jct_drift_refits", "i0").value == 1
    assert reg.gauge("jct_coef_a", "i0").value == pytest.approx(1e-4)
    # cooldown: the very next bad sample cannot refit again immediately
    mon.observe(10.0, 1.0, 100)
    assert mon.drift_refits == 1


# ---- chaos events land on the affected timelines ----------------------------

def _traced_server(pool, **kw):
    tracer = SpanTracer()
    srv = AsyncServer(pool, router=FirstRouter(),
                      retry=kw.pop("retry", RetryPolicy(budget=2,
                                                        backoff=0.0)),
                      tracer=tracer, **kw).start()
    return srv, tracer


def _timeline(tracer):
    recs = tracer.snapshot(include_active=True)
    assert len(recs) == 1
    return recs[0], [e["name"] for e in recs[0]["events"]]


def test_retry_events_on_timeline():
    plan = FaultPlan(ChaosConfig(schedule=[("i0", 0, "step_error")]))
    srv, tracer = _traced_server(_pool(2, plan))
    res = srv.submit("u", list(range(40))).result(timeout=10)
    assert not isinstance(res, Rejected)
    rec, names = _timeline(tracer)
    assert rec["outcome"] == "delivered" and rec["attempts"] == 2
    for needed in ("submit", "route", "enqueue", "lost", "retry", "finish"):
        assert needed in names, (needed, names)
    retry = next(e for e in rec["events"] if e["name"] == "retry")
    assert retry["instance"] == "i1" and retry["from_rid"] in rec["rids"]
    srv.shutdown(drain=True, timeout=5)


def test_watchdog_trip_and_tombstone_drop_on_timeline():
    plan = FaultPlan(ChaosConfig(schedule=[("i0", 0, "hang")],
                                 hang_seconds=0.8))
    wd = JCTDeadlineWatchdog(factor=4.0, min_deadline=0.12, interval=0.02)
    srv, tracer = _traced_server(_pool(2, plan), watchdog=wd)
    res = srv.submit("u", list(range(40))).result(timeout=10)
    assert not isinstance(res, Rejected)
    deadline = time.monotonic() + 5          # wait for the late harvest
    while (srv.metrics.total("late_results_dropped") < 1
           and time.monotonic() < deadline):
        time.sleep(0.02)
    rec, names = _timeline(tracer)
    assert rec["outcome"] == "delivered"
    for needed in ("watchdog_trip", "retry", "tombstone_drop"):
        assert needed in names, (needed, names)
    trip = next(e for e in rec["events"] if e["name"] == "watchdog_trip")
    assert trip["instance"] == "i0" and trip["elapsed"] > 0
    # event order tells the story: trip -> retry -> late drop
    assert names.index("watchdog_trip") < names.index("retry") \
        < names.index("tombstone_drop")
    srv.shutdown(drain=True, timeout=5)


def test_brownout_transition_and_rejection_on_timelines():
    b = BrownoutController(enter=(0.2, 0.5, 1.0), exit=(0.05, 0.1, 0.2),
                           hold=2, slack_factor=1.5)
    srv, tracer = _traced_server(_pool(2, sec_per_token=0.004),
                                 brownout=b,
                                 admission=AdmissionController(adapt=False))
    futs = [srv.submit(f"u{i}", list(range(100))) for i in range(12)]
    deadline = time.monotonic() + 5
    while b.level < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert b.level == 3
    late = srv.submit("u-late", list(range(100)))
    rej = late.result(timeout=2)
    assert isinstance(rej, Rejected) and rej.reason == "brownout"
    assert srv.drain(timeout=30)
    recs = tracer.snapshot()
    # in-flight requests saw the brownout transition as an event...
    touched = [r for r in recs if any(e["name"] == "brownout"
                                      for e in r["events"])]
    assert touched, "no timeline recorded the brownout transition"
    lv = next(e for r in touched for e in r["events"]
              if e["name"] == "brownout")
    assert lv["state"] in BrownoutController.LEVELS
    # ...and the shed request's own timeline records its rejection
    shed = [r for r in recs if r["outcome"] == "rejected:brownout"]
    assert len(shed) == 1 and shed[0]["user_id"] == "u-late"
    assert all(f.done() for f in futs)
    srv.shutdown(drain=True, timeout=5)
