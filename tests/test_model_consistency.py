"""Cross-path consistency: prefill vs decode, prefix-reuse vs fresh, MoE
sort-based dispatch vs dense reference, SSD chunk-size invariance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models.model import build, make_batch
from repro.runtime.sharding import materialize


def _setup(arch, **over):
    cfg = reduce_config(get_config(arch), **over)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    return cfg, api, params


def test_dense_decode_matches_prefill():
    """prefill(S) + decode(token S) == prefill(S+1) last-token logits."""
    cfg, api, params = _setup("qwen1.5-0.5b", hybrid_chunk=0)
    S = 31
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, S + 1), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    ref_logits, _ = api.prefill(params, {"tokens": toks})
    # build a decode cache from the prefill KV of the first S tokens
    _, kv = api.prefill(params, {"tokens": toks[:, :S]}, kv_keep=S)
    S_max = 64
    cache = api.init_cache(1, S_max)
    pad = S_max - S
    cache = {
        "k": jnp.pad(kv["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(kv["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
    }
    logits, _ = api.decode_step(params, toks[:, S], cache,
                                jnp.array([S], jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               atol=3e-2, rtol=3e-2)


def test_ssm_decode_matches_prefill():
    """Mamba2: prefill state + one decode step == prefill of S+1."""
    cfg, api, params = _setup("mamba2-130m", hybrid_chunk=0)
    S = 24
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, S + 1), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    ref_logits, _ = api.prefill(params, {"tokens": toks})
    _, state = api.prefill(params, {"tokens": toks[:, :S]})
    cache = {"ssm": state["ssm"], "conv": state["conv"]}
    logits, _ = api.decode_step(params, toks[:, S], cache,
                                jnp.array([S], jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               atol=3e-2, rtol=3e-2)


def test_ssd_chunk_size_invariance():
    """The chunked SSD scan is exact for any chunk size."""
    from repro.models.mamba2 import ssd_scan
    B, S, H, P, N = 2, 37, 3, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dA = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    dt = jnp.abs(jax.random.normal(ks[4], (B, S, H))) * 0.1
    y1, h1 = ssd_scan(x, dA, Bm, Cm, dt, chunk=37)
    y2, h2 = ssd_scan(x, dA, Bm, Cm, dt, chunk=8)
    y3, h3 = ssd_scan(x, dA, Bm, Cm, dt, chunk=1)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(y1, y3, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h1, h2, atol=1e-4, rtol=1e-4)


def test_ssd_initial_state_continuation():
    """ssd(x[:k]) then ssd(x[k:], h0) == ssd(x) — the SSM prefix-cache
    mechanism (state checkpoints) is exact."""
    from repro.models.mamba2 import ssd_scan
    B, S, H, P, N, k = 1, 20, 2, 4, 8, 11
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dA = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    dt = jnp.abs(jax.random.normal(ks[4], (B, S, H))) * 0.1
    y_full, h_full = ssd_scan(x, dA, Bm, Cm, dt, chunk=4)
    _, h_a = ssd_scan(x[:, :k], dA[:, :k], Bm[:, :k], Cm[:, :k], dt[:, :k],
                      chunk=4)
    y_b, h_b = ssd_scan(x[:, k:], dA[:, k:], Bm[:, k:], Cm[:, k:], dt[:, k:],
                        chunk=4, h0=h_a)
    np.testing.assert_allclose(y_b, y_full[:, k:], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h_b, h_full, atol=1e-4, rtol=1e-4)


def test_moe_matches_dense_reference():
    """Sort-based dispatch == dense all-experts reference when capacity is
    large enough for zero drops."""
    from repro.models.moe import moe_apply, moe_defs
    cfg = reduce_config(get_config("mixtral-8x22b"),
                        capacity_factor=8.0)   # no drops
    defs = moe_defs(cfg)
    params = materialize(jax.random.PRNGKey(7), defs, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    got = moe_apply(params, x, cfg, num_shards=2)

    # dense reference: every expert on every token, combine by gate weights
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gw, gi = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gw = gw / gw.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for e in range(cfg.num_experts):
        g = x @ params["w_gate"][e]
        u = x @ params["w_up"][e]
        y = (jax.nn.silu(g) * u) @ params["w_down"][e]
        w_e = jnp.sum(jnp.where(gi == e, gw, 0.0), axis=-1)
        want = want + y * w_e[..., None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_moe_num_shards_invariance():
    from repro.models.moe import moe_apply, moe_defs
    cfg = reduce_config(get_config("llama4-scout-17b-a16e"),
                        capacity_factor=8.0)
    defs = moe_defs(cfg)
    params = materialize(jax.random.PRNGKey(9), defs, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(10), (4, 8, cfg.d_model),
                          jnp.float32) * 0.5
    a = moe_apply(params, x, cfg, num_shards=1)
    b = moe_apply(params, x, cfg, num_shards=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-4, rtol=1e-4)


def test_prefix_reuse_matches_fresh_prefill():
    """prefill_with_prefix == fresh prefill on the concatenation."""
    from repro.models import transformer as tfm
    cfg, api, params = _setup("granite-3-8b", hybrid_chunk=0)
    from repro.models.model import cast_params
    pc = cast_params(params, cfg.dtype)
    P, S = 32, 16
    toks = jax.random.randint(jax.random.PRNGKey(11), (1, P + S), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    ref, _ = tfm.prefill(pc, cfg, {"tokens": toks})
    _, kv = tfm.prefill(pc, cfg, {"tokens": toks[:, :P]}, kv_keep=P)
    got, new_kv = tfm.prefill_with_prefix(pc, cfg, {"tokens": toks[:, P:]},
                                          kv, prefix_len=P, kv_keep=P + S)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=3e-2, rtol=3e-2)
    assert new_kv["k"].shape[2] == S


def test_gemma2_local_global_window_matters():
    """Local layers actually mask beyond the window (outputs differ when a
    far-away token changes) while staying finite."""
    cfg, api, params = _setup("gemma2-9b", hybrid_chunk=0, sliding_window=8)
    S = 32
    toks = jax.random.randint(jax.random.PRNGKey(12), (1, S), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    l1, _ = api.prefill(params, {"tokens": toks})
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    l2, _ = api.prefill(params, {"tokens": toks2})
    # global layers see position 0 => last-token logits must change
    assert not np.allclose(np.asarray(l1), np.asarray(l2))
