"""Distributed-numerics equivalence, run in a subprocess with 8 virtual
devices (the main test process must keep seeing 1 device).

Checks that the SAME reduced model produces the same loss/logits under:
  * single device (no mesh)
  * TP (model-axis sharded weights)
  * DP (dp_full preset: replicated weights, batch over every axis)
This exercises the whole sharding stack end to end: logical rules, ZeRO
optimizer shardings, the shard_map MoE, and the microbatch splitter.
"""
import json
import subprocess
import sys

import pytest

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_config, reduce_config
from repro.configs.base import ShapeConfig
from repro.launch.steps import build_step, rules_for, PRESETS
from repro.models.model import build, make_batch
from repro.optim import adamw
from repro.runtime import sharding as shd

cfg = reduce_config(get_config("%(arch)s"))
shp = ShapeConfig("t", 64, 8, "train")
api = build(cfg)
params = shd.materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
batch = make_batch(cfg, 8, 64, jax.random.PRNGKey(1))

# reference: single device, no mesh
ref_loss = float(api.train_loss(params, batch))

out = {"ref": ref_loss}
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
for name, overrides in [("tp", None), ("dp", PRESETS["dp_full"])]:
    rules = rules_for(cfg, shp, mesh, overrides=overrides)
    bundle = build_step(cfg, shp, mesh, rules)
    with mesh, shd.use_sharding(mesh, rules):
        state = adamw.init_state(params)
        state = jax.tree_util.tree_map(jax.device_put, state,
                                       bundle.in_shardings[0])
        b = jax.device_put({k: jnp.asarray(v) for k, v in batch.items()},
                           bundle.in_shardings[1])
        step = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                       out_shardings=bundle.out_shardings,
                       donate_argnums=bundle.donate_argnums)
        _, metrics = step(state, b)
        out[name] = float(metrics["loss"])
print("RESULT:" + json.dumps(out))
'''


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mixtral-8x22b"])
def test_tp_dp_single_device_losses_agree(arch):
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT % {"arch": arch}],
        capture_output=True, text=True, timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": "src",
             "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][-1]
    res = json.loads(line[len("RESULT:"):])
    # bf16 forward + different reduction orders: agree to ~1%
    assert abs(res["tp"] - res["ref"]) / res["ref"] < 0.02, res
    assert abs(res["dp"] - res["ref"]) / res["ref"] < 0.02, res
