"""Hierarchical KV memory, end to end: layer-wise discard arithmetic
(KVLifecycle / MemoryModel kv_keep pricing) and the DRAM offload tier
driven through the REAL engine — demote on eviction, restore on re-match,
score parity against pure recompute, break-even honored on a slow link."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.core.kv_policy import KVLifecycle, MemoryModel
from repro.core.offload import TieredPrefixCache
from repro.models.model import build
from repro.runtime.sharding import materialize

# 4-block device cache + solo packing + fine reuse granularity: two
# 40-token requests fill it, so a handful of distinct submissions force
# evictions into the host tier. offload_host_bw is pinned huge because
# worth_restoring prices the TARGET chip's recompute rate, which this
# CPU box can't approach (see EngineConfig.offload_host_bw).
TIER = dict(cache_capacity_tokens=64, offload=True, offload_host_bw=1e18,
            prefix_bucket_blocks=1, max_pack_requests=1)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_config(get_config("qwen1.5-0.5b"), hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    return cfg, params


def _flood(eng, cfg, seed, n=6, length=40):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        eng.submit(rng.integers(0, cfg.vocab_size, length).tolist(),
                   allowed_tokens=(5, 9))
    eng.run_until_drained()


def test_demote_restore_round_trip_scores(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, 40).tolist()

    eng = PrefillOnlyEngine(cfg, params, EngineConfig(**TIER))
    eng.submit(toks, allowed_tokens=(5, 9))
    eng.run_until_drained()
    _flood(eng, cfg, seed=1)                 # evict toks' kept KV host-side
    host = eng.cache.host
    assert host.offloads > 0, "device eviction never reached the host tier"
    # demoted payloads live as HOST numpy, not device arrays
    assert all(isinstance(arr, np.ndarray)
               for p in host._store.values() for arr in p)
    assert eng.cache.probe_blocks(_chain(eng, toks)) > 0

    r0 = eng.cache.restored_blocks
    i = eng.submit(toks, allowed_tokens=(5, 9))
    eng.run_until_drained()
    assert eng.cache.restored_blocks > r0, "re-match did not restore"
    got = eng.results[i]["scores"]

    cold = PrefillOnlyEngine(cfg, params,
                             EngineConfig(cache_capacity_tokens=0))
    j = cold.submit(toks, allowed_tokens=(5, 9))
    cold.run_until_drained()
    ref = cold.results[j]["scores"]
    for t in ref:                            # ISSUE acceptance: < 2e-2
        assert abs(ref[t] - got[t]) < 2e-2


def _chain(eng, toks):
    from repro.core.prefix_cache import token_chain
    return token_chain(toks, eng.ecfg.block_size)


def test_probe_is_side_effect_free_across_tiers(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, 40).tolist()
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(**TIER))
    eng.submit(toks, allowed_tokens=(5, 9))
    eng.run_until_drained()
    _flood(eng, cfg, seed=3)
    chain = _chain(eng, toks)
    before = (eng.cache.host.restores, eng.cache.restored_blocks)
    n = eng.cache.probe_blocks(chain)        # scheduling/routing probe
    assert n > 0, "host-resident prefix invisible to probes"
    assert (eng.cache.host.restores, eng.cache.restored_blocks) == before


def test_slow_link_breakeven_prefers_recompute(setup):
    cfg, params = setup
    rng = np.random.default_rng(4)
    toks = rng.integers(0, cfg.vocab_size, 40).tolist()
    slow = dict(TIER, offload_host_bw=1e3)   # ~KB/s fake PCIe
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(**slow))
    eng.submit(toks, allowed_tokens=(5, 9))
    eng.run_until_drained()
    _flood(eng, cfg, seed=5)
    assert eng.cache.host.offloads > 0       # demotion still happens
    i = eng.submit(toks, allowed_tokens=(5, 9))
    eng.run_until_drained()
    assert eng.cache.restored_blocks == 0, \
        "restored despite recompute being cheaper than the link"
    assert len(eng.results[i]["scores"]) == 2   # request still correct


def test_restore_estimate_prices_the_host_prefix(setup):
    cfg, params = setup
    rng = np.random.default_rng(6)
    toks = rng.integers(0, cfg.vocab_size, 40).tolist()
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(**TIER))
    eng.submit(toks, allowed_tokens=(5, 9))
    eng.run_until_drained()
    _flood(eng, cfg, seed=7)
    est = eng.restore_estimate(_chain(eng, toks))
    assert est["blocks"] > 0 and est["bytes"] > 0
    assert est["restore_s"] == pytest.approx(
        est["bytes"] / eng.cache.policy.host_bw)


def test_prefetch_upgrades_host_blocks_to_device(setup):
    cfg, params = setup
    rng = np.random.default_rng(8)
    toks = rng.integers(0, cfg.vocab_size, 40).tolist()
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(**TIER))
    eng.submit(toks, allowed_tokens=(5, 9))
    eng.run_until_drained()
    _flood(eng, cfg, seed=9)
    chain = _chain(eng, toks)
    n = eng.prefetch_prefix(chain)
    assert n > 0
    deadline = 50
    while eng.cache.probe_blocks(chain) == 0 and deadline:
        import time as _t
        _t.sleep(0.05)
        deadline -= 1
    assert eng.cache.probe_blocks(chain) > 0
    # the async worker upgrades payloads in place to device arrays
    for _ in range(100):
        blks = [eng.cache.blocks.get(h) for h in chain]
        blks = [b for b in blks if b is not None and b.payload is not None]
        if blks and all(not isinstance(b.payload[0], np.ndarray)
                        for b in blks):
            break
        import time as _t
        _t.sleep(0.05)
    assert blks and all(not isinstance(b.payload[0], np.ndarray)
                        for b in blks)


def test_pinned_blocks_survive_tiered_eviction():
    from repro.core.prefix_cache import token_chain
    c = TieredPrefixCache(2, 4)
    a = token_chain([1, 2, 3, 4, 5, 6, 7, 8], 4)
    c.insert(a, 8, payloads=[(np.ones((2, 4), np.float32),)] * 2)
    c.pin(a, 2)                              # running request holds it
    b = token_chain([9, 10, 11, 12, 13, 14, 15, 16], 4)
    c.insert(b, 8, now=1.0,
             payloads=[(np.zeros((2, 4), np.float32),)] * 2)
    assert all(h in c.blocks for h in a), "eviction dropped a pinned block"
    assert c.probe_blocks(a) == 2
    c.unpin(a, 2)


# ---- layer-wise discard arithmetic -----------------------------------------

def test_kv_lifecycle_keep_arithmetic():
    kv = KVLifecycle(block_size=16, kv_keep_tokens=40)
    assert kv.keep(100) == 40 and kv.keep(24) == 24
    assert kv.keep_aligned(100) == 32        # whole blocks only
    assert kv.resident(2, 100) and not kv.resident(1, 100)
    assert kv.keep_new(100, 16, 1) == 16     # one block reused, one new
    assert kv.keep_new(100, 32, 2) == 0      # already resident
    assert kv.suffix_keep_new(40, 32, 60) == 8
    assert kv.insertable_tokens(40, 32, 60) == 8
    assert kv.keep_pad(40, 2048) == 64       # bucketed jit key
    assert kv.keep_pad(40, 48) == 48         # clamped to padded S


def test_memory_model_kv_keep_prices_peak_layer():
    cfg = get_config("llama3.1-8b")
    mm = MemoryModel(cfg)
    S = 1 << 16
    unpriced = mm.peak_bytes(S, "hybrid")
    capped = mm.peak_bytes(S, "hybrid", kv_keep=1024)
    full = mm.peak_bytes(S, "hybrid", kv_keep=S)
    assert unpriced < capped < full
    # kept slice saturates at kv_keep: constant beyond the knee
    assert (mm.peak_bytes(2 * S, "hybrid", kv_keep=1024) - capped
            == pytest.approx(mm.peak_bytes(2 * S, "hybrid") - unpriced))


def test_memory_model_mil_knee_and_prefix_budget():
    cfg = get_config("llama3.1-8b")
    # fp8 weights — the paper's quantized serving setup; fp16 weights alone
    # would exceed the default chip's HBM and zero out every MIL
    mm = MemoryModel(cfg, weight_bytes_per_param=1)
    mil_all = mm.max_input_length("hybrid", kv_keep=1 << 30)  # keep all
    mil_cap = mm.max_input_length("hybrid", kv_keep=1024)
    mil_un = mm.max_input_length("hybrid")
    assert mil_all <= mil_cap <= mil_un
    # discard bound honored: serving at mil_cap with the capped keep fits
    assert mm.peak_bytes(mil_cap, "hybrid", kv_keep=1024) <= mm.budget_bytes()
    # peak-layer pricing shrinks the reservation -> larger device cache:
    # at the SAME serving length, a capped kept slice reserves less HBM
    # than keeping every input token's KV, so more is left for the cache
    S = mil_all
    budget_cap = mm.prefix_budget_tokens(S, kv_keep=1024)
    budget_all = mm.prefix_budget_tokens(S, kv_keep=S)
    assert budget_cap > budget_all
    assert budget_cap > 0
