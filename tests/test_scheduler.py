"""Scheduling tests — including the paper's §6.2/§6.3 A/B/C/D example:
continuous JCT calibration schedules A, D, C, B and harvests strictly more
prefix-cache hits than FIFO or arrival-frozen SRJF."""
from typing import List

from repro.core.jct import LinearProxyJCT
from repro.core.prefix_cache import PrefixCache, token_chain
from repro.core.scheduler import Request, Scheduler

BLOCK = 4


def _req(tokens, arrival=0.0, user=None):
    return Request(n_input=len(tokens), arrival=arrival,
                   chain=token_chain(tokens, BLOCK), tokens=tokens,
                   user_id=user)


def _run(queue: List[Request], policy: str, capacity_blocks: int,
         lam: float = 0.0):
    """Mini engine loop: pick -> count hit -> insert (whole request)."""
    cache = PrefixCache(capacity_blocks, BLOCK)
    sched = Scheduler(policy, LinearProxyJCT(a=1.0, b=0.0), lam=lam)
    for r in queue:
        r.n_cached_at_arrival = cache.match_len(r.chain)
    order, hits = [], {}
    now = 0.0
    q = list(queue)
    while q:
        i = sched.pick(q, cache, now)
        r = q.pop(i)
        hits[r.user_id] = cache.match_len(r.chain, now, touch=True)
        cache.pin(r.chain, hits[r.user_id] // BLOCK)
        cache.insert(r.chain, r.n_input, now=now)
        cache.unpin(r.chain, hits[r.user_id] // BLOCK)
        order.append(r.user_id)
        now += 1.0
    return order, hits


def _paper_requests():
    """A < C < B < D; A,D share a long profile prefix (P1), B,C share P2 —
    the recommendation-workload shape: long shared profile, short suffix."""
    P1 = list(range(100, 140))           # 40 tokens
    P2 = list(range(200, 248))           # 48 tokens
    A = _req(P1 + [1] * 4, arrival=0.000, user="A")     # 44
    B = _req(P2 + [3] * 12, arrival=0.001, user="B")    # 60
    C = _req(P2 + [2] * 4, arrival=0.002, user="C")     # 52
    D = _req(P1 + [4] * 24, arrival=0.003, user="D")    # 64
    return [A, B, C, D]                  # arrival order


def test_paper_example_calibrated_order_and_hits():
    # capacity = one largest request (the paper's "one request" cache)
    order, hits = _run(_paper_requests(), "srjf_calibrated", 60 // BLOCK)
    assert order == ["A", "D", "C", "B"], order      # §6.3 walkthrough
    assert hits["D"] == 40 and hits["B"] == 48       # two full-prefix hits
    assert sum(1 for v in hits.values() if v > 0) == 2


def test_paper_example_baselines_get_exactly_one_hit():
    """Paper §6.3: total cache hits is 1 for FIFO and naive SRJF, 2 with
    continuous calibration."""
    _, hits_cal = _run(_paper_requests(), "srjf_calibrated", 60 // BLOCK)
    _, hits_srjf = _run(_paper_requests(), "srjf", 60 // BLOCK)
    _, hits_fifo = _run(_paper_requests(), "fifo", 60 // BLOCK)
    assert sum(1 for v in hits_cal.values() if v > 0) == 2
    assert sum(1 for v in hits_srjf.values() if v > 0) == 1
    assert sum(1 for v in hits_fifo.values() if v > 0) == 1


def test_naive_srjf_schedules_by_arrival_jct():
    order, _ = _run(_paper_requests(), "srjf", 60 // BLOCK)
    assert order == ["A", "C", "B", "D"]             # §6.2: pure length order


def test_fifo_schedules_by_arrival():
    order, _ = _run(_paper_requests(), "fifo", 60 // BLOCK)
    assert order == ["A", "B", "C", "D"]


def test_lambda_prevents_starvation():
    """A stream of short jobs must not starve one long job when λ > 0."""
    jct = LinearProxyJCT(a=1.0, b=0.0)
    cache = PrefixCache(0, BLOCK)
    long_req = _req([9] * 100, arrival=0.0, user="long")
    q = [long_req]
    # λ = 0: long job loses to every short job forever
    sched0 = Scheduler("srjf_calibrated", jct, lam=0.0)
    schedL = Scheduler("srjf_calibrated", jct, lam=5.0)
    # a stream of FRESH short jobs keeps arriving (arrival ~ now)
    for t in range(30):
        q.append(_req([t] * 10, arrival=29.9, user=f"s{t}"))
    # with λ=0 the long job is never picked while shorts exist
    i = sched0.pick(q, cache, now=30.0)
    assert q[i].user_id != "long"
    # with λ large enough, waiting time wins
    i = schedL.pick(q, cache, now=30.0)
    assert q[i].user_id == "long"


def test_calibration_reacts_to_cache_contents():
    """Algorithm 1: a request becomes preferred the moment its prefix lands
    in the cache, without re-submission."""
    jct = LinearProxyJCT(a=1.0, b=0.0)
    sched = Scheduler("srjf_calibrated", jct)
    cache = PrefixCache(100, BLOCK)
    short = _req([1] * 20, user="short")
    long_shared = _req(list(range(64)) + [2] * 8, user="long")
    q = [short, long_shared]
    assert q[sched.pick(q, cache, 0.0)].user_id == "short"
    cache.insert(token_chain(list(range(64)), BLOCK), 64)
    # now long's miss count is 72-64=8 < short's 20
    assert q[sched.pick(q, cache, 0.0)].user_id == "long"
