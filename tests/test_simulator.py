"""Simulator-level reproduction checks (paper §7 headline behaviours)."""
import pytest

from repro.configs import get_config
from repro.core.simulator import EngineSpec, Simulator, paper_engines
from repro.data.workloads import credit_verification, post_recommendation


CFG = get_config("llama3.1-8b")


def _run(spec, trace, qps, chips=2):
    sim = Simulator(CFG, spec, total_chips=chips,
                    weight_bytes_per_param=1.0, user_mil=trace.max_len)
    return sim.run(list(trace.requests), qps)


def test_prefillonly_highest_throughput_at_high_qps():
    trace = post_recommendation(qps=4.0, seed=1)
    results = {s.name: _run(s, trace, 4.0) for s in paper_engines()}
    po = results["prefillonly"]
    for name, r in results.items():
        if name != "prefillonly":
            assert po.throughput >= r.throughput, (name, r.throughput)
    # headline: >= ~2x the best baseline under load
    best_baseline = max(r.throughput for n, r in results.items()
                        if n != "prefillonly")
    assert po.throughput > 1.5 * best_baseline


def test_prefillonly_highest_cache_hit_rate():
    trace = post_recommendation(qps=2.0, seed=2)
    results = {s.name: _run(s, trace, 2.0) for s in paper_engines()}
    po = results["prefillonly"]
    assert po.hit_rate == max(r.hit_rate for r in results.values())


def test_tensor_parallel_wins_at_low_qps():
    """Fig 6: at low QPS the TP baseline has lower latency (2 chips/request)."""
    trace = post_recommendation(qps=0.3, seed=3)
    po = _run([s for s in paper_engines() if s.name == "prefillonly"][0],
              trace, 0.3)
    tp = _run([s for s in paper_engines()
               if s.name == "tensor_parallel"][0], trace, 0.3)
    assert tp.mean_latency < po.mean_latency


def test_credit_verification_rejects_short_mil_engines():
    """Table 2: WL2 (40k-60k) is infeasible for paged on a 16GB chip."""
    trace = credit_verification(qps=0.5, seed=4)
    paged = _run([s for s in paper_engines() if s.name == "paged_fcfs"][0],
                 trace, 0.5)
    po = _run([s for s in paper_engines() if s.name == "prefillonly"][0],
              trace, 0.5)
    assert paged.rejected == len(trace.requests)   # WL2: x for paged
    assert po.rejected == 0                        # WL2: pass for PrefillOnly


def test_lambda_trades_p99_for_mean():
    """Fig 11 regime: λ=0 starves the tail (SRJF worst case); a moderate λ
    repairs P99; a large λ (≈FIFO) inflates mean latency."""
    trace = post_recommendation(qps=3.0, seed=5)
    r0 = _run(EngineSpec("po_l0", "srjf_calibrated", lam=0.0), trace, 3.0)
    rm = _run(EngineSpec("po_lm", "srjf_calibrated", lam=0.05), trace, 3.0)
    rh = _run(EngineSpec("po_lh", "srjf_calibrated", lam=2.0), trace, 3.0)
    assert rm.p99_latency < r0.p99_latency        # starvation repaired
    assert rh.mean_latency > rm.mean_latency      # too much fairness costs mean


def test_conservation():
    trace = post_recommendation(qps=1.0, seed=6)
    for spec in paper_engines():
        r = _run(spec, trace, 1.0)
        assert r.completed + r.rejected == len(trace.requests)
