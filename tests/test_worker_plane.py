"""Cross-process serving plane: RPC framing, worker processes, heartbeat
leases, supervised restart, and kill -9 chaos.

The invariants under test (ISSUE 8 acceptance):
  * the RPC layer turns every transport failure — refused, reset, torn
    frame, frozen peer — into a TYPED, bounded-time error, never a hang
  * a worker SIGKILLed mid-batch loses nothing: the shadow queue re-homes,
    retries recover in-flight work, every future resolves exactly once
  * a SIGSTOPped (frozen) worker is detected by missed heartbeats, killed,
    and restarted; a crash-looping worker permafails within its budget
  * an orphaned worker (supervisor gone) self-exits on lease expiry; a
    SIGTERMed worker drains and exits 0
  * the 6-seed process-chaos soak (SIGKILL mid-batch, SIGSTOP freeze, RPC
    drop/delay) serves >= 90% with exactly-once delivery and bounded
    resolution
"""
import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time

import pytest

from repro.runtime.fault_tolerance import JCTDeadlineWatchdog
from repro.serving import (AsyncServer, ChaosConfig, FaultPlan,
                           LeastBacklogRouter, Rejected, RetryPolicy,
                           RpcClient, RpcClosed, RpcDropped, RpcError,
                           RpcRemoteError, RpcTimeout, SpanTracer,
                           make_process_pool, wire_supervisor,
                           wrap_pool_processes)
from repro.serving.rpc import recv_msg, send_msg

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")


# ---- rpc layer ---------------------------------------------------------------

def test_rpc_framing_roundtrip():
    a, b = socket.socketpair()
    try:
        msg = {"op": "x", "nested": {"k": [1, 2, 3]}, "s": "héllo"}
        send_msg(a, msg)
        assert recv_msg(b) == msg
    finally:
        a.close()
        b.close()


def test_rpc_torn_frame_is_closed_not_hang():
    a, b = socket.socketpair()
    try:
        # a length prefix promising 100 bytes, then the peer dies
        import struct
        a.sendall(struct.pack(">I", 100) + b"only-some")
        a.close()
        b.settimeout(2.0)
        with pytest.raises(RpcClosed):
            recv_msg(b)
    finally:
        b.close()


def _mini_server(handler):
    """One-op TCP server thread for client tests; returns (port, stop)."""
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(8)
    stop = threading.Event()

    def loop():
        srv.settimeout(0.2)
        while not stop.is_set():
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            threading.Thread(target=handler, args=(conn,),
                             daemon=True).start()

    threading.Thread(target=loop, daemon=True).start()
    return srv.getsockname()[1], lambda: (stop.set(), srv.close())


def test_rpc_client_typed_errors_and_retry():
    state = {"conns": 0}

    def handler(conn):
        state["conns"] += 1
        try:
            if state["conns"] == 1:
                conn.close()            # die before answering: conn-level
                return
            msg = recv_msg(conn)
            if msg["op"] == "boom":
                send_msg(conn, {"ok": False, "error": "kaboom"})
            elif msg["op"] == "slow":
                time.sleep(1.0)
                send_msg(conn, {"ok": True, "out": {}})
            else:
                send_msg(conn, {"ok": True, "out": {"echo": msg["op"]}})
        finally:
            conn.close()

    port, stop = _mini_server(handler)
    try:
        c = RpcClient("127.0.0.1", port, retry_backoff=0.01)
        # first connection is torn down pre-response -> one retry recovers
        assert c.call("hi", retries=2)["echo"] == "hi"
        with pytest.raises(RpcRemoteError):
            c.call("boom", retries=2)
        with pytest.raises(RpcTimeout):
            c.call("slow", timeout=0.2, retries=2)   # never retried
        c.close()
        with pytest.raises(RpcError):
            c.call("hi")
    finally:
        stop()


def test_rpc_fault_hook_drop_and_delay():
    def handler(conn):
        try:
            while True:
                recv_msg(conn)
                send_msg(conn, {"ok": True, "out": {}})
        except Exception:
            conn.close()

    faults = iter([("rpc_drop", 0.0), ("rpc_delay", 0.15), None])
    port, stop = _mini_server(handler)
    try:
        c = RpcClient("127.0.0.1", port,
                      fault_hook=lambda op: next(faults, None))
        with pytest.raises(RpcDropped):      # worker DID process the call
            c.call("x", retries=3)           # ...and drops are not retried
        t0 = time.perf_counter()
        c.call("x")
        assert time.perf_counter() - t0 >= 0.14
        c.close()
    finally:
        stop()


# ---- one worker process, no supervisor --------------------------------------

def _spawn_worker(tmp_path, name="w0", lease=30.0, drain_grace=5.0,
                  spec=None):
    port_file = str(tmp_path / f"{name}.port.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serving.worker", "--name", name,
         "--spec", json.dumps(spec or {"kind": "fake",
                                       "sec_per_token": 1e-4}),
         "--port-file", port_file, "--lease", str(lease),
         "--drain-grace", str(drain_grace)],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    deadline = time.monotonic() + 30.0
    while time.monotonic() < deadline:
        try:
            with open(port_file) as f:
                return proc, int(json.load(f)["port"])
        except (FileNotFoundError, json.JSONDecodeError, KeyError):
            time.sleep(0.02)
    proc.kill()
    raise RuntimeError("worker did not listen")


def test_worker_submit_step_harvest_and_dedupe(tmp_path):
    proc, port = _spawn_worker(tmp_path)
    c = RpcClient("127.0.0.1", port)
    try:
        hello = c.call("hello")
        assert hello["pid"] == proc.pid and hello["block_size"] == 16
        req = {"rid": 7001, "tokens": list(range(32)),
               "allowed_tokens": [5, 9], "user_id": "u1"}
        assert c.call("submit", dict(req))["dup"] is False
        # idempotent replay: same rid is deduped, not double-queued
        assert c.call("submit", dict(req))["dup"] is True
        assert c.call("heartbeat", {})["depth"] == 1
        out = c.call("step", timeout=30.0)
        assert out["rid"] == 7001
        served = dict((int(k), v) for k, v in out["served"])
        assert served[7001]["req_id"] == 7001
        assert served[7001]["token"] == 5
        # harvest is destructive: a second step has nothing
        assert c.call("step", timeout=30.0)["rid"] is None
        # even a re-submit of the harvested rid is still a dup
        assert c.call("submit", dict(req))["dup"] is True
    finally:
        c.close()
        proc.kill()
        proc.wait(timeout=10)


def test_worker_sigterm_drains_and_exits_zero(tmp_path):
    proc, port = _spawn_worker(tmp_path, drain_grace=10.0)
    c = RpcClient("127.0.0.1", port)
    try:
        c.call("submit", {"rid": 7101, "tokens": list(range(64))})
        proc.send_signal(signal.SIGTERM)
        # the draining worker refuses NEW work but keeps serving steps
        deadline = time.monotonic() + 5.0
        refused = False
        while time.monotonic() < deadline and not refused:
            try:
                c.call("submit", {"rid": 7102, "tokens": [1, 2]})
                time.sleep(0.02)
            except RpcError:
                refused = True
        assert refused, "draining worker accepted new work"
        out = c.call("step", timeout=30.0)
        assert out["rid"] == 7101
        assert proc.wait(timeout=15) == 0
    finally:
        c.close()
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)


def test_worker_lease_expiry_self_exit(tmp_path):
    # no heartbeats ever arrive -> the orphaned worker must self-exit rc=2
    proc, _port = _spawn_worker(tmp_path, lease=0.6)
    assert proc.wait(timeout=15) == 2


# ---- supervised pool + AsyncServer ------------------------------------------

def _plane(tmp_path, n=2, specs=None, rpc_fault_hook=None, retry=None,
           **sup_kw):
    specs = specs or {f"i{k}": {"kind": "fake", "sec_per_token": 2e-4}
                      for k in range(n)}
    kw = dict(lease=2.5, heartbeat_interval=0.1, miss_budget=3,
              drain_grace=2.0, restart_backoff=0.1, restart_backoff_cap=1.0,
              log_dir=str(tmp_path), rpc_fault_hook=rpc_fault_hook)
    kw.update(sup_kw)
    pool, sup = make_process_pool(specs, **kw)
    srv = AsyncServer(
        pool, router=LeastBacklogRouter(),
        retry=retry if retry is not None
        else RetryPolicy(budget=3, backoff=0.01, jitter_seed=0),
        watchdog=JCTDeadlineWatchdog(factor=6, min_deadline=1.0,
                                     interval=0.02),
        tracer=SpanTracer(capacity=256))
    wire_supervisor(sup, srv)
    sup.start()
    srv.start()
    return pool, sup, srv


def _teardown(sup, srv):
    srv.shutdown(drain=False)
    sup.stop(graceful=False)


def test_process_pool_smoke_and_telemetry(tmp_path):
    pool, sup, srv = _plane(tmp_path, n=2)
    try:
        futs = [srv.submit(f"u{i}", list(range(40)), allowed_tokens=(5, 9))
                for i in range(12)]
        res = [f.result(timeout=30) for f in futs]
        assert all(isinstance(r, dict) for r in res), res
        assert all(r["token"] == 5 for r in res)
        assert srv.metrics.total("requests_served") == 12
        # worker-side metrics crossed the heartbeat bridge
        time.sleep(0.3)
        assert srv.metrics.gauge("worker_up", "i0").value == 1
        # engines really are separate processes
        pids = {sup.pid_of(n) for n in pool.engines}
        assert len(pids) == 2 and os.getpid() not in pids
    finally:
        _teardown(sup, srv)


def test_sigkill_mid_batch_exactly_once(tmp_path):
    pool, sup, srv = _plane(tmp_path, n=2)
    try:
        futs = [srv.submit(f"u{i}", list(range(150 + (i % 4) * 50)),
                           allowed_tokens=(5, 9)) for i in range(20)]
        time.sleep(0.1)                       # let batches get in flight
        victim = sup.pid_of("i0")
        os.kill(victim, signal.SIGKILL)
        res = [f.result(timeout=60) for f in futs]
        ok = [r for r in res if isinstance(r, dict)]
        assert len(ok) == 20, [r for r in res if not isinstance(r, dict)]
        # exactly-once: the server counted each delivery exactly once
        assert srv.metrics.total("requests_served") == 20
        assert sup.handles["i0"].deaths >= 1
        # the worker comes back and serves again
        deadline = time.monotonic() + 20
        while time.monotonic() < deadline and not pool.healthy["i0"]:
            time.sleep(0.05)
        assert pool.healthy["i0"], "killed worker never rejoined the pool"
        assert sup.pid_of("i0") not in (None, victim)
        more = [srv.submit(f"v{i}", list(range(30))) for i in range(6)]
        assert all(isinstance(f.result(timeout=30), dict) for f in more)
        assert srv.metrics.total("worker_restarts") >= 1
    finally:
        _teardown(sup, srv)


def test_sigstop_freeze_detected_and_recovered(tmp_path):
    pool, sup, srv = _plane(tmp_path, n=2)
    frozen = None
    try:
        futs = [srv.submit(f"u{i}", list(range(200)),
                           allowed_tokens=(5, 9)) for i in range(10)]
        time.sleep(0.05)
        frozen = sup.pid_of("i1")
        os.kill(frozen, signal.SIGSTOP)
        t0 = time.monotonic()
        res = [f.result(timeout=60) for f in futs]
        assert all(isinstance(r, dict) for r in res), res
        # detection came from missed heartbeats (the process never exited
        # by itself; the supervisor had to notice and SIGKILL it)
        assert sup.handles["i1"].deaths >= 1
        assert time.monotonic() - t0 < 45
    finally:
        if frozen is not None:
            try:
                os.kill(frozen, signal.SIGCONT)
            except ProcessLookupError:
                pass
        _teardown(sup, srv)


def test_crash_loop_budget_permafails(tmp_path):
    pool, sup, srv = _plane(tmp_path, n=2, max_restarts=2,
                            restart_window=120.0)
    try:
        for _ in range(3):                    # budget is 2 restarts
            pid = sup.pid_of("i0")
            if pid is not None:
                os.kill(pid, signal.SIGKILL)
            deadline = time.monotonic() + 20
            h = sup.handles["i0"]
            while (time.monotonic() < deadline and not h.permafailed
                   and (sup.pid_of("i0") in (None, pid))):
                time.sleep(0.05)
            if h.permafailed:
                break
        assert sup.handles["i0"].permafailed
        assert srv.metrics.total("worker_crashloop_permafail") >= 1
        # the pool keeps serving on the survivor
        futs = [srv.submit(f"u{i}", list(range(30))) for i in range(5)]
        assert all(isinstance(f.result(timeout=30), dict) for f in futs)
        assert not pool.healthy["i0"]
    finally:
        _teardown(sup, srv)


def test_frontend_failure_verdict_restarts_worker(tmp_path):
    """A dropped step response makes the SERVER mark the instance failed
    while the process is still alive; the supervisor must convert that
    verdict into a kill + restart (health_view wiring)."""
    drops = {"n": 0}

    def hook(name, op):
        if name == "i0" and op == "step" and drops["n"] == 0:
            drops["n"] += 1
            return ("rpc_drop", 0.0)
        return None

    pool, sup, srv = _plane(tmp_path, n=2, rpc_fault_hook=hook)
    try:
        old_pid = sup.pid_of("i0")
        futs = [srv.submit(f"u{i}", list(range(60)),
                           allowed_tokens=(5, 9)) for i in range(10)]
        res = [f.result(timeout=60) for f in futs]
        assert all(isinstance(r, dict) for r in res), res
        assert drops["n"] == 1
        deadline = time.monotonic() + 20
        while time.monotonic() < deadline and not (
                pool.healthy["i0"] and sup.pid_of("i0") not in
                (None, old_pid)):
            time.sleep(0.05)
        assert pool.healthy["i0"]
        assert sup.pid_of("i0") not in (None, old_pid), \
            "server-declared failure did not restart the live worker"
    finally:
        _teardown(sup, srv)


# ---- the acceptance soak -----------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_process_chaos_soak_exactly_once(tmp_path, seed):
    """6-seed soak: SIGKILL mid-batch + SIGSTOP freeze (scheduled, so every
    seed provably exercises both — and staggered, so the 3-worker pool is
    never in TOTAL outage, which would insta-reject submits by design) plus
    seeded random RPC response delays. Every future resolves exactly once
    within the bound, >= 90% served. Response DROPS are excluded here: a
    drop kills its worker via the frontend-verdict path (see
    test_frontend_failure_verdict_restarts_worker), and a randomly-timed
    third death can coincide with the scheduled two — total outage again."""
    cfg = ChaosConfig(seed=seed, kill=0.0, freeze=0.0, freeze_seconds=1.0,
                      rpc_delay=0.05, rpc_delay_seconds=0.02,
                      max_faults=8,
                      schedule=(("i0", 2, "kill"), ("i1", 12, "freeze")))
    plan = FaultPlan(cfg)
    specs = {f"i{k}": {"kind": "fake", "sec_per_token": 2e-4}
             for k in range(3)}
    pool, sup, srv = _plane(tmp_path / f"s{seed}", specs=specs,
                            rpc_fault_hook=plan.rpc_fault)
    wrap_pool_processes(pool, plan, sup, delay=0.01)
    n = 36
    try:
        t0 = time.monotonic()
        futs = []
        for i in range(n):
            futs.append(srv.submit(f"u{i % 7}",
                                   list(range(80 + (i % 5) * 40)),
                                   allowed_tokens=(5, 9)))
            time.sleep(0.015)
        # bounded resolution: no future outlives the watchdog + restart
        # machinery — 60s is many multiples of every deadline in play
        res = [f.result(timeout=60) for f in futs]
        wall = time.monotonic() - t0
        served = [r for r in res if isinstance(r, dict)]
        rejected = [r for r in res if isinstance(r, Rejected)]
        assert len(served) + len(rejected) == n     # resolved exactly once
        assert len(served) >= 0.9 * n, \
            (f"served {len(served)}/{n}; rejects: "
             f"{[(r.reason, r.detail) for r in rejected]}; "
             f"faults: {plan.counts()}")
        # exactly-once: server-side delivery count matches what we hold
        assert srv.metrics.total("requests_served") == len(served)
        # both scheduled process faults actually fired
        kinds = {k for _, _, k in plan.injected}
        assert "kill" in kinds and "freeze" in kinds, plan.counts()
        assert wall < 90
    finally:
        _teardown(sup, srv)


# ---- launch-layer e2e --------------------------------------------------------

def test_serve_cli_sigterm_preempts_drains_exits_zero(tmp_path):
    """Satellite: a REAL SIGTERM to a running ``launch/serve.py`` process
    (in --workers process mode) stops the replay, drains every admitted
    request, reports ``preempted: True`` in the results, and exits 0 —
    the PreemptionHandler path end to end, across the RPC boundary."""
    out_path = tmp_path / "serve.out"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["REPRO_WORKER_LOG_DIR"] = str(tmp_path)
    with open(out_path, "w") as out:
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.serve",
             "--workers", "1", "--qps", "6", "--max-requests", "500",
             "--metrics-port", "0"],
            stdout=out, stderr=subprocess.STDOUT, env=env)
    try:
        # readiness: the "metrics:" banner prints after the worker spawned
        # and the PreemptionHandler installed, right before the replay
        deadline = time.monotonic() + 300
        while time.monotonic() < deadline:
            if "metrics:" in out_path.read_text():
                break
            assert proc.poll() is None, \
                f"serve died early:\n{out_path.read_text()}"
            time.sleep(0.25)
        else:
            pytest.fail(f"serve never became ready:\n{out_path.read_text()}")
        time.sleep(3.0)          # let the open-loop replay admit some work
        proc.send_signal(signal.SIGTERM)
        rc = proc.wait(timeout=180)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)
    text = out_path.read_text()
    assert rc == 0, f"exit {rc}:\n{text}"
    assert "preempted: True" in text, text
    import re
    m = re.search(r"^served: (\d+)$", text, re.M)
    assert m is not None, text
    assert int(m.group(1)) >= 1, f"preemption dropped admitted work:\n{text}"
    # far fewer than the full trace ran: the SIGTERM actually cut it short
    m2 = re.search(r"^requests: (\d+)$", text, re.M)
    assert m2 is not None and int(m2.group(1)) < 500, text
