"""Host-KV offload tier (paper §9): evict -> offload -> restore-on-match."""
import numpy as np

from repro.configs import get_config
from repro.core.offload import HostKVStore, OffloadPolicy, TieredPrefixCache
from repro.core.prefix_cache import token_chain

BLOCK = 4
CFG = get_config("llama3.1-8b")


def _chain(n, seed=0):
    toks = [(seed * 997 + i) % 89 for i in range(n)]
    return token_chain(toks, BLOCK)


def _payloads(chain):
    return [(np.full((2, BLOCK), i, np.float32),) for i in range(len(chain))]


def test_evicted_blocks_land_in_host_store():
    c = TieredPrefixCache(2, BLOCK, cfg=CFG)
    a = _chain(8, seed=1)
    c.insert(a, 8, payloads=_payloads(a))
    b = _chain(8, seed=2)
    c.insert(b, 8, now=1.0, payloads=_payloads(b))   # evicts a's blocks
    assert c.host.offloads >= 1
    assert any(h in c.host for h in a)


def test_match_restores_from_host():
    c = TieredPrefixCache(2, BLOCK, cfg=CFG)
    a = _chain(8, seed=1)
    c.insert(a, 8, payloads=_payloads(a))
    b = _chain(8, seed=2)
    c.insert(b, 8, now=1.0, payloads=_payloads(b))
    assert super(TieredPrefixCache, c).match_blocks(a) == 0  # device miss
    m = c.match_len(a, now=2.0)                              # host restore
    assert m > 0
    assert c.host.restores >= 1
    # restored payload is intact
    payloads = c.match_payloads(a, now=3.0)
    assert payloads and payloads[0][0][0, 0] == 0.0


def test_host_store_capacity_lru():
    payload_bytes = 2 * BLOCK * 4
    s = HostKVStore(capacity_bytes=2 * payload_bytes)   # fits 2 payloads
    for i in range(4):
        s.put(i, (np.zeros((2, BLOCK), np.float32),))
    assert s.used_bytes <= s.capacity_bytes
    assert s.host_evictions >= 2
    assert 3 in s and 0 not in s


def test_policy_breakeven():
    pol = OffloadPolicy()
    # an 8B model: restoring a 16-token block (~2 MB) beats recomputing
    assert pol.worth_restoring(CFG, 16, 2 * 2**20)
    # absurdly slow link -> recompute wins
    slow = OffloadPolicy(host_bw=1e3)
    assert not slow.worth_restoring(CFG, 16, 2 * 2**20)
