"""JCT models (paper §6.3) + the MIL/prefix-budget memory model (§3.1/§4)."""
import numpy as np
from hypothesis import given, strategies as st

from repro.configs import get_config
from repro.core.jct import (GridJCT, LinearProxyJCT, RooflineJCT, pearson,
                            tp_comm_bytes_per_token)
from repro.core.kv_policy import MemoryModel


def test_linear_proxy_fit_recovers_slope():
    samples = [(n, c, 2e-4 * (n - c) + 0.01)
               for n in range(1000, 20000, 1000) for c in (0, n // 2)]
    m = LinearProxyJCT().fit(samples)
    assert abs(m.a - 2e-4) / 2e-4 < 1e-6
    assert m.pearson_r > 0.999


def test_proxy_pearson_on_roofline_samples():
    """The paper reports r=0.987 between JCT and miss tokens; our roofline
    JCT over the profiling grid correlates comparably."""
    cfg = get_config("llama3.1-8b")
    model = RooflineJCT(cfg)
    samples = model.samples(max_len=60_000, granularity=2_000)
    miss = [s[0] - s[1] for s in samples]
    t = [s[2] for s in samples]
    assert pearson(miss, t) > 0.97


def test_grid_jct_beats_proxy_on_quadratic_regime():
    cfg = get_config("llama3.1-8b")
    model = RooflineJCT(cfg)
    samples = model.samples(max_len=120_000, granularity=4_000)
    lin = LinearProxyJCT().fit(samples)
    grid = GridJCT().fit(samples)
    err_l = np.mean([abs(lin.predict(n, c) - t) for n, c, t in samples])
    err_g = np.mean([abs(grid.predict(n, c) - t) for n, c, t in samples])
    assert err_g <= err_l


@given(st.integers(1_000, 100_000), st.integers(0, 99_000))
def test_jct_monotonicity(n_input, n_cached):
    """More cache can never hurt; longer input can never be faster."""
    cfg = get_config("llama3.1-8b")
    model = RooflineJCT(cfg)
    n_cached = min(n_cached, n_input)
    t = model.predict(n_input, n_cached)
    assert t >= model.predict(n_input, min(n_input, n_cached + 1000)) - 1e-12
    assert model.predict(n_input + 1000, n_cached) >= t - 1e-12


def test_tp_comm_bytes_positive_and_scaling():
    cfg = get_config("llama3.1-8b")
    assert tp_comm_bytes_per_token(cfg, 1) == 0.0
    b2 = tp_comm_bytes_per_token(cfg, 2)
    b4 = tp_comm_bytes_per_token(cfg, 4)
    assert 0 < b2 < b4  # (k-1)/k grows with k


# ---- memory model / MIL (Table 2 + Fig 10 analog) --------------------------

def test_mil_ordering_matches_paper():
    """Table 2's qualitative ordering on a single accelerator:
    paged < discard-only < chunked < hybrid; TP-2 > paged."""
    cfg = get_config("llama3.1-8b")
    mm = MemoryModel(cfg, weight_bytes_per_param=1.0)
    mil = mm.mil_table()
    assert mil["paged"] < mil["discard"]
    assert mil["paged"] < mil["chunked"]
    assert mil["chunked"] < mil["hybrid"]
    assert mil["hybrid"] > 2 * mil["paged"]      # ">= upto 5x" headline
    assert mil["tp"] > mil["paged"]


def test_discard_alone_is_marginal():
    """Paper §2.6: naive KV discard gives only ~1.6x (intermediates bound)."""
    cfg = get_config("llama3.1-8b")
    mm = MemoryModel(cfg, weight_bytes_per_param=1.0)
    mil = mm.mil_table()
    assert mil["discard"] / mil["paged"] < 2.5


def test_mlp_intermediates_dominate_one_layer_kv():
    """Fig 4: intermediate tensors ~14x one-layer KV on Llama-3.1-8B."""
    cfg = get_config("llama3.1-8b")
    mm = MemoryModel(cfg)
    ratio = mm.mlp_int_per_token / mm.kv_one_layer_per_token
    assert 10 < ratio < 20


def test_prefix_budget_positive_at_workload_mil():
    cfg = get_config("llama3.1-8b")
    mm = MemoryModel(cfg, weight_bytes_per_param=1.0)
    assert mm.prefix_budget_tokens(20_000) > 10_000


def test_hybrid_micro_optimizations_increase_mil():
    """§4.3 output-preallocation / in-place ablation (Fig 10 steps)."""
    cfg = get_config("llama3.1-8b")
    base = MemoryModel(cfg, weight_bytes_per_param=1.0,
                       output_prealloc=False, inplace=False)
    opt = MemoryModel(cfg, weight_bytes_per_param=1.0)
    assert opt.max_input_length("hybrid") >= base.max_input_length("hybrid")
    # chunked technique depends on the act coefficient too
    assert opt.peak_bytes(32_768, "paged") < base.peak_bytes(32_768, "paged")
