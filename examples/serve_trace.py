"""END-TO-END DRIVER: serve the paper's post-recommendation trace through
the async serving subsystem — a pool of PrefillOnly instances behind an
AsyncServer (real forwards, real prefix-KV reuse, Algorithm-1 scheduling,
JCT-aware routing, open-loop real-time arrivals).

    PYTHONPATH=src python examples/serve_trace.py [--qps 20] [--requests 40]
"""
import argparse

from repro.launch.serve import serve_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--qps", type=float, default=20.0)
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--policy", default="srjf_calibrated")
    ap.add_argument("--router", default="least_backlog",
                    choices=["user_hash", "least_backlog"])
    args = ap.parse_args()

    out = serve_trace("qwen1.5-0.5b", "post_recommendation", qps=args.qps,
                      n_instances=args.instances, policy=args.policy,
                      router=args.router,
                      scale_tokens=0.02, max_requests=args.requests)
    print("\n=== serve_trace results ===")
    for k, v in out.items():
        if k == "per_instance":
            for name, st in v.items():
                print(f"  {name}: hit_rate={st['hit_rate']:.2f} "
                      f"steps={st['steps']}")
        elif k == "metrics":
            print("--- telemetry ---")
            print(v)
        else:
            print(f"{k}: {v}")


if __name__ == "__main__":
    main()
