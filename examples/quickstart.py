"""Quickstart: a prefill-only request through the PrefillOnly engine.

Builds a reduced qwen1.5-0.5b, submits the paper's recommendation-style
prompt shape ([user profile] + [post] -> Yes/No), and prints the constrained
single-token scores. Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.models.model import build
from repro.runtime.sharding import materialize


def main():
    cfg = reduce_config(get_config("qwen1.5-0.5b"))
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)

    engine = PrefillOnlyEngine(cfg, params, EngineConfig(
        policy="srjf_calibrated", lam=0.05, cache_capacity_tokens=4096))

    # the paper's profile run: fit the JCT model on this host
    r = engine.profile((64, 128))
    print(f"profile run: JCT ~ {engine.jct_model.a:.2e}s/token "
          f"(pearson {r:.3f})")

    rng = np.random.default_rng(0)
    YES, NO = 5, 9                      # stand-in token ids
    profile = rng.integers(0, cfg.vocab_size, 120).tolist()  # user profile

    # 3 posts for the same user — requests 2 and 3 hit the profile's prefix KV
    for post_id in range(3):
        post = rng.integers(0, cfg.vocab_size, 24).tolist()
        rid = engine.submit(profile + post, allowed_tokens=(YES, NO),
                            user_id="demo-user")
        engine.step()
        res = engine.results[rid]
        print(f"post {post_id}: P(yes)={res['scores'][YES]:.3f} "
              f"P(no)={res['scores'][NO]:.3f} "
              f"cached={res['n_cached']}/{res['n_input']} tokens "
              f"latency={res['latency']*1e3:.0f}ms")
    print("engine stats:", engine.stats())


if __name__ == "__main__":
    main()
