"""Train a language model with the full fault-tolerant stack (ZeRO-1
shardings, microbatching, async checkpoints, NaN guard, resume).

Default: a reduced qwen on CPU for a quick demonstration. ``--full-size``
uses the real 0.5B config (~463M params — the "train a ~100M+ model" shape;
expect TPU-scale hardware for a few hundred steps).

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/prefillonly_train_ck")
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    losses = train(args.arch, steps=args.steps, seq_len=args.seq_len,
                   global_batch=args.global_batch,
                   reduced=not args.full_size, ckpt_dir=args.ckpt_dir,
                   ckpt_every=20, log_every=5)
    print(f"\ntrained {len(losses)} steps: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"checkpoints in {args.ckpt_dir} (re-run to resume)")


if __name__ == "__main__":
    main()
