"""The paper's §6.2/§6.3 A/B/C/D scheduling example, step by step.

Shows why continuous JCT calibration gets 2 cache hits where FIFO and naive
SRJF get 1. Pure scheduling logic — no model needed.

    PYTHONPATH=src python examples/schedule_playground.py
"""
from repro.core.jct import LinearProxyJCT
from repro.core.prefix_cache import PrefixCache, token_chain
from repro.core.scheduler import Request, Scheduler

BLOCK = 4


def make_requests():
    P1 = list(range(100, 140))           # profile shared by A and D
    P2 = list(range(200, 248))           # profile shared by B and C
    mk = lambda toks, t, u: Request(n_input=len(toks), arrival=t,
                                    chain=token_chain(toks, BLOCK),
                                    tokens=toks, user_id=u)
    return [mk(P1 + [1] * 4, 0.000, "A"),    # 44 tokens (shortest)
            mk(P2 + [3] * 12, 0.001, "B"),   # 60
            mk(P2 + [2] * 4, 0.002, "C"),    # 52
            mk(P1 + [4] * 24, 0.003, "D")]   # 64 (longest)


def run(policy: str):
    cache = PrefixCache(60 // BLOCK, BLOCK)   # ~one request of space
    sched = Scheduler(policy, LinearProxyJCT(a=1.0, b=0.0), lam=0.0)
    q = make_requests()
    for r in q:
        r.n_cached_at_arrival = cache.match_len(r.chain)
    print(f"\n--- {policy} ---")
    now, hits = 0.0, 0
    while q:
        i = sched.pick(q, cache, now)
        r = q.pop(i)
        cached = cache.match_len(r.chain, now, touch=True)
        hits += cached > 0
        print(f"  t={now:.0f} run {r.user_id} ({r.n_input} tokens, "
              f"{cached} cached -> {r.n_input - cached} to prefill)")
        cache.insert(r.chain, r.n_input, now=now)
        now += 1
    print(f"  => {hits} cache hit(s)")
    return hits


if __name__ == "__main__":
    print("Requests: A=44tok, C=52, B=60, D=64; A/D share a 40-token "
          "profile, B/C share a 48-token one.\nCache holds ~one request.")
    h_fifo = run("fifo")
    h_srjf = run("srjf")
    h_cal = run("srjf_calibrated")
    print(f"\nFIFO: {h_fifo} hit(s), naive SRJF: {h_srjf} hit(s), "
          f"PrefillOnly (continuous calibration): {h_cal} hits — "
          "matches the paper's Figure 5.")
