"""Sharded, atomic, elastic checkpointing (no orbax dependency).

Layout (one directory per step):
    step_000123/
      manifest.json     tree structure, shapes, dtypes, CRCs, mesh snapshot
      arr_00000.npy ... one file per leaf (host-local shard in multi-host)
      COMMITTED         sentinel written LAST (atomic via rename)

Fault-tolerance properties:
  * atomic: readers only trust directories with the COMMITTED sentinel; a
    crash mid-save leaves a step_*.tmp directory that is garbage-collected
  * elastic: restore() re-shards onto whatever mesh is active now — arrays
    are saved unsharded (gathered) per host and re-placed with
    ``jax.device_put`` under the new sharding, so a 256-chip checkpoint
    restores onto 512 chips (or 8) unchanged
  * integrity: per-leaf CRC32 checked on load
  * retention: keep_last N (default 3) with safe GC
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[Any], Any]:
    return jax.tree_util.tree_flatten(tree)


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def save_checkpoint(path: str | Path, step: int, tree: Any,
                    extra: Optional[Dict] = None,
                    keep_last: int = 3) -> Path:
    """Synchronous sharded save. Returns the committed directory."""
    root = Path(path)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(tree)
    manifest: Dict[str, Any] = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extra": extra or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": _crc(arr),
        })
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMITTED").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)           # atomic on POSIX
    _gc(root, keep_last)
    return final


def _gc(root: Path, keep_last: int):
    committed = sorted(d for d in root.glob("step_*")
                       if (d / "COMMITTED").exists())
    for d in committed[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(d, ignore_errors=True)
    for d in root.glob("step_*.tmp"):
        shutil.rmtree(d, ignore_errors=True)


def latest_step(path: str | Path) -> Optional[int]:
    root = Path(path)
    if not root.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in root.glob("step_*")
             if (d / "COMMITTED").exists()]
    return max(steps) if steps else None


def restore_checkpoint(path: str | Path, template: Any,
                       step: Optional[int] = None,
                       shardings: Optional[Any] = None) -> Tuple[int, Any]:
    """Restore onto the CURRENT mesh (elastic re-shard).

    ``template`` provides the tree structure; ``shardings`` (same structure,
    NamedSharding leaves) re-places every array — pass the shardings of the
    new mesh and the checkpoint transparently re-shards.
    """
    root = Path(path)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_t, treedef = _flatten(template)
    if manifest["n_leaves"] != len(leaves_t):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, template has "
            f"{len(leaves_t)} — incompatible tree")
    # None leaves mean "host array, no placement" — keep them in the
    # flatten (tree_flatten drops bare None otherwise)
    sh_leaves = (jax.tree_util.tree_flatten(
                     shardings, is_leaf=lambda x: x is None)[0]
                 if shardings is not None else [None] * len(leaves_t))
    out = []
    for meta, tmpl, sh in zip(manifest["leaves"], leaves_t, sh_leaves):
        arr = np.load(d / meta["file"])
        if _crc(arr) != meta["crc32"]:
            raise IOError(f"CRC mismatch in {meta['file']} (corrupt shard)")
        want_shape = tuple(getattr(tmpl, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"shape mismatch {arr.shape} vs {want_shape}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Double-buffered background saver: snapshot to host, write off-thread.

    The training loop only blocks for the device->host copy; serialization
    overlaps the next steps. ``wait()`` before exit."""

    def __init__(self, path: str | Path, keep_last: int = 3):
        self.path = Path(path)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            save_checkpoint(self.path, step, host_tree, extra,
                            self.keep_last)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
