"""Pallas TPU kernel: sequence-blocked fused SwiGLU MLP.

This is hybrid prefilling (paper §4) pushed down to the kernel level: the
``(tokens, d_ff)`` gate/up intermediates — the paper's peak-memory villain
(Fig 3/4) — are tiled over (token-block, d_ff-block) and live ONLY in VMEM.
They are never materialized in HBM at all, a strictly stronger guarantee
than the graph-level ``lax.map`` chunking (which still writes chunk results
through HBM).

Tiling: grid (T/bt, F/bf), f-block innermost. Each step computes
    g = x_i @ Wg[:, j] ; u = x_i @ Wu[:, j] ; a = silu(g) * u
    acc_i += a @ Wd[j, :]
with acc in a f32 VMEM scratch written to the output on the last f-step —
the "output preallocation + in-place" optimizations of §4.3 are structural
here. MXU alignment: bt, bf multiples of 128 (ops.py pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_mlp_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *,
                      n_f_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    a = (jax.nn.silu(g) * u).astype(x.dtype)
    acc_ref[...] += jnp.dot(a, wd_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == n_f_blocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def fused_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, *, block_t: int = 256, block_f: int = 512,
              interpret: bool = True) -> jax.Array:
    """x: (T, D); w_gate/w_up: (D, F); w_down: (F, D) -> (T, D).

    Caller guarantees T % block_t == 0 and F % block_f == 0 (ops.py pads).
    """
    T, D = x.shape
    F = w_gate.shape[1]
    bt, bf = min(block_t, T), min(block_f, F)
    assert T % bt == 0 and F % bf == 0, (T, F, bt, bf)
    grid = (T // bt, F // bf)
    return pl.pallas_call(
        functools.partial(_fused_mlp_kernel, n_f_blocks=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, D), lambda i, j: (i, 0)),
            pl.BlockSpec((D, bf), lambda i, j: (0, j)),
            pl.BlockSpec((D, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, D), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
