"""Pallas TPU kernel: blocked causal flash attention (GQA / SWA / softcap /
segment-restricted prepacking).

Hybrid prefilling's counterpart guarantee (paper §4): attention is NOT
chunked — each (q-block, kv-block) tile streams through VMEM with online
softmax, so the (S, S) logits never exist and kernel efficiency is intact
(the paper's complaint about chunked prefill is precisely that it degrades
the attention kernel).

GQA without materializing repeated KV: the kv-head index of each q head is
resolved in the BlockSpec index_map (h // group), so HBM holds only
``num_kv_heads`` K/V copies.

Grid: (B, H, nq, nk), kv innermost. Causal + sliding-window block skipping
happens via ``pl.when`` on whole tiles — off-diagonal masked tiles cost 0
FLOPs (the structural half-compute win the dry-run hillclimb measures).

Prepacked prefill (arXiv:2404.09529 / BatchLLM): optional per-token
``seg_q``/``seg_k`` id arrays restrict attention to same-segment pairs so N
short requests share one contiguous forward. Tile skipping extends to
segments: a (q-block, kv-block) tile whose segment-id *ranges* cannot
intersect is skipped by the same ``pl.when`` mechanism as the causal skip,
so cross-segment tiles also cost 0 FLOPs. Padding tokens carry a negative
segment id, which doubles as the padded-KV mask (``kv_valid`` handles the
unsegmented case).

Prefix-aware packing (cache-HIT co-packing): optional per-token ``pos_q``/
``pos_k`` absolute-position arrays generalize the structural causal/window
masks. The KV side may then be the concatenation of a *gathered per-segment
cached-prefix KV buffer* and the fresh packed KV: prefix tokens carry their
segment's id and their absolute positions [0, prefix_len), fresh tokens carry
positions [prefix_len, n_input) — so each packed query segment attends
causally over its own cached prefix plus its own fresh tokens and nothing
else. Tile skipping stays intact: the causal skip becomes a dynamic
min/max-position range test (same pl.when mechanism), composed with the
segment-range skip, so a query block never touches another segment's prefix
tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
# padding-kv position sentinel (shared with the model-layer oracle and the
# engine): huge, power of two (f32-exact for the tile-skip reductions), so
# causal masks kill padded tokens and pure-padding tiles never run
PAD_POS = 1 << 30


def _make_kernel(bq, bk, nk, window, softcap, scale, causal, kv_valid,
                 segmented, positioned, tile_map):
    def kernel(*refs):
        it = iter(refs)
        q_ref, k_ref, v_ref = next(it), next(it), next(it)
        sq_ref = next(it) if segmented else None
        sk_ref = next(it) if segmented else None
        pq_ref = next(it) if positioned else None
        pk_ref = next(it) if positioned else None
        o_ref = next(it)
        map_ref = next(it) if tile_map else None
        m_ref, l_ref, acc_ref = next(it), next(it), next(it)

        i = pl.program_id(2)
        j = pl.program_id(3)

        @pl.when(j == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        run = jnp.asarray(True)
        if positioned:
            # Per-token absolute positions (prefix-aware packing: the KV side
            # may concatenate a gathered prefix buffer with the fresh packed
            # tokens, so structural tile positions are meaningless). The
            # causal/window skips become dynamic range tests over the tiles'
            # position min/max — padding kv tokens carry a huge position so
            # pure-padding tiles fail the causal test and never run.
            # f32 reductions: Mosaic has no integer reduce_min/max; positions
            # (< 2^24, plus the power-of-two pad value) are f32-exact.
            pq = pq_ref[0].astype(jnp.float32)              # (bq,)
            pk = pk_ref[0].astype(jnp.float32)              # (bk,)
            if causal:
                run = run & (jnp.min(pk) <= jnp.max(pq))
            if window > 0:
                run = run & (jnp.max(pk) >= jnp.min(pq) - window + 1)
        else:
            if causal:
                run = run & (j * bk <= i * bq + bq - 1)
            if window > 0:
                run = run & (j * bk + bk - 1 >= i * bq - window + 1)
        if kv_valid is not None:
            run = run & (j * bk < kv_valid)
        if segmented:
            # Packed layouts keep each segment contiguous, so a tile computes
            # real work only if the q-block's and kv-block's segment-id ranges
            # intersect AND the kv-block holds at least one real (id >= 0)
            # token. Data-dependent, but pl.when lowers it to a branch the
            # same way as the structural causal skip. (f32 reductions: see
            # above — segment ids are small ints, exactly representable.)
            sq = sq_ref[0].astype(jnp.float32)              # (bq,)
            sk = sk_ref[0].astype(jnp.float32)              # (bk,)
            run = run & (jnp.min(sq) <= jnp.max(sk))
            run = run & (jnp.max(sq) >= jnp.min(sk))
            run = run & (jnp.max(sk) >= 0)

        if tile_map:
            map_ref[0, 0, 0] = run.astype(jnp.int32)

        @pl.when(run)
        def _compute():
            q = q_ref[0, 0].astype(jnp.float32) * scale     # (bq, d)
            k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
            v = v_ref[0, 0].astype(jnp.float32)
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            if positioned:
                qpos = jnp.broadcast_to(pq_ref[0][:, None], (bq, bk))
                kpos = jnp.broadcast_to(pk_ref[0][None, :], (bq, bk))
            else:
                qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
                kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = jnp.ones((bq, bk), jnp.bool_)
            if causal:
                mask &= qpos >= kpos
            if window > 0:
                mask &= (qpos - kpos) < window
            if kv_valid is not None:
                struct_k = j * bk + jax.lax.broadcasted_iota(
                    jnp.int32, (bq, bk), 1)
                mask &= struct_k < kv_valid
            if segmented:
                sq = sq_ref[0]
                sk = sk_ref[0]
                mask &= sq[:, None] == sk[None, :]
                mask &= sk[None, :] >= 0
            s = jnp.where(mask, s, NEG_INF)
            m_prev = m_ref[...]                              # (bq, 1)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)                   # (bq, 1)
            l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
            acc_ref[...] = (acc_ref[...] * corr
                            + jax.lax.dot_general(
                                p.astype(v.dtype), v,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32))
            m_ref[...] = m_new

        @pl.when(j == nk - 1)
        def _flush():
            denom = jnp.maximum(l_ref[...], 1e-30)
            o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)

    return kernel


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    kv_valid: int | None = None,
                    seg_q: jax.Array | None = None,
                    seg_k: jax.Array | None = None,
                    pos_q: jax.Array | None = None,
                    pos_k: jax.Array | None = None,
                    block_q: int = 256, block_k: int = 256,
                    debug_tile_map: bool = False,
                    interpret: bool = True):
    """q: (B, H, Sq, d); k/v: (B, KV, Sk, d) with H % KV == 0 -> (B, H, Sq, d).

    ``kv_valid``: number of real kv columns (static); columns >= kv_valid are
    padding and are masked regardless of ``causal`` (ops.py pads to block
    multiples). ``seg_q``/``seg_k``: (B, Sq)/(B, Sk) int32 per-token segment
    ids for prepacked batches; attention is restricted to ``seg_q == seg_k``
    (composed with causal/window, which use *packed* positions — valid within
    a segment because segments are contiguous). Negative ids mark padding.

    ``pos_q``/``pos_k``: (B, Sq)/(B, Sk) int32 per-token ABSOLUTE positions —
    the prefix-aware packed path, where the KV side is concat(gathered
    per-segment prefix KV, fresh packed KV) and structural indices no longer
    encode order. Causal/window masks (and their tile skips, now dynamic
    min/max range tests) use these instead. Padding kv tokens should carry a
    huge position (and segment id -1) so they are masked and their tiles
    skipped. Requires ``seg_q``/``seg_k``.

    ``debug_tile_map=True`` additionally returns a (B, nq, nk) int32 map of
    tiles that executed (1) vs were skipped (0) — test/diagnostic only.

    Caller guarantees Sq % block_q == 0 and Sk % block_k == 0."""
    B, H, Sq, d = q.shape
    _, KV, Sk, _ = k.shape
    group = H // KV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    segmented = seg_q is not None
    assert segmented == (seg_k is not None), "seg_q and seg_k come together"
    positioned = pos_q is not None
    assert positioned == (pos_k is not None), "pos_q and pos_k come together"
    assert not positioned or segmented, "per-token positions require segments"
    nq, nk = Sq // bq, Sk // bk
    if scale is None:
        scale = d ** -0.5
    if kv_valid is not None and kv_valid >= Sk:
        kv_valid = None                     # no padded kv columns: no masking
    kernel = _make_kernel(bq, bk, nk, window, softcap, scale, causal,
                          kv_valid, segmented, positioned, debug_tile_map)
    in_specs = [
        pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        pl.BlockSpec((1, 1, bk, d),
                     lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        pl.BlockSpec((1, 1, bk, d),
                     lambda b, h, i, j, g=group: (b, h // g, j, 0)),
    ]
    args = [q, k, v]
    if segmented:
        in_specs.append(pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)))
        in_specs.append(pl.BlockSpec((1, bk), lambda b, h, i, j: (b, j)))
        args += [seg_q.astype(jnp.int32), seg_k.astype(jnp.int32)]
    if positioned:
        in_specs.append(pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)))
        in_specs.append(pl.BlockSpec((1, bk), lambda b, h, i, j: (b, j)))
        args += [pos_q.astype(jnp.int32), pos_k.astype(jnp.int32)]
    out_specs = pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0))
    out_shape = jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype)
    if debug_tile_map:
        out_specs = [out_specs,
                     pl.BlockSpec((1, 1, 1), lambda b, h, i, j: (b, i, j))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((B, nq, nk), jnp.int32)]
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    if debug_tile_map:
        return out[0], out[1]
    return out
