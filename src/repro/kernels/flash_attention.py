"""Pallas TPU kernel: blocked causal flash attention (GQA / SWA / softcap).

Hybrid prefilling's counterpart guarantee (paper §4): attention is NOT
chunked — each (q-block, kv-block) tile streams through VMEM with online
softmax, so the (S, S) logits never exist and kernel efficiency is intact
(the paper's complaint about chunked prefill is precisely that it degrades
the attention kernel).

GQA without materializing repeated KV: the kv-head index of each q head is
resolved in the BlockSpec index_map (h // group), so HBM holds only
``num_kv_heads`` K/V copies.

Grid: (B, H, nq, nk), kv innermost. Causal + sliding-window block skipping
happens via ``pl.when`` on whole tiles — off-diagonal masked tiles cost 0
FLOPs (the structural half-compute win the dry-run hillclimb measures).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _make_kernel(bq, bk, nk, window, softcap, scale, causal):
    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        i = pl.program_id(2)
        j = pl.program_id(3)

        @pl.when(j == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        run = jnp.asarray(True)
        if causal:
            run = run & (j * bk <= i * bq + bq - 1)
        if window > 0:
            run = run & (j * bk + bk - 1 >= i * bq - window + 1)

        @pl.when(run)
        def _compute():
            q = q_ref[0, 0].astype(jnp.float32) * scale     # (bq, d)
            k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
            v = v_ref[0, 0].astype(jnp.float32)
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = jnp.ones((bq, bk), jnp.bool_)
            if causal:
                mask &= qpos >= kpos
            if window > 0:
                mask &= (qpos - kpos) < window
            s = jnp.where(mask, s, NEG_INF)
            m_prev = m_ref[...]                              # (bq, 1)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)                   # (bq, 1)
            l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
            acc_ref[...] = (acc_ref[...] * corr
                            + jax.lax.dot_general(
                                p.astype(v.dtype), v,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32))
            m_ref[...] = m_new

        @pl.when(j == nk - 1)
        def _flush():
            denom = jnp.maximum(l_ref[...], 1e-30)
            o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)

    return kernel


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, d); k/v: (B, KV, Sk, d) with H % KV == 0 -> (B, H, Sq, d).

    Caller guarantees Sq % block_q == 0 and Sk % block_k == 0 (ops.py pads
    with fully-masked positions)."""
    B, H, Sq, d = q.shape
    _, KV, Sk, _ = k.shape
    group = H // KV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    if scale is None:
        scale = d ** -0.5
    kernel = _make_kernel(bq, bk, nk, window, softcap, scale, causal)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
