"""jit'd wrappers around the Pallas kernels: padding to block/MXU multiples,
GQA layout, backend selection (interpret=True everywhere except real TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention as _decode_kernel
from repro.kernels.flash_attention import PAD_POS
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.fused_mlp import fused_mlp as _mlp_kernel
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_dim(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f"))
def fused_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
              w_down: jax.Array, *, block_t: int = 256,
              block_f: int = 512) -> jax.Array:
    """x: (..., T, D) -> (..., T, D); pads T to block_t and F to block_f."""
    lead = x.shape[:-2]
    T, D = x.shape[-2:]
    xf = x.reshape(-1, D)
    bt = min(block_t, max(8, xf.shape[0]))
    xp = _pad_dim(xf, 0, bt)
    bf = min(block_f, w_gate.shape[1])
    wg = _pad_dim(w_gate, 1, bf)
    wu = _pad_dim(w_up, 1, bf)
    wd = _pad_dim(w_down, 0, bf)
    out = _mlp_kernel(xp, wg, wu, wd, block_t=bt, block_f=bf,
                      interpret=not _on_tpu())
    return out[: xf.shape[0]].reshape(*lead, T, D)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 256,
                    block_k: int = 256) -> jax.Array:
    """Layout: q (B, Sq, H, d), k/v (B, Sk, KV, d) — model-layer layout;
    transposed to the kernel's (B, heads, S, d) internally."""
    B, Sq, H, d = q.shape
    Sk = k.shape[1]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    qt = _pad_dim(qt, 2, bq)
    kt = _pad_dim(kt, 2, bk)
    vt = _pad_dim(vt, 2, bk)
    # padded kv columns must not contribute: mask them explicitly via
    # kv_valid — the causal mask alone covers them only when causal=True
    # (padded k rows have kpos > every real qpos), not for causal=False
    out = _flash_kernel(qt, kt, vt, causal=causal, window=window,
                        softcap=softcap, scale=d ** -0.5, kv_valid=Sk,
                        block_q=bq, block_k=bk, interpret=not _on_tpu())
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "block_q", "block_k"))
def packed_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           seg_ids: jax.Array, *, window: int = 0,
                           softcap: float = 0.0,
                           prefix_k: jax.Array | None = None,
                           prefix_v: jax.Array | None = None,
                           prefix_seg: jax.Array | None = None,
                           positions: jax.Array | None = None,
                           prefix_positions: jax.Array | None = None,
                           block_q: int = 256,
                           block_k: int = 256) -> jax.Array:
    """Segment-restricted causal self-attention over a prepacked sequence.

    Layout: q (B, S, H, d), k/v (B, S, KV, d), seg_ids (B, S) int32 — the
    per-token segment index of each packed request (negative = padding).
    Attention is causal *within* each segment and zero across segments;
    cross-segment tiles are skipped inside the kernel (0 FLOPs).

    Prefix-aware packing (cache-HIT co-packing): ``prefix_k``/``prefix_v``
    (B, P, KV, d) is a gathered buffer of each segment's CACHED prefix KV,
    ``prefix_seg`` (B, P) the owning segment of each prefix token (negative =
    padding), ``positions`` (B, S) each packed token's absolute position in
    its own request (restarting at prefix_len per segment), and
    ``prefix_positions`` (B, P) the prefix tokens' absolute positions. The
    kernel attends over concat(prefix KV, fresh KV) with per-token position
    masks; a query block skips another segment's prefix tiles the same way it
    skips its fresh tiles.
    """
    B, Sq, H, d = q.shape
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    seg = seg_ids.astype(jnp.int32)
    with_prefix = prefix_k is not None
    if with_prefix:
        assert prefix_v is not None and prefix_seg is not None
        assert positions is not None and prefix_positions is not None
        kt = jnp.concatenate([prefix_k.transpose(0, 2, 1, 3), kt], axis=2)
        vt = jnp.concatenate([prefix_v.transpose(0, 2, 1, 3), vt], axis=2)
    Sk = kt.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    qt = _pad_dim(qt, 2, bq)
    kt = _pad_dim(kt, 2, bk)
    vt = _pad_dim(vt, 2, bk)
    # pad segment ids with -1: padded tokens match nothing (real ids >= 0)
    seg_q = jnp.pad(seg, ((0, 0), (0, qt.shape[2] - Sq)),
                    constant_values=-1)
    seg_kv = (jnp.concatenate([prefix_seg.astype(jnp.int32), seg], axis=1)
              if with_prefix else seg)
    seg_k = jnp.pad(seg_kv, ((0, 0), (0, kt.shape[2] - Sk)),
                    constant_values=-1)
    pos_q = pos_k = None
    if with_prefix:
        pos = positions.astype(jnp.int32)
        pos_q = jnp.pad(pos, ((0, 0), (0, qt.shape[2] - Sq)))
        pos_kv = jnp.concatenate([prefix_positions.astype(jnp.int32), pos],
                                 axis=1)
        pos_k = jnp.pad(pos_kv, ((0, 0), (0, kt.shape[2] - Sk)),
                        constant_values=PAD_POS)
    out = _flash_kernel(qt, kt, vt, causal=True, window=window,
                        softcap=softcap, scale=d ** -0.5,
                        seg_q=seg_q, seg_k=seg_k, pos_q=pos_q, pos_k=pos_k,
                        block_q=bq, block_k=bk,
                        interpret=not _on_tpu())
    return out[:, :, :Sq].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("softcap", "block_s"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, softcap: float = 0.0,
                     block_s: int = 512) -> jax.Array:
    """q: (B, 1, H, d), caches: (B, S, KV, d), kv_len: (B,) -> (B, 1, H, d)."""
    B, _, H, d = q.shape
    S = k_cache.shape[1]
    KV = k_cache.shape[2]
    G = H // KV
    qh = q.reshape(B, KV, G, d)
    bs = min(block_s, S)
    kc = _pad_dim(k_cache, 1, bs)
    vc = _pad_dim(v_cache, 1, bs)
    out = _decode_kernel(qh, kc, vc, kv_len.astype(jnp.int32),
                         softcap=softcap, block_s=bs,
                         interpret=not _on_tpu())
    return out.reshape(B, 1, H, d)


@functools.partial(jax.jit, static_argnames=("eps", "block_t"))
def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            block_t: int = 256) -> jax.Array:
    """x: (..., D) -> (..., D); pads the token dim to block_t."""
    lead = x.shape[:-1]
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    bt = min(block_t, max(8, xf.shape[0]))
    xp = _pad_dim(xf, 0, bt)
    out = _rmsnorm_kernel(xp, weight, eps=eps, block_t=bt,
                          interpret=not _on_tpu())
    return out[: xf.shape[0]].reshape(*lead, D)
