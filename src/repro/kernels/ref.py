"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def fused_mlp_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                  w_down: jax.Array) -> jax.Array:
    g = jnp.dot(x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    a = (jax.nn.silu(g) * u).astype(x.dtype)
    return jnp.dot(a, w_down, preferred_element_type=jnp.float32).astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0,
                        scale: float | None = None) -> jax.Array:
    """q: (B, H, Sq, d); k/v: (B, KV, Sk, d) -> (B, H, Sq, d). Naive softmax."""
    B, H, Sq, d = q.shape
    _, KV, Sk, _ = k.shape
    group = H // KV
    if scale is None:
        scale = d ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   kk.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def packed_flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                               seg_ids: jax.Array, *, window: int = 0,
                               softcap: float = 0.0,
                               scale: float | None = None) -> jax.Array:
    """Prepacked segment-restricted causal attention, naive softmax.

    q: (B, H, S, d); k/v: (B, KV, S, d); seg_ids: (B, S) int32 (< 0 = pad)
    -> (B, H, S, d). Causal within segments, zero across them.
    """
    B, H, S, d = q.shape
    KV = k.shape[1]
    group = H // KV
    if scale is None:
        scale = d ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   kk.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    seg = seg_ids.astype(jnp.int32)
    segm = (seg[:, :, None] == seg[:, None, :]) & (seg[:, None, :] >= 0)
    mask = mask[None] & segm                       # (B, S, S)
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def packed_prefix_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                                seg_q: jax.Array, seg_k: jax.Array,
                                pos_q: jax.Array, pos_k: jax.Array, *,
                                window: int = 0, softcap: float = 0.0,
                                scale: float | None = None) -> jax.Array:
    """Prefix-aware packed attention, naive softmax (ground truth).

    q: (B, H, Sq, d); k/v: (B, KV, Sk, d) where the KV side is typically
    concat(gathered per-segment prefix KV, fresh packed KV). seg_q/seg_k:
    (B, Sq)/(B, Sk) segment ids (< 0 = pad); pos_q/pos_k: per-token absolute
    positions. Mask = same segment AND pos_q >= pos_k (AND window).
    """
    B, H, Sq, d = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    group = H // KV
    if scale is None:
        scale = d ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   kk.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pq = pos_q.astype(jnp.int32)[:, :, None]
    pk = pos_k.astype(jnp.int32)[:, None, :]
    mask = pq >= pk
    if window > 0:
        mask &= (pq - pk) < window
    sq = seg_q.astype(jnp.int32)
    sk = seg_k.astype(jnp.int32)
    mask &= (sq[:, :, None] == sk[:, None, :]) & (sk[:, None, :] >= 0)
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    # fully-masked rows (padding queries) produce a uniform softmax over
    # NEG_INF logits; zero them so comparisons see a deterministic value
    any_live = jnp.any(mask, axis=-1)[:, None, :, None]
    return jnp.where(any_live, out, 0.0).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         kv_len: jax.Array, *, softcap: float = 0.0,
                         scale: float | None = None) -> jax.Array:
    """q: (B, KV, G, d); caches: (B, S, KV, d); kv_len: (B,) -> (B, KV, G, d)."""
    B, KV, G, d = q.shape
    S = k_cache.shape[1]
    if scale is None:
        scale = d ** -0.5
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32) * scale,
                   k_cache.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    valid = jnp.arange(S)[None, None, None, :] < kv_len[:, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bskd->bkgd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, weight: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)
