"""Pallas TPU kernel: fused RMSNorm (token-blocked).

Small but on the decode critical path: every block applies two of these per
layer, and an unfused lowering reads the activation three times (square-sum,
scale, multiply). The fused kernel streams each (block, D) tile through VMEM
once. Token-wise => composes with hybrid prefilling chunking trivially.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))
                  ).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            block_t: int = 256, interpret: bool = True) -> jax.Array:
    """x: (T, D), weight: (D,) -> (T, D). Caller pads T to block_t."""
    T, D = x.shape
    bt = min(block_t, T)
    assert T % bt == 0, (T, bt)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(T // bt,),
        in_specs=[
            pl.BlockSpec((bt, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        interpret=interpret,
    )(x, weight)
