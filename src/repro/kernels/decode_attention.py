"""Pallas TPU kernel: flash-decoding (single query token vs a deep KV cache).

Grid: (B, KV, n_splits) — the KV sequence is split into tiles; each tile
updates online-softmax partials (m, l, acc) held in VMEM scratch, and the
last split normalizes and writes the (group, d) output for this kv head.
``kv_len`` arrives as a per-batch scalar and masks slots beyond the valid
length (ring-buffer SWA caches pass kv_len >= S so every slot is valid).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _make_kernel(bs: int, ns: int, scale: float, softcap: float):
    def kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        s_idx = pl.program_id(2)

        @pl.when(s_idx == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0, 0].astype(jnp.float32) * scale     # (G, d)
        k = k_ref[0, :, 0].astype(jnp.float32)          # (bs, d)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bs)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        slot = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(slot < len_ref[0], s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

        @pl.when(s_idx == ns - 1)
        def _flush():
            o_ref[0, 0] = (acc_ref[...]
                           / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)

    return kernel


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, softcap: float = 0.0,
                     scale: float | None = None, block_s: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q: (B, KV, G, d); caches: (B, S, KV, d); kv_len: (B,) int32
    -> (B, KV, G, d). Caller guarantees S % block_s == 0."""
    B, KV, G, d = q.shape
    _, S, _, _ = k_cache.shape
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    ns = S // bs
    if scale is None:
        scale = d ** -0.5
    kernel = _make_kernel(bs, ns, scale, softcap)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
            pl.BlockSpec((1, 1, G, d), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, d), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, q, k_cache, v_cache)
