"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block applied
every ``attn_every`` layers (shared weights, distinct KV per application).

Structure: ``num_layers`` mamba blocks grouped as (G groups x attn_every);
after each group the shared attention+MLP block runs. Simplification vs the
released checkpoints (concat-with-embedding input, per-application LoRA) is
recorded in DESIGN.md — the systems-relevant property (shared weights, hybrid
KV/state caching) is preserved.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.hybrid_prefill import chunked_softmax_xent, last_token_logits
from repro.models import layers as L
from repro.models.mamba2 import mamba_defs, mamba_prefill, mamba_decode
from repro.models.transformer import stack_defs, head_weight
from repro.runtime.sharding import pdef


def _n_groups(cfg: ModelConfig) -> int:
    assert cfg.num_layers % cfg.attn_every == 0, (cfg.num_layers, cfg.attn_every)
    return cfg.num_layers // cfg.attn_every


def model_defs(cfg: ModelConfig) -> Dict:
    mamba_block = {
        "ln": pdef((cfg.d_model,), ("d_model",), init="zeros"),
        "mamba": mamba_defs(cfg),
    }
    shared = {
        "ln1": pdef((cfg.d_model,), ("d_model",), init="zeros"),
        "ln2": pdef((cfg.d_model,), ("d_model",), init="zeros"),
        "attn": L.attention_defs(cfg),
        "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff_shared),
    }
    out: Dict[str, Any] = {
        "embed": L.embed_defs(cfg),
        # grouped (G, attn_every, ...) for the nested scan
        "blocks": stack_defs(stack_defs(mamba_block, cfg.attn_every),
                             _n_groups(cfg)),
        "shared": shared,
        "final_norm": pdef((cfg.d_model,), ("d_model",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = pdef((cfg.d_model, cfg.vocab_size),
                              ("d_model", "vocab"), init="scaled")
    return out


def _shared_attn_full(params: Dict, x: jax.Array, cfg: ModelConfig,
                      positions: jax.Array, kv_keep: int):
    sp = params["shared"]
    h = L.rms_norm(x, sp["ln1"])
    attn, k, v = L.attention_prefill(sp["attn"], h, cfg, positions=positions,
                                     chunk=cfg.hybrid_chunk)
    x = x + attn
    h = L.rms_norm(x, sp["ln2"])
    x = x + L.mlp_apply(sp["mlp"], h, chunk=cfg.hybrid_chunk)
    kv = (k[:, :kv_keep], v[:, :kv_keep]) if kv_keep > 0 else None
    return x, kv


def forward_full(params: Dict, cfg: ModelConfig, *,
                 tokens: Optional[jax.Array] = None,
                 embeds: Optional[jax.Array] = None,
                 kv_keep: int = 0, collect_state: bool = False,
                 remat: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    dtype = jnp.dtype(cfg.dtype)
    x = (L.embed_apply(params["embed"], tokens, dtype)
         if embeds is None else embeds.astype(dtype))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    keep = min(kv_keep, S)

    def mamba_one(x, bp):
        def fn(x):
            h = L.rms_norm(x, bp["ln"])
            out, hf, cf = mamba_prefill(bp["mamba"], h, cfg,
                                        chunk=cfg.hybrid_chunk)
            return x + out, (hf, cf)
        if remat:
            fn = jax.checkpoint(fn)
        x, st = fn(x)
        return x, st if collect_state else None

    def group(x, gp):
        x, states = jax.lax.scan(mamba_one, x, gp)      # inner: attn_every
        fn = lambda xx: _shared_attn_full(params, xx, cfg, positions, keep)
        if remat:
            fn = jax.checkpoint(fn)                     # shared block too
        x, kv = fn(x)
        return x, (states, kv)

    x, (states, kvs) = jax.lax.scan(group, x, params["blocks"])
    aux: Optional[Dict] = None
    if collect_state or keep > 0:
        aux = {}
        if collect_state:
            aux["ssm"], aux["conv"] = states[0], states[1]
        if keep > 0:
            aux["k"], aux["v"] = kvs[0], kvs[1]          # (G, B, keep, KV, hd)
    return L.rms_norm(x, params["final_norm"]), aux


def train_loss(params: Dict, cfg: ModelConfig, batch: Dict,
               num_shards: int = 1) -> jax.Array:
    hidden, _ = forward_full(params, cfg, tokens=batch.get("tokens"),
                             embeds=batch.get("embeds"), remat=cfg.remat)
    loss, cnt = chunked_softmax_xent(hidden, head_weight(params, cfg),
                                     batch["labels"], cfg.logits_chunk)
    return loss / jnp.maximum(cnt, 1.0)


def prefill(params: Dict, cfg: ModelConfig, batch: Dict, *,
            kv_keep: int = 0, num_shards: int = 1):
    hidden, aux = forward_full(params, cfg, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"), kv_keep=kv_keep,
                               collect_state=True)
    logits = last_token_logits(hidden, head_weight(params, cfg))
    return logits, aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False) -> Dict:
    G = _n_groups(cfg)
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    W = cfg.ssm_conv_width
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    shapes = {
        "ssm": ((G, cfg.attn_every, batch, H, P, N), jnp.float32),
        "conv": ((G, cfg.attn_every, batch, W - 1, conv_dim),
                 jnp.dtype(cfg.dtype)),
        "k": ((G, batch, max_len, KV, hd), jnp.dtype(cfg.dtype)),
        "v": ((G, batch, max_len, KV, hd), jnp.dtype(cfg.dtype)),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def cache_axes(cfg: ModelConfig) -> Dict:
    return {
        "ssm": ("layers", "layers", "batch", "ssm_heads", None, None),
        "conv": ("layers", "layers", "batch", None, "ssm_inner"),
        "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    }


def decode_step(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict, position: jax.Array, *, num_shards: int = 1):
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_apply(params["embed"], tokens[:, None], dtype)
    sp = params["shared"]

    def mamba_one(x, xs):
        bp, h, conv = xs
        hdd = L.rms_norm(x, bp["ln"])
        out, h, conv = mamba_decode(bp["mamba"], hdd, cfg, h=h, conv_state=conv)
        return x + out, (h, conv)

    def group(carry, xs):
        x, g, k_all, v_all = carry
        gp, h_g, conv_g = xs
        x, (h_g, conv_g) = jax.lax.scan(mamba_one, x, (gp, h_g, conv_g))
        h = L.rms_norm(x, sp["ln1"])
        # attention KV cache carried + updated in place (see transformer)
        kc = jax.lax.dynamic_index_in_dim(k_all, g, 0, False)
        vc = jax.lax.dynamic_index_in_dim(v_all, g, 0, False)
        attn, kc, vc = L.attention_decode(sp["attn"], h, cfg,
                                          position=position, k_cache=kc,
                                          v_cache=vc, ring=False)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, kc, g, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, vc, g, 0)
        x = x + attn
        h = L.rms_norm(x, sp["ln2"])
        x = x + L.mlp_apply(sp["mlp"], h)
        return (x, g + 1, k_all, v_all), (h_g, conv_g)

    (x, _, k_all, v_all), ys = jax.lax.scan(
        group, (x, 0, cache["k"], cache["v"]),
        (params["blocks"], cache["ssm"], cache["conv"]))
    new_cache = {"ssm": ys[0], "conv": ys[1], "k": k_all, "v": v_all}
    hidden = L.rms_norm(x, params["final_norm"])
    logits = last_token_logits(hidden, head_weight(params, cfg))
    return logits, new_cache
