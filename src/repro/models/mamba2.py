"""Mamba2 (SSD — state-space duality) blocks. [arXiv:2405.21060]

Chunked SSD: within a chunk the recurrence is evaluated as a (Q, Q) masked
attention-like product; across chunks a scan carries the (H, P, N) state.
The scan processes ONE chunk at a time so the (B, H, Q, Q) intra-chunk matrix
never exists for more than one chunk — the SSM analogue of hybrid prefilling.

PrefillOnly applicability (DESIGN.md §Arch-applicability): attention-free —
no KV cache exists, so suffix-KV discard is vacuous; the O(1) per-layer state
doubles as the prefix cache (state checkpoints at block boundaries). The
in/out projections are token-wise and run under hybrid chunking.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.hybrid_prefill import chunked_map
from repro.runtime.sharding import constrain, pdef


def mamba_defs(cfg: ModelConfig) -> Dict:
    D, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, W = cfg.ssm_heads, cfg.ssm_conv_width
    conv_dim = di + 2 * N
    return {
        "in_z": pdef((D, di), ("d_model", "ssm_inner"), init="scaled"),
        "in_x": pdef((D, di), ("d_model", "ssm_inner"), init="scaled"),
        "in_B": pdef((D, N), ("d_model", "state"), init="scaled"),
        "in_C": pdef((D, N), ("d_model", "state"), init="scaled"),
        "in_dt": pdef((D, H), ("d_model", "ssm_heads"), init="scaled"),
        "conv_w": pdef((W, conv_dim), ("conv", "ssm_inner"), init="scaled"),
        "conv_b": pdef((conv_dim,), ("ssm_inner",), init="zeros"),
        "A_log": pdef((H,), ("ssm_heads",), init="zeros"),
        "D": pdef((H,), ("ssm_heads",), init="ones"),
        "dt_bias": pdef((H,), ("ssm_heads",), init="zeros"),
        "norm": pdef((di,), ("ssm_inner",), init="zeros"),
        "out": pdef((di, D), ("ssm_inner", "d_model"), init="scaled"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C), w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):  # W is tiny (4): unrolled taps beat a conv op on TPU
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out + b.astype(jnp.float32)


def ssd_scan(x: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array,
             dt: jax.Array, chunk: int,
             h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. x: (B,S,H,P), dA: (B,S,H) (negative log-decay increments),
    Bm/Cm: (B,S,N), dt: (B,S,H). Returns (y: (B,S,H,P), final state (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // Q

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(B, nc, Q, *a.shape[2:]), 1, 0)

    xs = (to_chunks(x), to_chunks(dA), to_chunks(Bm), to_chunks(Cm), to_chunks(dt))
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        x_c, dA_c, B_c, C_c, dt_c = inp          # (B,Q,...)
        cum = jnp.cumsum(dA_c, axis=1)           # (B,Q,H)
        # contribution of the incoming state (inter-chunk)
        y_off = jnp.einsum("bqn,bhpn,bqh->bqhp", C_c.astype(jnp.float32), h,
                           jnp.exp(cum))
        # intra-chunk masked "attention"
        seg = cum[:, :, None, :] - cum[:, None, :, :]           # (B,Q,Q,H) i-j
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bqn,bsn->bqs", C_c.astype(jnp.float32),
                            B_c.astype(jnp.float32))
        M = scores[..., None] * L * dt_c[:, None, :, :]          # dt at source
        y_diag = jnp.einsum("bqsh,bshp->bqhp", M, x_c.astype(jnp.float32))
        # state handoff
        decay_end = jnp.exp(cum[:, -1:, :] - cum)                # (B,Q,H)
        h_new = (h * jnp.exp(cum[:, -1])[:, :, None, None]
                 + jnp.einsum("bqn,bqh,bqhp->bhpn", B_c.astype(jnp.float32),
                              dt_c * decay_end, x_c.astype(jnp.float32)))
        return h_new, y_off + y_diag

    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nc * Q, H, P)
    if pad:
        y = y[:, :S]
    return y, h_final


def mamba_prefill(p: Dict, u: jax.Array, cfg: ModelConfig, *,
                  chunk: int = 0, h0: Optional[jax.Array] = None,
                  conv0: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence Mamba2 block. u: (B, S, D).
    Returns (out, final_ssm_state (B,H,P,N), final_conv_state (B,W-1,conv_dim)).
    """
    B, S, D = u.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    W = cfg.ssm_conv_width

    def in_proj(uc):
        return jnp.concatenate(
            [uc @ p["in_z"], uc @ p["in_x"], uc @ p["in_B"], uc @ p["in_C"],
             uc @ p["in_dt"]], axis=-1)

    zxbcdt = chunked_map(in_proj, u, chunk)
    z, xr, Bm, Cm, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N],
                                  axis=-1)
    xBC = jnp.concatenate([xr, Bm, Cm], axis=-1)
    if conv0 is not None:
        xBC_ext = jnp.concatenate([conv0.astype(xBC.dtype), xBC], axis=1)
        conv_out = _causal_conv(xBC_ext, p["conv_w"], p["conv_b"])[:, W - 1:]
    else:
        conv_out = _causal_conv(xBC, p["conv_w"], p["conv_b"])
        # left-pad so the returned conv state is always (B, W-1, Cd)
        xBC_ext = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    conv_state = xBC_ext[:, -(W - 1):, :]
    xBC = jax.nn.silu(conv_out).astype(u.dtype)
    xr, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    xh = xr.reshape(B, S, H, P)
    xh = constrain(xh, ("batch", "seq", "ssm_heads", None))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,)
    y, h_final = ssd_scan(xh, dt * A, Bm, Cm, dt, cfg.ssm_chunk, h0=h0)
    y = y + (p["D"].astype(jnp.float32))[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di)
    from repro.models.layers import rms_norm
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype), p["norm"])
    out = chunked_map(lambda yc: yc @ p["out"], y, chunk)
    return constrain(out, ("batch", "seq", "d_model")), h_final, conv_state.astype(u.dtype)


def mamba_decode(p: Dict, u: jax.Array, cfg: ModelConfig, *,
                 h: jax.Array, conv_state: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token step. u: (B, 1, D); h: (B,H,P,N); conv_state: (B,W-1,Cd)."""
    B = u.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    W = cfg.ssm_conv_width
    u1 = u[:, 0, :]
    z = u1 @ p["in_z"]
    xr = u1 @ p["in_x"]
    Bm = u1 @ p["in_B"]
    Cm = u1 @ p["in_C"]
    dt = u1 @ p["in_dt"]
    xBC = jnp.concatenate([xr, Bm, Cm], axis=-1)                 # (B, Cd)
    window = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)  # (B,W,Cd)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    new_conv_state = window[:, 1:, :].astype(conv_state.dtype)
    xBC = jax.nn.silu(conv_out)
    xr, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    xh = xr.reshape(B, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                      # (B,H)
    h_new = (h * decay[:, :, None, None]
             + jnp.einsum("bn,bh,bhp->bhpn", Bm, dt, xh))
    y = jnp.einsum("bn,bhpn->bhp", Cm, h_new)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, di)
    from repro.models.layers import rms_norm
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype), p["norm"])
    out = (y @ p["out"])[:, None, :]
    return out.astype(u.dtype), h_new, new_conv_state
