"""Decoder-only transformer covering the dense / moe / vlm / audio families,
including gemma2's alternating local(SWA)/global attention + logit softcaps.

All models scan over layer-stacked parameters so HLO size (and therefore
compile time on this 1-core container) is independent of depth.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.hybrid_prefill import (chunked_softmax_xent,
                                       last_token_logits,
                                       packed_last_logits)
from repro.models import layers as L
from repro.models.moe import moe_defs, moe_apply
from repro.runtime.sharding import pdef, ParamDef, is_paramdef_leaf


# --------------------------------------------------------------------------
# parameter definitions
# --------------------------------------------------------------------------

def stack_defs(defs: Any, n: int) -> Any:
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale),
        defs, is_leaf=is_paramdef_leaf)


def block_defs(cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    out = {
        "ln1": pdef((D,), ("d_model",), init="zeros"),
        "ln2": pdef((D,), ("d_model",), init="zeros"),
        "attn": L.attention_defs(cfg),
    }
    if cfg.is_moe:
        out["moe"] = moe_defs(cfg)
    else:
        out["mlp"] = L.mlp_defs(D, cfg.d_ff)
    return out


def model_defs(cfg: ModelConfig) -> Dict:
    out: Dict[str, Any] = {"embed": L.embed_defs(cfg)}
    if cfg.local_global:
        half = cfg.num_layers // 2
        out["blocks_local"] = stack_defs(block_defs(cfg), half)
        out["blocks_global"] = stack_defs(block_defs(cfg), half)
    else:
        out["blocks"] = stack_defs(block_defs(cfg), cfg.num_layers)
    out["final_norm"] = pdef((cfg.d_model,), ("d_model",), init="zeros")
    if not cfg.tie_embeddings:
        out["lm_head"] = pdef((cfg.d_model, cfg.vocab_size),
                              ("d_model", "vocab"), init="scaled")
    return out


def _remat_groups(n_layers: int) -> int:
    """Largest divisor of n_layers that is <= ~sqrt(n_layers)*1.5."""
    best = 1
    limit = int(math.sqrt(n_layers) * 1.5)
    for g in range(2, n_layers):
        if n_layers % g == 0 and g <= limit:
            best = g
    return best


def head_weight(params: Dict, cfg: ModelConfig) -> jax.Array:
    w = (params["embed"]["tok"].T if cfg.tie_embeddings
         else params["lm_head"])
    dt = jnp.dtype(cfg.dtype)
    return w.astype(dt) if w.dtype != dt else w


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------

def _cast_block(bp: Dict, dtype) -> Dict:
    """Per-layer weight cast (fp8 storage -> compute dtype); no-op at bf16."""
    dt = jnp.dtype(dtype)
    return jax.tree_util.tree_map(
        lambda a: a.astype(dt) if a.dtype != dt else a, bp)


def _block_full(bp: Dict, x: jax.Array, cfg: ModelConfig, *,
                positions: jax.Array, window: int, chunk: int,
                num_shards: int, seg_ids: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    bp = _cast_block(bp, cfg.dtype)
    h = L.rms_norm(x, bp["ln1"])
    attn, k, v = L.attention_prefill(bp["attn"], h, cfg, positions=positions,
                                     window=window, chunk=chunk,
                                     seg_ids=seg_ids)
    x = x + attn
    h = L.rms_norm(x, bp["ln2"])
    if cfg.is_moe:
        m = moe_apply(bp["moe"], h, cfg, num_shards=num_shards,
                      hybrid_chunk=chunk)
    else:
        m = L.mlp_apply(bp["mlp"], h, chunk=chunk)
    return x + m, (k, v)


def forward_full(params: Dict, cfg: ModelConfig, *,
                 tokens: Optional[jax.Array] = None,
                 embeds: Optional[jax.Array] = None,
                 kv_keep: int = 0, num_shards: int = 1,
                 remat: bool = False,
                 positions: Optional[jax.Array] = None,
                 seg_ids: Optional[jax.Array] = None,
                 kv_indices: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """Returns (final-normed hidden (B,S,D), kv tree or None).

    ``kv_keep`` is the PrefillOnly prefix budget: only the first ``kv_keep``
    tokens' KV leave each layer (suffix KV discard — the rest is freed by XLA
    as soon as the layer's attention is done, because it is not a scan
    output). This is the LAYER-WISE discard the memory hierarchy is built
    on: at any instant at most ONE layer's full-length K/V is live, so peak
    prefill memory prices one transient layer plus the kept slice —
    ``core.kv_policy.KVLifecycle`` owns the keep arithmetic callers pass in
    here, and ``MemoryModel.peak_bytes(..., kv_keep=...)`` prices exactly
    this shape.

    Prepacked prefill: ``positions`` (B, S) overrides the default arange —
    packed batches restart RoPE positions at every segment boundary — and
    ``seg_ids`` (B, S) restricts attention to same-segment pairs.
    ``kv_indices`` (K,) generalizes the prefix budget for packed batches:
    each layer's KV scan output is the GATHER of those token positions
    instead of a prefix slice, so per-segment keep windows scattered through
    the packed sequence cost K stacked tokens, not S (suffix discard keeps
    its memory bound under packing). Overrides ``kv_keep`` when given.
    """
    dtype = jnp.dtype(cfg.dtype)
    if embeds is None:
        x = L.embed_apply(params["embed"], tokens, dtype)
        if cfg.local_global:           # gemma-style embedding scale
            x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    else:
        x = embeds.astype(dtype)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    chunk = cfg.hybrid_chunk
    keep = min(kv_keep, S)
    if kv_indices is not None:
        keep = kv_indices.shape[0]     # drives only the kv-is-kept checks

    def run_block(x, bp, window):
        x, (k, v) = _block_full(bp, x, cfg, positions=positions,
                                window=window, chunk=chunk,
                                num_shards=num_shards, seg_ids=seg_ids)
        # keep the prefix KV in compute dtype — rope's f32 internals must
        # not leak into the (layers, B, keep, KV, hd) scan output stack
        if kv_indices is not None:
            kv = (jnp.take(k, kv_indices, axis=1).astype(dtype),
                  jnp.take(v, kv_indices, axis=1).astype(dtype))
        elif keep > 0:
            kv = (k[:, :keep].astype(dtype), v[:, :keep].astype(dtype))
        else:
            kv = None
        return x, kv

    if cfg.local_global:
        def pair(x, lps):
            lp_local, lp_global = lps
            fn1 = lambda x: run_block(x, lp_local, cfg.sliding_window)
            fn2 = lambda x: run_block(x, lp_global, 0)
            if remat:
                fn1, fn2 = jax.checkpoint(fn1), jax.checkpoint(fn2)
            x, kv_l = fn1(x)
            x, kv_g = fn2(x)
            return x, (kv_l, kv_g)

        x, kvs = jax.lax.scan(pair, x,
                              (params["blocks_local"], params["blocks_global"]))
        kv = None if keep == 0 else {
            "local_k": kvs[0][0], "local_v": kvs[0][1],
            "global_k": kvs[1][0], "global_v": kvs[1][1]}
    else:
        def body(x, bp):
            fn = lambda x: run_block(x, bp, cfg.sliding_window)
            if remat:
                fn = jax.checkpoint(fn)
            return fn(x)

        if jnp.dtype(cfg.param_dtype).itemsize == 1 and not remat:
            # fp8 serving: index layers from the closure so the per-layer
            # upcast's operand is loop-VARIANT — scanning over the stacked
            # weights as xs lets XLA hoist the cast and materialize a full
            # bf16 copy of the model (measured +16 GB on granite prefill)
            def body_idx(x, l):
                bp = jax.tree_util.tree_map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, l, 0, False),
                    params["blocks"])
                return run_block(x, bp, cfg.sliding_window)

            x, kvs = jax.lax.scan(body_idx, x,
                                  jnp.arange(cfg.num_layers))
            kv = None if keep == 0 else {"k": kvs[0], "v": kvs[1]}
            return L.rms_norm(x, params["final_norm"]), kv

        G = _remat_groups(cfg.num_layers) if (remat and keep == 0) else 1
        if G > 1:
            # 2-level remat: only G ~ sqrt(L) group inputs are saved across
            # the forward; each group recomputes its K layers (which are
            # themselves block-checkpointed) during backward. Cuts the
            # dominant (L, B, S, D) saved-activation stack by K.
            K = cfg.num_layers // G
            grouped = jax.tree_util.tree_map(
                lambda a: a.reshape(G, K, *a.shape[1:]), params["blocks"])

            @jax.checkpoint
            def group_fn(x, gp):
                x, _ = jax.lax.scan(body, x, gp)
                return x, None

            x, _ = jax.lax.scan(group_fn, x, grouped)
            kv = None
        else:
            x, kvs = jax.lax.scan(body, x, params["blocks"])
            kv = None if keep == 0 else {"k": kvs[0], "v": kvs[1]}

    return L.rms_norm(x, params["final_norm"]), kv


def train_loss(params: Dict, cfg: ModelConfig, batch: Dict,
               num_shards: int = 1) -> jax.Array:
    hidden, _ = forward_full(params, cfg, tokens=batch.get("tokens"),
                             embeds=batch.get("embeds"), kv_keep=0,
                             num_shards=num_shards, remat=cfg.remat)
    loss, cnt = chunked_softmax_xent(hidden, head_weight(params, cfg),
                                     batch["labels"], cfg.logits_chunk,
                                     final_softcap=cfg.final_softcap)
    return loss / jnp.maximum(cnt, 1.0)


def prefill(params: Dict, cfg: ModelConfig, batch: Dict, *,
            kv_keep: int = 0, num_shards: int = 1,
            last_index: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Optional[Dict]]:
    """PrefillOnly serving prefill: (last-token logits (B, V), prefix KV)."""
    hidden, kv = forward_full(params, cfg, tokens=batch.get("tokens"),
                              embeds=batch.get("embeds"), kv_keep=kv_keep,
                              num_shards=num_shards)
    logits = last_token_logits(hidden, head_weight(params, cfg),
                               last_index=last_index,
                               final_softcap=cfg.final_softcap)
    return logits, kv


def prefill_packed(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                   seg_ids: jax.Array, positions: jax.Array,
                   last_indices: jax.Array, *, kv_keep: int = 0,
                   num_shards: int = 1,
                   kv_indices: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, Optional[Dict]]:
    """Prepacked prefill: N requests packed into ONE contiguous sequence.

    tokens/seg_ids/positions: (1, S) — the packed sequence, its per-token
    segment index (negative = padding slack), and per-token positions that
    restart at 0 on every segment boundary (RoPE sees each request at its
    own offsets). ``last_indices``: (N,) packed index of each segment's last
    token. Returns (per-segment last-token logits (N, V), KV tree: the first
    ``kv_keep`` packed tokens, or — preferred for suffix discard, which is
    per-segment rather than a packed-sequence prefix — the gather of
    ``kv_indices`` (K,) packed positions, which the caller slices per
    segment for cache inserts at solo-path memory cost (K kept tokens, not
    S).

    Attention is causal within each segment and zero across segments, so the
    result matches N independent ``prefill`` calls while the MXU sees one
    dense sequence (prepacking, arXiv:2404.09529): padding-bucket waste is
    recovered as throughput, which PrefillOnly's single-token output makes
    safe — each request needs only its own last-row logits.
    """
    hidden, kv = forward_full(params, cfg, tokens=tokens, kv_keep=kv_keep,
                              num_shards=num_shards, positions=positions,
                              seg_ids=seg_ids, kv_indices=kv_indices)
    logits = packed_last_logits(hidden, head_weight(params, cfg),
                                last_indices,
                                final_softcap=cfg.final_softcap)
    return logits, kv


def prefill_with_prefix(params: Dict, cfg: ModelConfig, batch: Dict,
                        prefix_kv: Dict, prefix_len: int, *,
                        kv_keep: int = 0, num_shards: int = 1,
                        last_index: Optional[jax.Array] = None):
    """Prefill of a SUFFIX given a cached prefix's KV (prefix-cache hit path).

    tokens/embeds cover positions [prefix_len, prefix_len+S); every layer
    attends over concat(prefix KV, fresh suffix KV). Returns last-token
    logits + the suffix KV to extend the cache with (up to ``kv_keep`` total
    tokens — suffix discard). Dense/vlm/audio/moe families, full attention
    (window archs take the full-attention path here; engine demos are dense).
    """
    dtype = jnp.dtype(cfg.dtype)
    if batch.get("embeds") is None:
        x = L.embed_apply(params["embed"], batch["tokens"], dtype)
    else:
        x = batch["embeds"].astype(dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(prefix_len + jnp.arange(S, dtype=jnp.int32),
                                 (B, S))
    chunk = cfg.hybrid_chunk
    keep_new = max(0, min(kv_keep, prefix_len + S) - prefix_len)

    def body(x, xs):
        bp, pk, pv = xs
        h = L.rms_norm(x, bp["ln1"])
        q, k, v = L._qkv_project(bp["attn"], h, cfg, positions, chunk)
        k_full = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v_full = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        out = L.blocked_attention(q, k_full, v_full, window=cfg.sliding_window,
                                  softcap=cfg.attn_softcap, q_offset=prefix_len)
        out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
        out = out @ bp["attn"]["wo"]
        x = x + out
        h = L.rms_norm(x, bp["ln2"])
        if cfg.is_moe:
            m = moe_apply(bp["moe"], h, cfg, num_shards=num_shards,
                          hybrid_chunk=chunk)
        else:
            m = L.mlp_apply(bp["mlp"], h, chunk=chunk)
        return x + m, (k[:, :keep_new], v[:, :keep_new])

    x, kvs = jax.lax.scan(body, x, (params["blocks"], prefix_kv["k"],
                                    prefix_kv["v"]))
    hidden = L.rms_norm(x, params["final_norm"])
    logits = last_token_logits(hidden, head_weight(params, cfg),
                               last_index=last_index,
                               final_softcap=cfg.final_softcap)
    return logits, {"k": kvs[0], "v": kvs[1]}


def prefill_packed_with_prefix(params: Dict, cfg: ModelConfig,
                               tokens: jax.Array, positions: jax.Array,
                               last_indices: jax.Array, prefix_kv: Dict,
                               prefix_pos: jax.Array, seg_qidx: jax.Array,
                               inv_idx: jax.Array, *,
                               num_shards: int = 1,
                               kv_indices: Optional[jax.Array] = None
                               ) -> Tuple[jax.Array, Optional[Dict]]:
    """Prepacked prefill of N SUFFIXES, each over its own cached prefix KV
    (the packed cache-HIT path: prefix sharers / hit requests co-packed).

    Hybrid layout — prepacking's win is in the token-wise (linear) layers,
    so they run on the packed (1, S) sequence; attention runs BATCHED per
    segment, (N, smax) queries against each segment's own gathered
    (N, pmax) prefix KV plus its own fresh tokens, as a handful of dense
    einsums. (A flat segment-masked formulation — see the Pallas kernel and
    ``blocked_attention``'s positioned mode — computes q-block x
    whole-prefix-buffer tiles: with short suffixes every q block spans many
    segments, no prefix tile can skip, and XLA-on-CPU tile overhead
    dominates. The batched form does exactly sum-of-segment work.)

    tokens (1, S): packed suffix tokens. ``positions`` (1, S): per-token
    RoPE positions restarting at each segment's own ``prefix_len`` (RoPE
    sees every suffix at its true offsets — per-segment q offsets).
    ``last_indices`` (N,): packed index of each segment's last token.
    ``prefix_kv``: {"k","v"} (L, N, pmax, KV, hd) — segment n's cached
    prefix KV in row n, zero-padded to pmax. ``prefix_pos`` (N, pmax):
    absolute positions of the prefix tokens, padding = a huge value (killed
    by the causal mask). ``seg_qidx`` (N, smax): packed index of segment
    n's j-th suffix token, -1 = padding. ``inv_idx`` (S,): flat
    (n * smax + slot) of each packed position (scatter-back map; slack
    positions may point anywhere). The result matches N independent
    ``prefill_with_prefix`` calls.

    Returns (per-segment last-token logits (N, V), fresh-KV tree gathered
    at ``kv_indices`` (K,) packed positions — the per-segment suffix keep
    windows, which the caller slices for cache inserts at solo-path memory
    cost). Dense/vlm/audio/moe families (same coverage as
    ``prefill_with_prefix``).
    """
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_apply(params["embed"], tokens, dtype)
    B, S, _ = x.shape
    N, smax = seg_qidx.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    chunk = cfg.hybrid_chunk
    scale = hd ** -0.5
    window = cfg.sliding_window
    softcap = cfg.attn_softcap
    idx = jnp.clip(seg_qidx, 0, S - 1)             # (N, smax)
    kvalid = seg_qidx >= 0                         # padded slots: dead keys
    posb = positions[0][idx]                       # (N, smax) abs positions
    ppos = prefix_pos.astype(jnp.int32)            # (N, pmax)
    # masks are layer-invariant: build once
    mask_p = posb[:, :, None] >= ppos[:, None, :]  # (N, smax, pmax)
    mask_f = ((posb[:, :, None] >= posb[:, None, :])
              & kvalid[:, None, :])                # (N, smax, smax)
    if window > 0:
        mask_p &= (posb[:, :, None] - ppos[:, None, :]) < window
        mask_f &= (posb[:, :, None] - posb[:, None, :]) < window

    def body(x, xs):
        bp, pk, pv = xs
        h = L.rms_norm(x, bp["ln1"])
        q, k, v = L._qkv_project(bp["attn"], h, cfg, positions, chunk)
        qb = q[0][idx].reshape(N, smax, KV, G, hd)
        qb = qb.astype(jnp.float32) * scale
        kb, vb = k[0][idx], v[0][idx]              # (N, smax, KV, hd)
        s_p = jnp.einsum("nqkgd,npkd->nkgqp", qb,
                         pk.astype(jnp.float32))   # (N,KV,G,smax,pmax)
        s_f = jnp.einsum("nqkgd,nskd->nkgqs", qb,
                         kb.astype(jnp.float32))   # (N,KV,G,smax,smax)
        if softcap:
            s_p = softcap * jnp.tanh(s_p / softcap)
            s_f = softcap * jnp.tanh(s_f / softcap)
        s_p = jnp.where(mask_p[:, None, None], s_p, L.NEG_INF)
        s_f = jnp.where(mask_f[:, None, None], s_f, L.NEG_INF)
        m = jnp.maximum(jnp.max(s_p, axis=-1), jnp.max(s_f, axis=-1))
        p_p = jnp.exp(s_p - m[..., None])
        p_f = jnp.exp(s_f - m[..., None])
        l = jnp.sum(p_p, axis=-1) + jnp.sum(p_f, axis=-1)
        o = (jnp.einsum("nkgqp,npkd->nkgqd", p_p, pv.astype(jnp.float32))
             + jnp.einsum("nkgqs,nskd->nkgqd", p_f, vb.astype(jnp.float32)))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        flat = o.transpose(0, 3, 1, 2, 4).reshape(N * smax, H * hd)
        out = flat[inv_idx][None].astype(x.dtype)  # back to packed (1, S, .)
        out = out @ bp["attn"]["wo"]
        x = x + out
        h = L.rms_norm(x, bp["ln2"])
        if cfg.is_moe:
            mo = moe_apply(bp["moe"], h, cfg, num_shards=num_shards,
                           hybrid_chunk=chunk)
        else:
            mo = L.mlp_apply(bp["mlp"], h, chunk=chunk)
        if kv_indices is not None:
            kv = (jnp.take(k, kv_indices, axis=1).astype(dtype),
                  jnp.take(v, kv_indices, axis=1).astype(dtype))
        else:
            kv = (jnp.zeros((B, 0) + k.shape[2:], dtype),
                  jnp.zeros((B, 0) + v.shape[2:], dtype))
        return x + mo, kv

    x, kvs = jax.lax.scan(body, x, (params["blocks"], prefix_kv["k"],
                                    prefix_kv["v"]))
    hidden = L.rms_norm(x, params["final_norm"])
    logits = packed_last_logits(hidden, head_weight(params, cfg),
                                last_indices,
                                final_softcap=cfg.final_softcap)
    kv = None if kv_indices is None else {"k": kvs[0], "v": kvs[1]}
    return logits, kv


# --------------------------------------------------------------------------
# decode (one token against a KV cache)
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False) -> Dict:
    """KV cache tree. SWA-only archs get a ring buffer bounded by the window
    (this is what makes mixtral's long_500k cell runnable); gemma2 gets a
    ring for local layers + full cache for global layers."""
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    dtype = jnp.dtype(cfg.dtype)

    def mk(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    if cfg.local_global:
        half = cfg.num_layers // 2
        w = min(cfg.sliding_window, max_len)
        return {"local_k": mk((half, batch, w, KV, hd)),
                "local_v": mk((half, batch, w, KV, hd)),
                "global_k": mk((half, batch, max_len, KV, hd)),
                "global_v": mk((half, batch, max_len, KV, hd))}
    s = max_len
    if cfg.sliding_window:
        s = min(cfg.sliding_window, max_len)
    return {"k": mk((cfg.num_layers, batch, s, KV, hd)),
            "v": mk((cfg.num_layers, batch, s, KV, hd))}


def cache_axes(cfg: ModelConfig) -> Dict:
    """Logical sharding axes matching ``init_cache``'s tree structure."""
    kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    if cfg.local_global:
        return {"local_k": kv, "local_v": kv, "global_k": kv, "global_v": kv}
    return {"k": kv, "v": kv}


def _block_decode(bp: Dict, x: jax.Array, cfg: ModelConfig, *,
                  position: jax.Array, kc: jax.Array, vc: jax.Array,
                  ring: bool, num_shards: int):
    bp = _cast_block(bp, cfg.dtype)
    h = L.rms_norm(x, bp["ln1"])
    attn, kc, vc = L.attention_decode(bp["attn"], h, cfg, position=position,
                                      k_cache=kc, v_cache=vc, ring=ring)
    x = x + attn
    h = L.rms_norm(x, bp["ln2"])
    if cfg.is_moe:
        m = moe_apply(bp["moe"], h, cfg, num_shards=num_shards)
    else:
        m = L.mlp_apply(bp["mlp"], h)
    return x + m, kc, vc


def decode_step(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict, position: jax.Array, *, num_shards: int = 1
                ) -> Tuple[jax.Array, Dict]:
    """tokens: (B,) int32; position: (B,) int32 (uniform). -> (logits, cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_apply(params["embed"], tokens[:, None], dtype)
    if cfg.local_global:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    ring = bool(cfg.sliding_window)

    # The cache rides in the scan CARRY with per-layer dynamic updates, NOT
    # as xs/ys — scan ys are double-buffered by XLA, which would cost a full
    # extra cache copy per step (measured: 2.6x cache in temp).
    def upd(buf, sl, l):
        return jax.lax.dynamic_update_index_in_dim(buf, sl, l, 0)

    if cfg.local_global:
        def pair(carry, xs):
            x, l, lk_a, lv_a, gk_a, gv_a = carry
            lp_l, lp_g = xs
            x, lk, lv = _block_decode(
                lp_l, x, cfg, position=position,
                kc=jax.lax.dynamic_index_in_dim(lk_a, l, 0, False),
                vc=jax.lax.dynamic_index_in_dim(lv_a, l, 0, False),
                ring=True, num_shards=num_shards)
            x, gk, gv = _block_decode(
                lp_g, x, cfg, position=position,
                kc=jax.lax.dynamic_index_in_dim(gk_a, l, 0, False),
                vc=jax.lax.dynamic_index_in_dim(gv_a, l, 0, False),
                ring=False, num_shards=num_shards)
            return (x, l + 1, upd(lk_a, lk, l), upd(lv_a, lv, l),
                    upd(gk_a, gk, l), upd(gv_a, gv, l)), None

        init = (x, 0, cache["local_k"], cache["local_v"],
                cache["global_k"], cache["global_v"])
        (x, _, lk_a, lv_a, gk_a, gv_a), _ = jax.lax.scan(
            pair, init, (params["blocks_local"], params["blocks_global"]))
        new_cache = {"local_k": lk_a, "local_v": lv_a,
                     "global_k": gk_a, "global_v": gv_a}
    else:
        def body(carry, bp):
            x, l, k_a, v_a = carry
            x, kc, vc = _block_decode(
                bp, x, cfg, position=position,
                kc=jax.lax.dynamic_index_in_dim(k_a, l, 0, False),
                vc=jax.lax.dynamic_index_in_dim(v_a, l, 0, False),
                ring=ring, num_shards=num_shards)
            return (x, l + 1, upd(k_a, kc, l), upd(v_a, vc, l)), None

        # weights stay scan-xs: slices are loop-variant so the per-layer fp8
        # upcast in _block_decode cannot be hoisted; closure-capture instead
        # makes them loop INVARIANTS, which XLA COPIES into the loop state
        # (measured +15.7 GB on mixtral decode)
        (x, _, k_a, v_a), _ = jax.lax.scan(
            body, (x, 0, cache["k"], cache["v"]), params["blocks"])
        new_cache = {"k": k_a, "v": v_a}

    hidden = L.rms_norm(x, params["final_norm"])
    logits = last_token_logits(hidden, head_weight(params, cfg),
                               final_softcap=cfg.final_softcap)
    return logits, new_cache
