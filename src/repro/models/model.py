"""Model zoo dispatcher — one uniform API over all families.

API (all pure functions closed over the config):
    defs()                          ParamDef tree (shapes+logical axes+init)
    train_loss(params, batch, num_shards)      scalar loss
    prefill(params, batch, kv_keep, num_shards) -> (last logits, prefix cache)
    decode_step(params, tokens, cache, position, num_shards) -> (logits, cache)
    init_cache(batch, max_len, abstract)
    input_specs(shape_cfg)          ShapeDtypeStruct stand-ins for the dry-run
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import hybrid as hybrid_model
from repro.models import ssm_model
from repro.models import transformer as tfm


def cast_params(params: Any, dtype) -> Any:
    """Cast float params to the compute dtype (mixed precision: fp32 master
    weights live in the optimizer; every step computes in cfg.dtype)."""
    dtype = jnp.dtype(dtype)

    def cast(a):
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating):
            return a.astype(dtype)
        return a

    return jax.tree_util.tree_map(cast, params)


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    defs: Callable[[], Any]
    train_loss: Callable[..., jax.Array]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]


def build(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "ssm":
        mod = ssm_model
    elif cfg.family == "hybrid":
        mod = hybrid_model
    else:  # dense / moe / vlm / audio share the transformer implementation
        mod = tfm

    def _cast(params):
        # 1-byte (fp8) weights: casting the whole tree would materialize a
        # full bf16 copy in HBM — the per-layer cast inside each scan body
        # (models/transformer._cast_block) handles those instead.
        if jnp.dtype(cfg.param_dtype).itemsize == 1:
            return params
        return cast_params(params, cfg.dtype)

    return ModelAPI(
        cfg=cfg,
        defs=lambda: mod.model_defs(cfg),
        train_loss=lambda params, batch, num_shards=1:
            mod.train_loss(_cast(params), cfg, batch, num_shards=num_shards),
        prefill=lambda params, batch, kv_keep=0, num_shards=1:
            mod.prefill(_cast(params), cfg, batch, kv_keep=kv_keep,
                        num_shards=num_shards),
        decode_step=lambda params, tokens, cache, position, num_shards=1:
            mod.decode_step(_cast(params), cfg, tokens, cache, position,
                            num_shards=num_shards),
        init_cache=lambda batch, max_len, abstract=False:
            mod.init_cache(cfg, batch, max_len, abstract=abstract),
    )


def input_specs(cfg: ModelConfig, shp: ShapeConfig,
                api: Optional[ModelAPI] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Weak-type-correct, shardable, zero allocation. Modality frontends are
    STUBS: the vlm family receives precomputed patch embeddings; the audio
    family receives precomputed EnCodec codec-token ids.
    """
    B, S = shp.global_batch, shp.seq_len
    i32 = jnp.dtype(jnp.int32)
    act = jnp.dtype(cfg.dtype)

    if shp.kind == "train":
        if cfg.embed_inputs:
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        else:  # vlm stub: precomputed patch embeddings
            batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), act)}
        batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return {"batch": batch}

    if shp.kind == "prefill":
        if cfg.embed_inputs:
            return {"batch": {"tokens": jax.ShapeDtypeStruct((B, S), i32)}}
        return {"batch": {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), act)}}

    # decode: one new token against a seq_len-deep cache
    api = api or build(cfg)
    cache = api.init_cache(B, S, abstract=True)
    return {
        "tokens": jax.ShapeDtypeStruct((B,), i32),
        "cache": cache,
        "position": jax.ShapeDtypeStruct((B,), i32),
    }


def make_batch(cfg: ModelConfig, batch_size: int, seq_len: int,
               rng: jax.Array, kind: str = "train") -> Dict[str, jax.Array]:
    """Concrete random batch (smoke tests / examples)."""
    kt, kl = jax.random.split(rng)
    if cfg.embed_inputs:
        batch: Dict[str, jax.Array] = {
            "tokens": jax.random.randint(kt, (batch_size, seq_len), 0,
                                         cfg.vocab_size, dtype=jnp.int32)}
    else:
        batch = {"embeds": jax.random.normal(
            kt, (batch_size, seq_len, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype)) * 0.02}
    if kind == "train":
        batch["labels"] = jax.random.randint(kl, (batch_size, seq_len), 0,
                                             cfg.vocab_size, dtype=jnp.int32)
    return batch
