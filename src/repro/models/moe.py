"""Mixture-of-Experts MLP — sort-based dispatch, shard-local, with an
explicit-collective (shard_map) distributed path.

TPU-friendly design:
  * tokens stay LOCAL to a device for the argsort / scatter / gather that
    implement dispatch (no cross-device sort; the classic GShard one-hot
    dispatch tensor would be O(T*E*C) and is avoided entirely).
  * expert FFN weights are (E, D, F) with ``d_ff`` sharded over the model
    axis (TP). Under shard_map the collective schedule is pinned by hand:
    weights enter d_model-GATHERED (cheap: one layer's shards), each device
    computes its token shard against its F-shard, tokens are combined
    locally, and ONE psum over the model axis reduces the (tokens, D)
    partials. Letting SPMD choose here partial-summed the (E, C, F) expert
    intermediates over the data axis instead — measured 2.8 TB/step on
    mixtral prefill.
  * capacity C = ceil(T_local*K/E * capacity_factor); overflow tokens drop
    to the residual path (standard dropping MoE). Routing is per-token, so
    hybrid prefilling (chunking the token axis) remains exact.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.hybrid_prefill import chunked_map
from repro.models.layers import mlp_defs, mlp_apply
from repro.runtime.sharding import active_mesh, constrain, pdef


def moe_defs(cfg: ModelConfig) -> Dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    defs = {
        "router": pdef((D, E), ("d_model", "experts"), init="scaled"),
        "w_gate": pdef((E, D, F), ("experts", "d_model", "d_ff"), init="scaled"),
        "w_up": pdef((E, D, F), ("experts", "d_model", "d_ff"), init="scaled"),
        "w_down": pdef((E, F, D), ("experts", "d_ff", "d_model"), init="scaled"),
    }
    if cfg.shared_expert:
        defs["shared"] = mlp_defs(D, F)
    return defs


def _capacity(t_local: int, cfg: ModelConfig) -> int:
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    c = int(math.ceil(t_local * K / E * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # >=8, rounded up to a multiple of 8


def _dispatch_compute(xr: jax.Array, router, w_gate, w_up, w_down,
                      cfg: ModelConfig) -> jax.Array:
    """Device-local sort-based MoE on a (t, D) token shard. Returns the
    (t, D) output, PARTIAL over any sharded d_ff dim of the weights."""
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    t, D = xr.shape
    C = _capacity(t, cfg)
    logits = (xr @ router).astype(jnp.float32)            # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, K)            # (t, K)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    flat_e = gate_idx.reshape(t * K)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    tok = order // K                                      # source token
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos = jnp.arange(t * K) - seg_start[sorted_e]
    keep = pos < C
    dest = jnp.where(keep, sorted_e * C + pos, E * C)     # E*C = dump row

    buf = jnp.zeros((E * C + 1, D), xr.dtype).at[dest].set(xr[tok])
    h = buf[: E * C].reshape(E, C, D)
    g = jnp.einsum("ecd,edf->ecf", h, w_gate)
    u = jnp.einsum("ecd,edf->ecf", h, w_up)
    act = jax.nn.silu(g.astype(jnp.float32)).astype(xr.dtype) * u
    out_e = jnp.einsum("ecf,efd->ecd", act, w_down).reshape(E * C, D)

    gathered = jnp.where(keep[:, None],
                         out_e[jnp.minimum(dest, E * C - 1)], 0.0)
    contrib = gathered * gate_w.reshape(t * K)[order][:, None].astype(xr.dtype)
    return jnp.zeros((t, D), xr.dtype).at[tok].add(contrib)


def _mesh_axes(rules_entry, mesh) -> Tuple[str, ...]:
    if rules_entry is None:
        return ()
    if isinstance(rules_entry, str):
        rules_entry = (rules_entry,)
    return tuple(a for a in rules_entry if a in mesh.shape)


def moe_apply(p: Dict, x: jax.Array, cfg: ModelConfig, *,
              num_shards: int = 1, hybrid_chunk: int = 0) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    from repro.runtime.sharding import _CTX  # rules of the active context
    B, S, D = x.shape
    T = B * S
    mesh = active_mesh()

    dt = x.dtype
    castw = lambda a: a.astype(dt) if a.dtype != dt else a

    def local(xr):
        fn = lambda xc: _dispatch_compute(xc, castw(p["router"]),
                                          castw(p["w_gate"]),
                                          castw(p["w_up"]),
                                          castw(p["w_down"]), cfg)
        return chunked_map(fn, xr, hybrid_chunk, axis=0)

    if mesh is None:
        # single-device path (CPU tests / one-chip instances)
        out = local(x.reshape(T, D)).reshape(B, S, D)
    else:
        rules = _CTX.rules or {}
        tok_axes = _mesh_axes(rules.get("shards"), mesh)
        tok_size = 1
        for a in tok_axes:
            tok_size *= mesh.shape[a]
        if T % max(tok_size, 1) != 0:
            tok_axes, tok_size = (), 1      # tiny batches: replicate tokens
        ff_axes = _mesh_axes(rules.get("d_ff"), mesh)
        ff_axes = tuple(a for a in ff_axes if a not in tok_axes)
        w_spec = P(None, None, ff_axes if ff_axes else None)
        wd_spec = P(None, ff_axes if ff_axes else None, None)

        def local_fn(xr, router, wg, wu, wd):
            # cast AFTER the shard_map boundary: fp8 weights cross the
            # all-gather at 1 byte/param, upcast locally per layer
            cast = lambda a: a.astype(xr.dtype) if a.dtype != xr.dtype else a
            router, wg, wu, wd = map(cast, (router, wg, wu, wd))
            out = chunked_map(
                lambda xc: _dispatch_compute(xc, router, wg, wu, wd, cfg),
                xr, hybrid_chunk, axis=0)
            if ff_axes:
                # ONE reduction of the combined (t, D) partials — never of
                # the (E, C, F) expert intermediates
                out = jax.lax.psum(out, ff_axes)
            return out

        from repro.runtime.sharding import shard_map
        out = shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(tok_axes if tok_axes else None, None),
                      P(None, None), w_spec, w_spec, wd_spec),
            out_specs=P(tok_axes if tok_axes else None, None),
        )(x.reshape(T, D), p["router"], p["w_gate"], p["w_up"], p["w_down"])
        out = out.reshape(B, S, D)

    if cfg.shared_expert:
        out = out + mlp_apply(p["shared"], x, chunk=hybrid_chunk)
    return constrain(out, ("batch", "seq", "d_model"))
