"""Pure-SSM model (mamba2-130m): scan over stacked Mamba2 blocks.

No attention => no KV cache. The serving "cache" is the per-layer SSM state
plus the conv tail — O(1) in sequence length, which is why long_500k runs for
this family (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.hybrid_prefill import chunked_softmax_xent, last_token_logits
from repro.models import layers as L
from repro.models.mamba2 import mamba_defs, mamba_prefill, mamba_decode
from repro.models.transformer import stack_defs, head_weight
from repro.runtime.sharding import pdef


def model_defs(cfg: ModelConfig) -> Dict:
    block = {
        "ln": pdef((cfg.d_model,), ("d_model",), init="zeros"),
        "mamba": mamba_defs(cfg),
    }
    out: Dict[str, Any] = {
        "embed": L.embed_defs(cfg),
        "blocks": stack_defs(block, cfg.num_layers),
        "final_norm": pdef((cfg.d_model,), ("d_model",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = pdef((cfg.d_model, cfg.vocab_size),
                              ("d_model", "vocab"), init="scaled")
    return out


def forward_full(params: Dict, cfg: ModelConfig, *,
                 tokens: Optional[jax.Array] = None,
                 embeds: Optional[jax.Array] = None,
                 collect_state: bool = False, remat: bool = False,
                 init_state: Optional[Dict] = None
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    dtype = jnp.dtype(cfg.dtype)
    x = (L.embed_apply(params["embed"], tokens, dtype)
         if embeds is None else embeds.astype(dtype))

    def body(x, xs):
        if init_state is None:
            bp = xs
            h0 = conv0 = None
        else:
            bp, h0, conv0 = xs
        def fn(x):
            h = L.rms_norm(x, bp["ln"])
            out, hf, cf = mamba_prefill(bp["mamba"], h, cfg,
                                        chunk=cfg.hybrid_chunk,
                                        h0=h0, conv0=conv0)
            return x + out, (hf, cf)
        if remat:
            fn = jax.checkpoint(fn)
        x, (hf, cf) = fn(x)
        return x, (hf, cf) if collect_state else None

    xs = params["blocks"] if init_state is None else (
        params["blocks"], init_state["ssm"], init_state["conv"])
    x, states = jax.lax.scan(body, x, xs)
    state = None
    if collect_state:
        state = {"ssm": states[0], "conv": states[1]}
    return L.rms_norm(x, params["final_norm"]), state


def train_loss(params: Dict, cfg: ModelConfig, batch: Dict,
               num_shards: int = 1) -> jax.Array:
    hidden, _ = forward_full(params, cfg, tokens=batch.get("tokens"),
                             embeds=batch.get("embeds"), remat=cfg.remat)
    loss, cnt = chunked_softmax_xent(hidden, head_weight(params, cfg),
                                     batch["labels"], cfg.logits_chunk)
    return loss / jnp.maximum(cnt, 1.0)


def prefill(params: Dict, cfg: ModelConfig, batch: Dict, *,
            kv_keep: int = 0, num_shards: int = 1,
            init_state: Optional[Dict] = None):
    """kv_keep is accepted for API uniformity; the state is O(1) so there is
    nothing to discard (the PrefillOnly suffix-discard is vacuous here)."""
    hidden, state = forward_full(params, cfg, tokens=batch.get("tokens"),
                                 embeds=batch.get("embeds"),
                                 collect_state=True, init_state=init_state)
    logits = last_token_logits(hidden, head_weight(params, cfg))
    return logits, state


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False) -> Dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    W = cfg.ssm_conv_width
    shapes = {
        "ssm": ((cfg.num_layers, batch, H, P, N), jnp.float32),
        "conv": ((cfg.num_layers, batch, W - 1, conv_dim), jnp.dtype(cfg.dtype)),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def cache_axes(cfg: ModelConfig) -> Dict:
    return {
        "ssm": ("layers", "batch", "ssm_heads", None, None),
        "conv": ("layers", "batch", None, "ssm_inner"),
    }


def decode_step(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                cache: Dict, position: jax.Array, *, num_shards: int = 1):
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed_apply(params["embed"], tokens[:, None], dtype)

    def body(x, xs):
        bp, h, conv = xs
        hdd = L.rms_norm(x, bp["ln"])
        out, h, conv = mamba_decode(bp["mamba"], hdd, cfg, h=h, conv_state=conv)
        return x + out, (h, conv)

    x, (hs, convs) = jax.lax.scan(body, x,
                                  (params["blocks"], cache["ssm"], cache["conv"]))
    hidden = L.rms_norm(x, params["final_norm"])
    logits = last_token_logits(hidden, head_weight(params, cfg))
    return logits, {"ssm": hs, "conv": convs}
