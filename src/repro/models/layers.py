"""Layer library: RMSNorm, RoPE, blocked attention (GQA/SWA/softcap), SwiGLU.

Design rules
  * pure functions over param dicts (ParamDef-declared, see runtime/sharding)
  * fp32 softmax/norm internals, activations in cfg.dtype
  * attention is BLOCKED (flash-style online softmax via lax.map/scan) so the
    lowered HLO never materializes (S, S) logits — required for the 32k/500k
    dry-run cells to fit HBM
  * every token-wise op optionally runs under hybrid prefilling
    (core.hybrid_prefill.chunked_map)
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.hybrid_prefill import chunked_map
# PAD_POS: padding-kv position sentinel, shared with the Pallas kernel (the
# oracle and kernel must agree on what "huge" means for the causal skip)
from repro.kernels.flash_attention import PAD_POS  # noqa: F401  (re-export)
from repro.runtime.sharding import constrain, pdef

NEG_INF = -1e30


# --------------------------------------------------------------------------
# norms / rope
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def rope_apply(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, d), positions: (B, S) int32. Split-half RoPE."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs     # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# blocked attention (pure-JAX flash; the Pallas kernel mirrors this oracle)
# --------------------------------------------------------------------------

def _apply_mask(logits: jax.Array, qpos: jax.Array, kpos: jax.Array,
                kv_len: Optional[jax.Array], window: int) -> jax.Array:
    """logits: (..., qb, kb); qpos (qb,), kpos (kb,) absolute positions."""
    mask = qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    return jnp.where(mask, logits, NEG_INF)


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      softcap: float = 0.0, q_offset: int = 0,
                      q_block: int = 512, kv_block: int = 1024,
                      head_scale: Optional[float] = None,
                      seg_ids: Optional[jax.Array] = None,
                      seg_ids_k: Optional[jax.Array] = None,
                      pos_q: Optional[jax.Array] = None,
                      pos_k: Optional[jax.Array] = None) -> jax.Array:
    """Flash-style attention. q: (B,Sq,H,d), k/v: (B,Skv,KV,d) -> (B,Sq,H,d).

    Online-softmax over KV blocks (lax.scan) x lax.map over Q blocks: the HLO
    holds at most (qb, kb) logits per (batch, head) at a time.

    ``seg_ids`` (B, S) int32 enables prepacked prefill: attention is
    restricted to same-segment (q, k) pairs, so N packed requests attend only
    to themselves (negative ids mark padding). Self-attention (Sq==Skv) with
    packed positions, which agree with per-segment positions because segments
    are contiguous — unless ``seg_ids_k`` is also given.

    ``seg_ids_k`` (B, Skv): KV-side segment ids when the KV side differs from
    the query side — the prefix-aware packed path, where KV is
    concat(gathered per-segment CACHED prefix KV, fresh packed KV). Then
    ``pos_q``/``pos_k`` (B, Sq)/(B, Skv) per-token ABSOLUTE positions replace
    the structural causal/window positions (each query sits at
    prefix_len + local offset; its prefix tokens at [0, prefix_len)), and the
    causal tile skip becomes a dynamic min/max position range test, so a
    query block never computes another segment's prefix tiles.
    """
    B, Sq, H, d = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = head_scale if head_scale is not None else 1.0 / math.sqrt(d)
    if seg_ids is not None and seg_ids_k is None:
        assert Sq == Skv, "segment-restricted attention is self-attention"
        seg_ids_k = seg_ids
    positioned = pos_q is not None
    assert positioned == (pos_k is not None), "pos_q and pos_k come together"
    assert not positioned or seg_ids is not None, \
        "per-token positions require segment ids"

    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    # pad to block multiples (masked out below via absolute positions)
    pad_q = (-Sq) % qb
    pad_k = (-Skv) % kb
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    seg_q = seg_k = None
    if seg_ids is not None:
        seg_q = jnp.pad(seg_ids.astype(jnp.int32), ((0, 0), (0, pad_q)),
                        constant_values=-1)
        seg_k = jnp.pad(seg_ids_k.astype(jnp.int32), ((0, 0), (0, pad_k)),
                        constant_values=-1)
    pq_full = pk_full = None
    if positioned:
        pq_full = jnp.pad(pos_q.astype(jnp.int32), ((0, 0), (0, pad_q)))
        pk_full = jnp.pad(pos_k.astype(jnp.int32), ((0, 0), (0, pad_k)),
                          constant_values=PAD_POS)
    nq, nk = q.shape[1] // qb, k.shape[1] // kb
    qg = q.reshape(B, nq, qb, KV, G, d)
    kv_len = jnp.asarray(Skv)  # mask out k-padding

    def one_q_block(i):
        q_blk = qg[:, i].astype(jnp.float32) * scale      # (B,qb,KV,G,d)
        qpos = q_offset + i * qb + jnp.arange(qb)
        sq_blk = (jax.lax.dynamic_slice_in_dim(seg_q, i * qb, qb, axis=1)
                  if seg_q is not None else None)
        pq_blk = (jax.lax.dynamic_slice_in_dim(pq_full, i * qb, qb, axis=1)
                  if positioned else None)

        def kv_step(carry, j):
            kpos = j * kb + jnp.arange(kb)
            sk_blk = (jax.lax.dynamic_slice_in_dim(seg_k, j * kb, kb, axis=1)
                      if sq_blk is not None else None)
            pk_blk = (jax.lax.dynamic_slice_in_dim(pk_full, j * kb, kb, axis=1)
                      if positioned else None)

            def compute(carry):
                # K/V slices live INSIDE the branch: a skipped tile must not
                # even pay the (B, kb, KV, d) copies out of the full buffer
                # (they dominate the dead-tile cost of long gathered-prefix
                # buffers; the id/position slices above are kb ints each)
                k_j = jax.lax.dynamic_slice_in_dim(k, j * kb, kb, axis=1)
                v_j = jax.lax.dynamic_slice_in_dim(v, j * kb, kb, axis=1)
                m, l, acc = carry
                s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk,
                               k_j.astype(jnp.float32))    # (B,KV,G,qb,kb)
                if softcap:
                    s = softcap * jnp.tanh(s / softcap)
                if positioned:
                    pmask = jnp.ones((B, qb, kb), jnp.bool_)
                    if causal:
                        pmask &= pq_blk[:, :, None] >= pk_blk[:, None, :]
                    if window > 0:
                        pmask &= (pq_blk[:, :, None]
                                  - pk_blk[:, None, :]) < window
                    pmask &= (kpos < kv_len)[None, None, :]
                    s = jnp.where(pmask[:, None, None], s, NEG_INF)
                elif causal:
                    s = _apply_mask(s, qpos, kpos, kv_len, window)
                else:
                    s = jnp.where((kpos < kv_len)[None, :], s, NEG_INF)
                if sq_blk is not None:
                    segm = ((sq_blk[:, :, None] == sk_blk[:, None, :])
                            & (sk_blk[:, None, :] >= 0))   # (B, qb, kb)
                    s = jnp.where(segm[:, None, None], s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                pv = jnp.einsum("bkgqs,bskd->bkgqd", p,
                                v_j.astype(jnp.float32))
                acc_new = acc * corr[..., None] + pv
                return m_new, l_new, acc_new

            # tile-level skipping (XLA twin of the Pallas kernel's pl.when):
            # a tile that the causal/window/kv-padding/segment masks would
            # fully erase contributes exactly nothing to the online softmax
            # (exp underflows to 0 against any live row max), so branch it
            # out with lax.cond — fully-masked tiles cost 0 FLOPs. This is
            # what turns prepacked batches into sum-of-segment attention
            # cost instead of quadratic-in-packed-length.
            live = jnp.asarray(True)
            if positioned:
                # dynamic position ranges stand in for the structural causal
                # skip; PAD_POS on padded kv keeps pure-padding tiles dead
                if causal:
                    live = live & (jnp.min(pk_blk) <= jnp.max(pq_blk))
                if window > 0:
                    live = live & (jnp.max(pk_blk) > jnp.min(pq_blk) - window)
            else:
                if causal:
                    live = live & (j * kb <= qpos[-1])
                if window > 0:
                    live = live & (j * kb + kb - 1 > qpos[0] - window)
            live = live & (j * kb < kv_len)
            if sq_blk is not None:
                live = live & (jnp.min(sq_blk) <= jnp.max(sk_blk))
                live = live & (jnp.max(sq_blk) >= jnp.min(sk_blk))
                live = live & (jnp.max(sk_blk) >= 0)
            return jax.lax.cond(live, compute, lambda c: c, carry), None

        m0 = jnp.full((B, KV, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qb, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,KV,G,qb,d)
        return out.transpose(0, 3, 1, 2, 4)                # (B,qb,KV,G,d)

    outs = jax.lax.map(one_q_block, jnp.arange(nq))        # (nq,B,qb,KV,G,d)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * qb, H, d)
    if pad_q:
        out = out[:, :Sq]
    return out.astype(q.dtype)


def packed_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            softcap: float = 0.0, q_offset: int = 0,
                            block: int = 512,
                            head_scale: Optional[float] = None) -> jax.Array:
    """Causal attention with EXACT lower-triangle FLOPs (tile pair-packing).

    The naive blocked schedule computes all nq*nk tiles and masks half of
    them away — 2x wasted MXU work. Here q-block pairs (p, nq-1-p) share one
    scan of nq+1 tile-steps: step t serves (q=p, kv=t) while t<=p and
    (q=nq-1-p, kv=t-p-1) after, so every executed tile lies in the lower
    triangle: nq/2 * (nq+1) tiles == the triangle exactly. This is the
    "balanced causal swizzle" used by splash-style TPU kernels, expressed at
    the XLA level so the dry-run FLOP counts reflect it.
    """
    B, Sq, H, d = q.shape
    _, Skv, KV, _ = k.shape
    assert Sq == Skv, "packed schedule assumes self-attention"
    G = H // KV
    scale = head_scale if head_scale is not None else 1.0 / math.sqrt(d)
    bb = min(block, Sq)
    pad = (-Sq) % bb
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = q.shape[1] // bb
    if n % 2 == 1:                     # need an even number of q blocks
        q = jnp.pad(q, ((0, 0), (0, bb), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, bb), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, bb), (0, 0), (0, 0)))
        n += 1
    S_pad = n * bb
    qg = q.reshape(B, n, bb, KV, G, d)
    kv_valid = jnp.asarray(Skv)

    def one_pair(p):
        lo, hi = p, n - 1 - p
        q_lo = qg[:, lo].astype(jnp.float32) * scale   # (B,bb,KV,G,d)
        q_hi = qg[:, hi].astype(jnp.float32) * scale

        def step(carry, t):
            m, l, acc = carry                          # (2,B,KV,G,bb[,d])
            use_hi = t > p
            qi = jnp.where(use_hi, hi, lo)
            kj = jnp.where(use_hi, t - p - 1, t)
            slot = use_hi.astype(jnp.int32)
            q_blk = jnp.where(use_hi, q_hi, q_lo)
            k_j = jax.lax.dynamic_slice_in_dim(k, kj * bb, bb, axis=1)
            v_j = jax.lax.dynamic_slice_in_dim(v, kj * bb, bb, axis=1)
            qpos = q_offset + qi * bb + jnp.arange(bb)
            kpos = kj * bb + jnp.arange(bb)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk,
                           k_j.astype(jnp.float32))
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            s = _apply_mask(s, qpos, kpos, kv_valid, 0)
            m_prev = m[slot]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            pmat = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l[slot] * corr + jnp.sum(pmat, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", pmat,
                            v_j.astype(jnp.float32))
            acc_new = acc[slot] * corr[..., None] + pv
            m = m.at[slot].set(m_new)
            l = l.at[slot].set(l_new)
            acc = acc.at[slot].set(acc_new)
            return (m, l, acc), None

        m0 = jnp.full((2, B, KV, G, bb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((2, B, KV, G, bb), jnp.float32)
        a0 = jnp.zeros((2, B, KV, G, bb, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(n + 1))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (2,B,KV,G,bb,d)
        return out.transpose(0, 1, 4, 2, 3, 5)          # (2,B,bb,KV,G,d)

    outs = jax.lax.map(one_pair, jnp.arange(n // 2))   # (n/2,2,B,bb,KV,G,d)
    # reassemble: pair p produced q-blocks p (slot 0) and n-1-p (slot 1)
    lo_blocks = outs[:, 0]                              # (n/2, B, bb, ...)
    hi_blocks = outs[:, 1][::-1]                        # block n/2 .. n-1
    full = jnp.concatenate([lo_blocks, hi_blocks], axis=0)
    out = jnp.moveaxis(full, 0, 1).reshape(B, S_pad, H, d)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, softcap: float = 0.0,
                     ring: bool = False,
                     head_scale: Optional[float] = None) -> jax.Array:
    """One-token attention. q: (B,1,H,d); caches: (B,S,KV,d).

    ``ring=True`` means the cache is a sliding-window ring buffer: every slot
    with index < min(kv_len, S) is valid and window semantics are implicit.
    """
    B, _, H, d = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = head_scale if head_scale is not None else 1.0 / math.sqrt(d)
    # keep K/V in cache dtype with f32 ACCUMULATION — an explicit
    # .astype(f32) on the cache gets hoisted into a full-stack f32 copy of
    # the carried cache inside the decode loop (measured on mixtral decode)
    qh = (q.reshape(B, KV, G, d) * jnp.asarray(scale, q.dtype))
    s = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache,
                   preferred_element_type=jnp.float32)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    slots = jnp.arange(S)
    valid = slots < jnp.minimum(kv_len, S) if ring else slots < kv_len
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, d).astype(q.dtype)


# --------------------------------------------------------------------------
# attention block (projections + rope + attention)
# --------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig) -> Dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs = {
        "wq": pdef((D, H * hd), ("d_model", "qkv"), init="scaled"),
        "wk": pdef((D, KV * hd), ("d_model", "qkv"), init="scaled"),
        "wv": pdef((D, KV * hd), ("d_model", "qkv"), init="scaled"),
        "wo": pdef((H * hd, D), ("qkv", "d_model"), init="scaled"),
    }
    if cfg.qkv_bias:
        defs["bq"] = pdef((H * hd,), ("qkv",), init="zeros")
        defs["bk"] = pdef((KV * hd,), ("qkv",), init="zeros")
        defs["bv"] = pdef((KV * hd,), ("qkv",), init="zeros")
    return defs


def _qkv_project(p: Dict, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array, chunk: int):
    """Token-wise QKV projection + RoPE, chunked under hybrid prefilling."""
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def proj(xc):
        q = xc @ p["wq"]
        k = xc @ p["wk"]
        v = xc @ p["wv"]
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        return jnp.concatenate([q, k, v], axis=-1)

    qkv = chunked_map(proj, x, chunk)
    q, k, v = jnp.split(qkv, [H * hd, (H + KV) * hd], axis=-1)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    q = rope_apply(q, positions, cfg.rope_theta)
    k = rope_apply(k, positions, cfg.rope_theta)
    # "attn_seq" (not "seq"): under sequence parallelism the residual
    # stream is seq-sharded but attention needs the full sequence — XLA
    # inserts the Megatron-SP all-gather here and the reduce-scatter after
    # the output projection.
    q = constrain(q, ("batch", "attn_seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "attn_seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "attn_seq", "kv_heads", "head_dim"))
    return q, k, v


def _context_parallel_attention(q, k, v, *, window: int, softcap: float,
                                mesh, seq_axis: str = "model",
                                batch_axes=("pod", "data")) -> jax.Array:
    """Explicit context parallelism: queries stay seq-sharded, K/V are
    all-gathered per layer (small under GQA), attention is computed locally
    per seq shard with the right positional offset. shard_map pins this
    schedule — letting SPMD partition the blocked-attention scan instead
    replicates the compute across the seq axis (measured 10x)."""
    from jax.sharding import PartitionSpec as P
    import jax

    b_axes = tuple(a for a in batch_axes if a in mesh.shape)
    spec = P(b_axes if b_axes else None, seq_axis, None, None)

    def local_fn(ql, kl, vl):
        k_full = jax.lax.all_gather(kl, seq_axis, axis=1, tiled=True)
        v_full = jax.lax.all_gather(vl, seq_axis, axis=1, tiled=True)
        q_off = jax.lax.axis_index(seq_axis) * ql.shape[1]
        return blocked_attention(ql, k_full, v_full, window=window,
                                 softcap=softcap, q_offset=q_off)

    from repro.runtime.sharding import shard_map
    return shard_map(local_fn, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec)(q, k, v)


def attention_prefill(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                      positions: jax.Array, window: int = 0,
                      chunk: int = 0, seg_ids: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence attention. Returns (out, k, v) — the caller decides how
    much of (k, v) to keep (suffix KV discard happens there).

    ``seg_ids`` selects the prepacked path: segment-restricted blocked
    attention (single instance — the context-parallel and tile-packing
    schedules assume one contiguous causal sequence)."""
    from repro.runtime.sharding import _CTX
    B, S, D = x.shape
    q, k, v = _qkv_project(p, x, cfg, positions, chunk)
    rules = _CTX.rules or {}
    cp = (_CTX.mesh is not None and rules.get("attn_seq") == "model"
          and S % _CTX.mesh.shape.get("model", 1) == 0)
    if seg_ids is not None:
        # segment-scale tiles: tile-level skipping only pays off when blocks
        # are no bigger than typical packed segments — with the default
        # (512, 1024) blocks a 1k packed batch is ONE tile and nothing skips
        out = blocked_attention(q, k, v, window=window,
                                softcap=cfg.attn_softcap, seg_ids=seg_ids,
                                q_block=128, kv_block=128)
    elif cp:
        out = _context_parallel_attention(
            q, k, v, window=window, softcap=cfg.attn_softcap, mesh=_CTX.mesh)
    elif cfg.packed_attention and window == 0:
        out = packed_causal_attention(q, k, v, softcap=cfg.attn_softcap)
    else:
        out = blocked_attention(q, k, v, window=window,
                                softcap=cfg.attn_softcap)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = chunked_map(lambda oc: oc @ p["wo"], out, chunk)
    return constrain(out, ("batch", "seq", "d_model")), k, v


def attention_decode(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                     position: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, ring: bool = False
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention step. x: (B,1,D). Returns (out, k_cache, v_cache)
    with the new token written at ``position`` (mod window when ring)."""
    B, _, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    S = k_cache.shape[1]
    q = (x @ p["wq"])
    k = (x @ p["wk"])
    v = (x @ p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, H, hd)
    k = k.reshape(B, 1, KV, hd)
    v = v.reshape(B, 1, KV, hd)
    pos2d = position.reshape(B, 1)
    q = rope_apply(q, pos2d, cfg.rope_theta)
    k = rope_apply(k, pos2d, cfg.rope_theta)
    # uniform decode: all batch rows share the step position (slot from row 0)
    slot = position[0] % S if ring else position[0]
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), slot, axis=1)
    kv_len = position[0] + 1
    out = decode_attention(q, k_cache, v_cache, kv_len,
                           softcap=cfg.attn_softcap, ring=ring)
    out = out.reshape(B, 1, H * hd) @ p["wo"]
    return out.astype(x.dtype), k_cache, v_cache


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def mlp_defs(d_model: int, d_ff: int) -> Dict:
    return {
        "w_gate": pdef((d_model, d_ff), ("d_model", "d_ff"), init="scaled"),
        "w_up": pdef((d_model, d_ff), ("d_model", "d_ff"), init="scaled"),
        "w_down": pdef((d_ff, d_model), ("d_ff", "d_model"), init="scaled"),
    }


def mlp_apply(p: Dict, x: jax.Array, chunk: int = 0) -> jax.Array:
    """SwiGLU MLP; the (tokens, d_ff) intermediate is the paper's memory
    villain — chunked under hybrid prefilling."""

    def f(xc):
        g = xc @ p["w_gate"]
        u = xc @ p["w_up"]
        return (jax.nn.silu(g.astype(jnp.float32)).astype(xc.dtype) * u) @ p["w_down"]

    out = chunked_map(f, x, chunk)
    return constrain(out, ("batch", "seq", "d_model"))


# --------------------------------------------------------------------------
# embedding
# --------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig) -> Dict:
    return {"tok": pdef((cfg.vocab_size, cfg.d_model), ("vocab", "d_model"))}


def embed_apply(p: Dict, tokens: jax.Array, dtype) -> jax.Array:
    out = jnp.take(p["tok"], tokens, axis=0).astype(dtype)
    return constrain(out, ("batch", "seq", "d_model"))
