"""Request scheduling — paper §6, Algorithm 1.

Policies:
  fifo             first-come-first-serve (PagedAttention baseline)
  srjf             shortest-remaining-job-first with JCT frozen at ARRIVAL
                   (the "traditional JCT-based scheduling" of §6.2)
  srjf_calibrated  PrefillOnly: JCT re-computed against the CURRENT prefix
                   cache before every scheduling decision, minus the
                   starvation offset λ·T_queue  (Algorithm 1)

PrefillOnly's baseline executes ONE request per step (§6.1: prefill is
compute-bound; naive batching adds latency without throughput). The engine's
prepacked path refines this: ``pick`` still chooses the single next request
by Algorithm 1 — preserving SRJF-calibrated order — and the engine then
*backfills* the chosen request's padding slack with further cache-miss
requests (segment-restricted attention keeps them independent), which adds
throughput without the latency cost §6.1 warns about because the packed
batch finishes in the same bucketed forward the anchor alone would have
paid for.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

_req_counter = itertools.count()


@dataclasses.dataclass
class Request:
    n_input: int
    arrival: float
    chain: Tuple[int, ...] = ()            # precomputed prefix hash chain
    tokens: Optional[Sequence[int]] = None  # real engine only
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))
    user_id: Optional[str] = None
    allowed_tokens: Optional[Tuple[int, ...]] = None   # e.g. (yes_id, no_id)
    deadline: Optional[float] = None       # absolute; None = best-effort
    # bookkeeping filled by the engine/simulator:
    n_cached_at_arrival: int = 0
    start_time: float = -1.0
    finish_time: float = -1.0
    n_cached_at_start: int = 0

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival


class Scheduler:
    def __init__(self, policy: str, jct_model, lam: float = 0.0,
                 usable_prefix=None):
        """``lam`` (λ) is the paper's fairness knob in JCT-seconds per second
        of queueing (paper default 500 — their jct unit is ms; ours is s, the
        ratio is what matters).

        ``usable_prefix(n_input, matched_blocks) -> tokens`` optionally maps
        a raw cache match onto the prefix a forward would actually REUSE
        (the engine's reuse-granularity bucketing, never the whole request)
        so Algorithm-1 scores price requests the same way execution and the
        shedding/routing probes do. ``None`` falls back to the raw match
        (simulator / standalone use)."""
        assert policy in ("fifo", "srjf", "srjf_calibrated"), policy
        self.policy = policy
        self.jct_model = jct_model
        self.lam = lam
        self.usable_prefix = usable_prefix

    def score(self, r: Request, cache, now: float) -> float:
        """Algorithm 1 priority of one request (lower runs sooner)."""
        if self.policy == "srjf":
            return self.jct_model.predict(r.n_input, r.n_cached_at_arrival)
        # side-effect-free probes: scoring walks every queued request each
        # step, and on the tiered cache a match_* call would eagerly restore
        # host blocks — probe_blocks prices the restorable tier read-only
        if cache is None:
            n_cached = 0
        elif self.usable_prefix is not None:
            n_cached = self.usable_prefix(
                r.n_input, cache.probe_blocks(r.chain)
                if hasattr(cache, "probe_blocks")
                else cache.match_blocks(r.chain))
        else:
            n_cached = (cache.probe_len(r.chain)
                        if hasattr(cache, "probe_len")
                        else cache.match_len(r.chain))
        jct = self.jct_model.predict(r.n_input, n_cached)
        return jct - self.lam * (now - r.arrival)

    def pick(self, queue: List[Request], cache, now: float) -> Optional[int]:
        """Returns the index into ``queue`` of the request to run next.

        srjf_calibrated implements Algorithm 1: for each waiting request
        recompute n_cached against the *current* cache (continuous JCT
        calibration), score = jct(n_input, n_cached) − λ·T_queue, run argmin.
        """
        if not queue:
            return None
        if self.policy == "fifo":
            return min(range(len(queue)), key=lambda i: (queue[i].arrival,
                                                         queue[i].req_id))
        best_i, best_score = None, None
        for i, r in enumerate(queue):
            key = (self.score(r, cache, now), r.arrival, r.req_id)
            if best_score is None or key < best_score:   # deterministic ties
                best_score, best_i = key, i
        return best_i

    def pick_backfill(self, cands: Sequence[Tuple[Request, int]],
                      benefit) -> Optional[int]:
        """Returns the index into ``cands`` of the best backfill admit.

        ``cands`` is the engine's (request, usable_prefix) candidate list;
        ``benefit(request, prefix) -> Optional[float]`` prices one candidate:
        None marks it hard-ineligible this round (budget/sharer/brownout
        gates), otherwise the co-packing benefit ``solo_cost − marginal_cost``
        in JCT-seconds. The pick is the eligible candidate with the largest
        benefit (ties broken by arrival then req_id — FIFO among equals), or
        None when no candidate is eligible. Callers admit the pick only when
        its benefit is non-negative; a negative best benefit means every
        remaining candidate's padding externality exceeds its co-packing
        gain, i.e. the pack should close (skew split).
        """
        best_i, best_key = None, None
        for i, (r, pref) in enumerate(cands):
            gain = benefit(r, pref)
            if gain is None:
                continue
            key = (-gain, r.arrival, r.req_id)
            if best_key is None or key < best_key:
                best_key, best_i = key, i
        return best_i
