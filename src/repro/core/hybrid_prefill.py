"""Hybrid prefilling (paper §4) — chunk non-attention layers, not attention.

The paper's observation: peak prefill memory is dominated by the ``(seq,
d_ff)`` intermediates of the MLP (≈14x one layer's KV), not by the KV cache.
Chunking *only* the token-wise (linear) layers bounds those intermediates at
``(chunk, d_ff)`` while attention still sees the whole sequence — so attention
kernel efficiency is untouched and the request finishes in ONE forward pass
(the property that makes suffix-KV discard possible). The discard itself is
layer-wise and structural — see ``models/transformer.forward_full`` (the KV
keep-slice is the only scan output) and ``core.kv_policy.KVLifecycle``, the
single owner of the keep arithmetic.

TPU/XLA realization: ``lax.map`` (a scan) over sequence chunks. XLA's buffer
assignment then keeps exactly one chunk of intermediates live, and the scan
writes every chunk's result straight into the preallocated stacked output —
the paper's "output preallocation" optimization falls out of the IR for free.
The Pallas ``fused_mlp`` kernel (kernels/fused_mlp) is the stronger in-VMEM
form of the same idea and is selectable per-block.

Everything here is position-independent-exact: chunking a token-wise function
along the sequence axis never changes results (tested by property tests).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def _pad_to_multiple(x: jax.Array, multiple: int, axis: int) -> Tuple[jax.Array, int]:
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x, 0
    pad = multiple - rem
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def chunked_map(fn: Callable[[jax.Array], jax.Array], x: jax.Array,
                chunk: int, axis: int = 1) -> jax.Array:
    """Apply a token-wise ``fn`` over ``axis`` in chunks via ``lax.map``.

    ``fn`` maps (..., chunk, ...) -> (..., chunk, ...); it must be
    position-independent along ``axis`` (true for every linear/MLP/norm
    layer). Peak live intermediates inside ``fn`` are bounded by one chunk.
    """
    if chunk <= 0 or x.shape[axis] <= chunk:
        return fn(x)
    axis = axis % x.ndim
    x, pad = _pad_to_multiple(x, chunk, axis)
    n = x.shape[axis] // chunk

    def body(i):
        sl = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=axis)
        return fn(sl)

    ys = jax.lax.map(body, jnp.arange(n))          # (n, ..., chunk, ...)
    ys = jnp.moveaxis(ys, 0, axis)                 # (..., n, chunk, ...)
    new_shape = ys.shape[:axis] + (n * chunk,) + ys.shape[axis + 2:]
    ys = ys.reshape(new_shape)
    if pad:
        ys = jax.lax.slice_in_dim(ys, 0, new_shape[axis] - pad, axis=axis)
    return ys


def chunked_softmax_xent(hidden: jax.Array, w_head: jax.Array,
                         labels: jax.Array, chunk: int,
                         final_softcap: float = 0.0,
                         valid: Optional[jax.Array] = None
                         ) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy without ever materializing ``(B, S, vocab)`` logits.

    Beyond-paper but a direct extension of hybrid prefilling: the LM head is
    the largest linear layer of all (vocab up to 256k here), so we fold the
    loss into the chunked pass. Uses one-hot contraction instead of gather so
    a vocab-sharded head needs only a psum. Returns (sum_loss, num_tokens).
    """
    B, S, D = hidden.shape
    V = w_head.shape[-1]
    if valid is None:
        valid = jnp.ones((B, S), dtype=jnp.float32)

    # remat: recompute the (chunk, vocab) logits in the backward pass — the
    # whole point of chunking the loss is that logits never persist.
    @jax.checkpoint
    def piece(h, lab, msk):
        # operands stay in model dtype; f32 accumulation via the MXU — an
        # f32 upcast of w_head would materialize (and all-gather) a full
        # fp32 copy of the largest matrix in the model
        logits = jnp.einsum("bcd,dv->bcv", h, w_head,
                            preferred_element_type=jnp.float32)
        if final_softcap:
            logits = final_softcap * jnp.tanh(logits / final_softcap)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lab, V, dtype=jnp.bfloat16)
        gold = jnp.einsum("bcv,bcv->bc", logits, onehot,
                          preferred_element_type=jnp.float32)
        return jnp.sum((logz - gold) * msk), jnp.sum(msk)

    if chunk <= 0 or S <= chunk:
        return piece(hidden, labels, valid)

    hidden, pad = _pad_to_multiple(hidden, chunk, 1)
    labels, _ = _pad_to_multiple(labels, chunk, 1)
    valid, _ = _pad_to_multiple(valid, chunk, 1)
    n = hidden.shape[1] // chunk

    def body(carry, i):
        h = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
        lab = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        msk = jax.lax.dynamic_slice_in_dim(valid, i * chunk, chunk, axis=1)
        loss, cnt = piece(h, lab, msk)
        return (carry[0] + loss, carry[1] + cnt), None

    (loss, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n))
    return loss, cnt


def packed_last_logits(hidden: jax.Array, w_head: jax.Array,
                       last_indices: jax.Array,
                       final_softcap: float = 0.0) -> jax.Array:
    """Prefill-only LM head for a PREPACKED batch: one logits row per packed
    segment. ``last_indices`` (N,) are flat indices into the flattened
    (B*S,) token axis — for the engine's B==1 layout, simply each segment's
    last packed position. Projects only N rows (N << S)."""
    B, S, D = hidden.shape
    flat = hidden.reshape(B * S, D)
    last = jnp.take(flat, last_indices.astype(jnp.int32), axis=0)   # (N, D)
    logits = jnp.einsum("nd,dv->nv", last, w_head,
                        preferred_element_type=jnp.float32)
    if final_softcap:
        logits = final_softcap * jnp.tanh(logits / final_softcap)
    return logits


def last_token_logits(hidden: jax.Array, w_head: jax.Array,
                      last_index: Optional[jax.Array] = None,
                      final_softcap: float = 0.0) -> jax.Array:
    """Prefill-only LM head: project ONLY the last position.

    For a prefill-only request the other ``seq-1`` rows of logits are dead
    compute (``seq x vocab`` of it); this is the serving-side twin of
    ``chunked_softmax_xent``.
    """
    B, S, D = hidden.shape
    if last_index is None:
        last = hidden[:, -1, :]
    else:
        last = jnp.take_along_axis(
            hidden, last_index.reshape(B, 1, 1).astype(jnp.int32), axis=1
        )[:, 0, :]
    logits = jnp.einsum("bd,dv->bv", last, w_head,
                        preferred_element_type=jnp.float32)
    if final_softcap:
        logits = final_softcap * jnp.tanh(logits / final_softcap)
    return logits
