"""PrefillOnly core: the paper's contribution as composable modules.

  hybrid_prefill — §4  chunked non-attention execution (+ chunked LM loss)
  kv_policy      — §3.1/§5 memory model, MIL, prefix-KV budget
  prefix_cache   — §5  block-hash radix cache w/ LRU-leaf eviction
  jct            — §6.3 JCT models (linear proxy / grid fit / roofline)
  scheduler      — §6  Algorithm 1 (SRJF + continuous calibration), baselines
  engine         — §3  the real-compute serving loop
  simulator      — §7  discrete-event reproduction of the evaluation
"""
from repro.core.hybrid_prefill import (  # noqa: F401
    chunked_map, chunked_softmax_xent, last_token_logits)
from repro.core.jct import (  # noqa: F401
    GridJCT, LinearProxyJCT, RooflineJCT, pearson, tp_comm_bytes_per_token)
from repro.core.kv_policy import MemoryModel  # noqa: F401
from repro.core.prefix_cache import PrefixCache, token_chain  # noqa: F401
from repro.core.scheduler import Request, Scheduler  # noqa: F401
