"""Host-memory KV offload tier — paper §9 "Offloading the KV caches to CPU".

The base engine DISCARDS suffix KV (and evicted prefix blocks) outright.
This tier gives the cache a second chance: blocks evicted from the
device-resident ``PrefixCache`` drop into a host-RAM store (LMCache-style);
a later match restores them instead of recomputing. The paper leaves this
as future work — here it is a first-class, bounded, LRU-managed tier.

Economics (why restoring beats recomputing): restoring a block moves
``kv_bytes_per_token * block_size`` over PCIe/DMA (~10-100 GB/s), while
recomputing it costs ``2 * N_active * block_size`` FLOPs — for an 8B model
that is ~1000x more work per token than the transfer, so offload wins
whenever host RAM is available. ``OffloadPolicy.worth_restoring`` encodes
the break-even.
"""
from __future__ import annotations

import dataclasses
import sys
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.prefix_cache import Chain, PrefixCache


def _nbytes(payload: Any) -> int:
    total = 0
    for leaf in (payload if isinstance(payload, (tuple, list)) else [payload]):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        else:
            total += sys.getsizeof(leaf)
    return total


@dataclasses.dataclass
class OffloadPolicy:
    host_bw: float = 25e9            # bytes/s device<->host
    peak_flops: float = 197e12
    efficiency: float = 0.5

    def worth_restoring(self, cfg: ModelConfig, n_tokens: int,
                        payload_bytes: int) -> bool:
        recompute_s = (2.0 * cfg.active_param_count() * n_tokens
                       / (self.peak_flops * self.efficiency))
        restore_s = payload_bytes / self.host_bw
        return restore_s < recompute_s


class HostKVStore:
    """Bounded LRU store of per-block KV payloads in host memory."""

    def __init__(self, capacity_bytes: int = 1 << 30):
        self.capacity_bytes = capacity_bytes
        self._store: "OrderedDict[int, Any]" = OrderedDict()
        self._bytes: Dict[int, int] = {}
        self.used_bytes = 0
        self.offloads = 0
        self.restores = 0
        self.host_evictions = 0

    def put(self, block_hash: int, payload: Any):
        if payload is None:
            return
        nb = _nbytes(payload)
        if nb > self.capacity_bytes:
            return
        if block_hash in self._store:
            self._store.move_to_end(block_hash)
            return
        while self.used_bytes + nb > self.capacity_bytes and self._store:
            h, _ = self._store.popitem(last=False)
            self.used_bytes -= self._bytes.pop(h)
            self.host_evictions += 1
        # device -> host copy (np.asarray forces materialization off-device)
        host_payload = tuple(np.asarray(p) for p in payload) \
            if isinstance(payload, (tuple, list)) else np.asarray(payload)
        self._store[block_hash] = host_payload
        self._bytes[block_hash] = nb
        self.used_bytes += nb
        self.offloads += 1

    def get(self, block_hash: int) -> Optional[Any]:
        if block_hash not in self._store:
            return None
        self._store.move_to_end(block_hash)
        self.restores += 1
        return self._store[block_hash]

    def __contains__(self, block_hash: int) -> bool:
        return block_hash in self._store

    def stats(self) -> Dict[str, float]:
        return {"used_bytes": self.used_bytes,
                "capacity_bytes": self.capacity_bytes,
                "offloads": self.offloads, "restores": self.restores,
                "host_evictions": self.host_evictions}


class TieredPrefixCache(PrefixCache):
    """PrefixCache whose evictions offload to a HostKVStore and whose misses
    consult it — drop-in replacement for the engine's cache."""

    def __init__(self, capacity_blocks: int, block_size: int = 16,
                 host_store: Optional[HostKVStore] = None,
                 cfg: Optional[ModelConfig] = None,
                 policy: OffloadPolicy = OffloadPolicy()):
        super().__init__(capacity_blocks, block_size)
        self.host = host_store or HostKVStore()
        self.cfg = cfg
        self.policy = policy

    def _remove(self, h: int):
        blk = self.blocks.get(h)
        if blk is not None and blk.payload is not None:
            self.host.put(h, blk.payload)          # offload, don't discard
        super()._remove(h)

    def match_blocks(self, chain: Chain, now: float = 0.0,
                     touch: bool = False) -> int:
        """Device hits first; then extend the run with host-restorable
        blocks (restored into the device cache on the spot when worth it)."""
        n = super().match_blocks(chain, now, touch)
        restored = 0
        for h in chain[n:]:
            payload = self.host.get(h) if h in self.host else None
            if payload is None:
                break
            if self.cfg is not None and not self.policy.worth_restoring(
                    self.cfg, self.block_size, _nbytes(payload)):
                break
            # reinsert this block at the tail of the resident chain
            got = self.insert(chain[: n + restored + 1],
                              (n + restored + 1) * self.block_size,
                              now=now,
                              payloads=None)
            if got < n + restored + 1:
                break
            self.blocks[h].payload = payload
            restored += 1
        return n + restored

    def stats(self) -> Dict[str, float]:
        out = super().stats()
        out["host"] = self.host.stats()
        return out
