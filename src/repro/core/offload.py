"""Host-memory KV offload tier — paper §9 "Offloading the KV caches to CPU".

The base engine DISCARDS suffix KV (and evicted prefix blocks) outright.
This tier gives the cache a second chance: blocks evicted from the
device-resident ``PrefixCache`` drop into a host-RAM store (LMCache-style);
a later match restores them instead of recomputing. The paper leaves this
as future work — here it is a first-class, bounded, LRU-managed tier.

Economics (why restoring beats recomputing): restoring a block moves
``kv_bytes_per_token * block_size`` over PCIe/DMA (~10-100 GB/s), while
recomputing it costs ``2 * N_active * block_size`` FLOPs — for an 8B model
that is ~1000x more work per token than the transfer, so offload wins
whenever host RAM is available. ``OffloadPolicy.worth_restoring`` encodes
the break-even; its constants come from ``runtime/hw.py`` (the same
``ChipSpec`` that drives the MIL memory model), and the engine's
``profile()`` fit can override ``host_bw`` with a measured value.
"""
from __future__ import annotations

import dataclasses
import sys
from collections import OrderedDict
from typing import Any, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.prefix_cache import Chain, PrefixCache
from repro.runtime.hw import ChipSpec, DEFAULT_CHIP


def _nbytes(payload: Any) -> int:
    total = 0
    for leaf in (payload if isinstance(payload, (tuple, list)) else [payload]):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        else:
            total += sys.getsizeof(leaf)
    return total


def to_host(payload: Any) -> Any:
    """Force a (possibly jax device-array) payload onto host numpy.

    ``np.asarray`` materializes device buffers off-accelerator; without it a
    "host" store would keep the payload pinned in HBM, defeating the tier.
    """
    if payload is None:
        return None
    if isinstance(payload, (tuple, list)):
        return tuple(np.asarray(p) for p in payload)
    return np.asarray(payload)


@dataclasses.dataclass
class OffloadPolicy:
    """Transfer-vs-recompute break-even for the DRAM tier.

    Defaults are sourced from the target ``ChipSpec`` (``runtime/hw.py``)
    rather than re-hardcoded here; ``host_bw``/``peak_flops`` accept
    explicit overrides (e.g. a measured PCIe bandwidth from ``profile()``).
    """
    host_bw: Optional[float] = None      # bytes/s device<->host
    peak_flops: Optional[float] = None   # FLOP/s
    efficiency: float = 0.5
    chip: ChipSpec = DEFAULT_CHIP

    def __post_init__(self):
        if self.host_bw is None:
            self.host_bw = self.chip.host_bw
        if self.peak_flops is None:
            self.peak_flops = self.chip.peak_flops_bf16

    def restore_seconds(self, payload_bytes: int) -> float:
        return payload_bytes / self.host_bw

    def recompute_seconds(self, cfg: ModelConfig, n_tokens: int) -> float:
        return (2.0 * cfg.active_param_count() * n_tokens
                / (self.peak_flops * self.efficiency))

    def worth_restoring(self, cfg: ModelConfig, n_tokens: int,
                        payload_bytes: int) -> bool:
        return (self.restore_seconds(payload_bytes)
                < self.recompute_seconds(cfg, n_tokens))


class HostKVStore:
    """Bounded LRU store of per-block KV payloads in host memory."""

    def __init__(self, capacity_bytes: int = 1 << 30):
        self.capacity_bytes = capacity_bytes
        self._store: "OrderedDict[int, Any]" = OrderedDict()
        self._bytes: Dict[int, int] = {}
        self.used_bytes = 0
        self.offloads = 0
        self.restores = 0
        self.host_evictions = 0
        self.offload_bytes = 0
        self.restore_bytes = 0

    def put(self, block_hash: int, payload: Any):
        if payload is None:
            return
        if block_hash in self._store:
            self._store.move_to_end(block_hash)
            return
        # device -> host copy FIRST, then account post-conversion bytes —
        # the device view may be a lazy slice whose materialized size differs
        host_payload = to_host(payload)
        nb = _nbytes(host_payload)
        if nb > self.capacity_bytes:
            return
        while self.used_bytes + nb > self.capacity_bytes and self._store:
            h, _ = self._store.popitem(last=False)
            self.used_bytes -= self._bytes.pop(h)
            self.host_evictions += 1
        self._store[block_hash] = host_payload
        self._bytes[block_hash] = nb
        self.used_bytes += nb
        self.offloads += 1
        self.offload_bytes += nb

    def get(self, block_hash: int) -> Optional[Any]:
        if block_hash not in self._store:
            return None
        self._store.move_to_end(block_hash)
        self.restores += 1
        self.restore_bytes += self._bytes[block_hash]
        return self._store[block_hash]

    def nbytes_of(self, block_hash: int) -> int:
        """Stored size of a block WITHOUT touching LRU order or counters."""
        return self._bytes.get(block_hash, 0)

    def __contains__(self, block_hash: int) -> bool:
        return block_hash in self._store

    def __len__(self) -> int:
        return len(self._store)

    def stats(self) -> Dict[str, float]:
        return {"used_bytes": self.used_bytes,
                "capacity_bytes": self.capacity_bytes,
                "blocks": len(self._store),
                "offloads": self.offloads, "restores": self.restores,
                "host_evictions": self.host_evictions,
                "offload_bytes": self.offload_bytes,
                "restore_bytes": self.restore_bytes}


class TieredPrefixCache(PrefixCache):
    """PrefixCache whose evictions offload to a HostKVStore and whose misses
    consult it — drop-in replacement for the engine's cache.

    Tier vocabulary: a block is ``device`` (resident, payload usable by the
    forward), ``host`` (evicted into the DRAM store, restorable when
    ``OffloadPolicy.worth_restoring`` wins), or absent (recompute)."""

    def __init__(self, capacity_blocks: int, block_size: int = 16,
                 host_store: Optional[HostKVStore] = None,
                 cfg: Optional[ModelConfig] = None,
                 policy: Optional[OffloadPolicy] = None):
        super().__init__(capacity_blocks, block_size)
        self.host = host_store or HostKVStore()
        self.cfg = cfg
        self.policy = policy if policy is not None else OffloadPolicy()
        self.restored_blocks = 0

    def _remove(self, h: int):
        blk = self.blocks.get(h)
        if blk is not None and blk.payload is not None:
            self.host.put(h, blk.payload)          # offload, don't discard
        super()._remove(h)

    def _restorable(self, h: int) -> bool:
        if h not in self.host:
            return False
        if self.cfg is None:
            return True
        return self.policy.worth_restoring(
            self.cfg, self.block_size, self.host.nbytes_of(h))

    def match_tiers(self, chain: Chain) -> List[str]:
        """Per-block tier of the longest serveable prefix: ``device`` blocks
        first, then the ``host`` continuation that the policy would restore.
        Read-only — no LRU touch, no restore."""
        tiers: List[str] = []
        for h in chain:
            if h in self.blocks:
                tiers.append("device")
            else:
                break
        for h in chain[len(tiers):]:
            if not self._restorable(h):
                break
            tiers.append("host")
        return tiers

    def probe_blocks(self, chain: Chain) -> int:
        """Serveable prefix = device run + restorable host continuation,
        side-effect free (no LRU touch, no restore — see base docstring)."""
        return len(self.match_tiers(chain))

    def restore_estimate(self, chain: Chain) -> Dict[str, float]:
        """Restorable host continuation of ``chain``'s device run, priced at
        the policy's effective host bandwidth. Read-only; used by admission
        to fold restore latency into the JCT estimate and by the router-time
        prefetch to decide whether a transfer is worth starting."""
        n_dev = super().match_blocks(chain)
        blocks = 0
        nbytes = 0
        for h in chain[n_dev:]:
            if not self._restorable(h):
                break
            blocks += 1
            nbytes += self.host.nbytes_of(h)
        return {"device_blocks": n_dev, "blocks": blocks, "bytes": nbytes,
                "restore_s": self.policy.restore_seconds(nbytes)
                if nbytes else 0.0}

    def match_blocks(self, chain: Chain, now: float = 0.0,
                     touch: bool = False) -> int:
        """Device hits first; then extend the run with host-restorable
        blocks (restored into the device cache on the spot when worth it)."""
        n = super().match_blocks(chain, now, touch)
        restored = 0
        for h in chain[n:]:
            if not self._restorable(h):
                break
            payload = self.host.get(h)
            if payload is None:
                break
            # reinsert this block at the tail of the resident chain
            got = self.insert(chain[: n + restored + 1],
                              (n + restored + 1) * self.block_size,
                              now=now,
                              payloads=None)
            if got < n + restored + 1:
                break
            self.blocks[h].payload = payload
            restored += 1
        self.restored_blocks += restored
        return n + restored

    def stats(self) -> Dict[str, float]:
        out = super().stats()
        out["restored_blocks"] = self.restored_blocks
        out["host"] = self.host.stats()
        return out
