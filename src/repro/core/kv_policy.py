"""KV-cache policy + prefill memory model — paper §3.1 (profile run), §4, §5.

Answers three questions, all from one analytic model validated against the
dry-run's ``memory_analysis()``:
  * peak prefill memory of a technique at input length S  (Fig 3/4/10)
  * MIL — max input length a technique can serve            (Table 2)
  * prefix-KV budget: HBM left over for the prefix cache after reserving the
    peak working set at MIL                                  (profile run)

Techniques modeled (per paper §2.5/§4):
  paged       vLLM PagedAttention: full activations + full KV, no chunking
  chunked     chunked prefill: chunk-bounded activations, but KV of ALL
              layers retained between chunks
  discard     naive KV discard (§2.6): one layer of KV, but full-length
              linear-layer intermediates (the paper's 1.6x disappointment)
  hybrid      PrefillOnly hybrid prefilling: chunk-bounded MLP intermediates
              + one layer of transient K/V + suffix discard
  tp / pp     k-way tensor / pipeline parallel variants of ``paged``
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.runtime.hw import ChipSpec, DEFAULT_CHIP

BYTES = 2  # bf16


def bucket(n: int, sizes: Sequence[int]) -> int:
    """Smallest bucket >= n; grows geometrically past the table (clamping
    would truncate requests longer than the largest configured bucket)."""
    for s in sizes:
        if n <= s:
            return s
    s = sizes[-1]
    while s < n:
        s *= 2
    return s


@dataclasses.dataclass(frozen=True)
class KVLifecycle:
    """SINGLE OWNER of the KV keep/discard decision (paper §2.6/§4).

    The engine's forward paths discard suffix KV layer-by-layer (the KV
    keep-slice is the only scan output in ``models/transformer.py`` — each
    layer's full-length K/V is freed by XLA as soon as its attention has
    consumed it), and the prefix cache only ever receives whole blocks of
    the kept slice. Before this class the keep arithmetic was smeared across
    ``engine._execute``, ``engine._execute_packed``, ``_run_fresh`` /
    ``_run_suffix`` and ``PrefixCache.insert`` callers; every one of those
    sites now asks this object, so the policy is stated (and tested) once.

    All methods are pure shape/token arithmetic — safe to call under the
    engine lock and from routing probes.
    """
    block_size: int = 16
    kv_keep_tokens: int = 10**9             # suffix-discard threshold
    buckets: Tuple[int, ...] = (64, 128, 256, 512, 1024, 2048)

    def keep(self, n_input: int) -> int:
        """Per-request KV budget in tokens (the kept prefix slice)."""
        return min(n_input, self.kv_keep_tokens)

    def keep_aligned(self, n_input: int) -> int:
        """Budget rounded DOWN to whole cache blocks — only full blocks are
        insertable, so this is the most KV a request can leave behind."""
        return (self.keep(n_input) // self.block_size) * self.block_size

    def resident(self, matched_blocks: int, n_input: int) -> bool:
        """Chain already resident past the keep bound: an insert would only
        re-slice and re-touch existing blocks, so callers skip it."""
        return matched_blocks * self.block_size >= self.keep_aligned(n_input)

    def keep_new(self, n_input: int, prefix_len: int,
                 matched_blocks: int) -> int:
        """Block-aligned NEW kept tokens beyond a reused prefix (packed
        path's per-segment kv gather length; 0 when already resident)."""
        if self.resident(matched_blocks, n_input):
            return 0
        return max(0, self.keep_aligned(n_input) - prefix_len)

    def suffix_keep_new(self, keep: int, prefix_len: int, n_fresh: int) -> int:
        """Fresh-KV tokens the suffix (cache-hit) forward must emit so the
        total kept window reaches ``keep`` (solo hit path)."""
        return max(0, min(keep, prefix_len + n_fresh) - prefix_len)

    def keep_pad(self, keep: int, S: int) -> int:
        """Jit-key bucketing of a keep budget: kv_keep only bounds how much
        KV leaves each layer (keeping more is safe, callers slice), and a
        raw per-request value would put every length in its own jit key."""
        return min(bucket(keep, self.buckets) if keep else 0, S)

    def insertable_tokens(self, keep: int, kv_from: int, n_new: int) -> int:
        """Tokens of fresh KV actually insertable after a forward that
        produced ``n_new`` kept tokens starting at offset ``kv_from``."""
        return max(0, min(keep, kv_from + n_new) - kv_from)


@dataclasses.dataclass
class MemoryModel:
    cfg: ModelConfig
    chip: ChipSpec = DEFAULT_CHIP
    utilization: float = 0.9          # HBM headroom kept for the allocator
    weight_bytes_per_param: float = BYTES  # 1.0 = fp8 (paper's quantized setups)
    # hybrid-prefilling micro-optimizations (paper §4.3): without output
    # preallocation the chunked output is double-buffered; without in-place
    # reuse each grouped-linear keeps input+output copies.
    output_prealloc: bool = True
    inplace: bool = True

    # ---- per-token byte coefficients -------------------------------------
    @property
    def weights_bytes(self) -> float:
        return self.cfg.param_count() * self.weight_bytes_per_param

    @property
    def kv_all_per_token(self) -> float:
        return float(self.cfg.kv_bytes_per_token(BYTES))

    @property
    def kv_one_layer_per_token(self) -> float:
        n = max(1, self.cfg.num_layers if self.cfg.family != "hybrid"
                else self.cfg.num_layers // max(self.cfg.attn_every, 1))
        return self.kv_all_per_token / n

    @property
    def mlp_int_per_token(self) -> float:
        """gate+up intermediates — the paper's Fig 4 villain (14x one-layer KV
        on Llama-3.1-8B)."""
        d_ff = self.cfg.d_ff if self.cfg.d_ff else self.cfg.d_inner * 2
        mult = 1.0
        if not self.output_prealloc:
            mult += 0.5               # concat copy of the chunked output
        if not self.inplace:
            mult += 0.5               # separate in/out buffers per linear
        return 2.0 * d_ff * BYTES * mult

    @property
    def attn_stream_per_token(self) -> float:
        """Transient full-sequence q/k/v + residual streams for ONE layer."""
        cfg = self.cfg
        if not cfg.has_attention:
            return 4.0 * cfg.d_model * BYTES
        qkv = (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim * BYTES
        resid = 4.0 * cfg.d_model * BYTES
        return qkv + resid

    # ---- peak memory per technique ---------------------------------------
    def peak_bytes(self, S: int, technique: str, chunk: int = 2048,
                   k: int = 2, kv_keep: Optional[int] = None) -> float:
        """``kv_keep`` (hybrid only) prices the PEAK-LAYER footprint of the
        layer-wise discard: the transient suffix KV costs ONE layer (freed as
        soon as the next layer consumes it), while the kept slice — at most
        ``kv_keep`` tokens, what ``KVLifecycle`` lets out of the forward —
        persists across ALL layers into the cache insert. ``kv_keep=None``
        keeps the pre-hierarchy behavior (kept slice not priced; the prefix
        budget accounted it globally instead)."""
        W = self.weights_bytes
        act_full = self.mlp_int_per_token + self.attn_stream_per_token
        if technique == "paged":
            return W + S * act_full + S * self.kv_all_per_token
        if technique == "chunked":
            return W + chunk * act_full + S * self.kv_all_per_token
        if technique == "discard":
            return W + S * act_full + S * self.kv_one_layer_per_token
        if technique == "hybrid":
            kept = (min(S, kv_keep) * self.kv_all_per_token
                    if kv_keep is not None else 0.0)
            return (W + chunk * self.mlp_int_per_token
                    + S * self.attn_stream_per_token
                    + S * self.kv_one_layer_per_token + kept)
        if technique == "tp":
            return (W + S * act_full + S * self.kv_all_per_token) / k
        if technique == "pp":
            # weights and KV split across stages; activations of one stage
            return (W + S * self.kv_all_per_token) / k + S * act_full
        raise ValueError(technique)

    # ---- MIL + prefix budget ----------------------------------------------
    def budget_bytes(self) -> float:
        return self.chip.hbm_bytes * self.utilization

    def max_input_length(self, technique: str, chunk: int = 2048,
                         k: int = 2, kv_keep: Optional[int] = None) -> int:
        """Closed-form MIL: peak_bytes is affine in S (piecewise affine with
        a kv_keep knee — for S past the keep bound the kept slice is a
        constant, so the long-input branch is tried first)."""
        budget = self.budget_bytes()
        base = self.peak_bytes(0, technique, chunk, k)
        slope = self.peak_bytes(1, technique, chunk, k) - base
        if kv_keep is not None and technique == "hybrid":
            const = kv_keep * self.kv_all_per_token
            if slope > 0 and base + const < budget:
                s = int((budget - base - const) / slope)
                if s > kv_keep:
                    return s
            # short-input branch: the kept slice still grows with S
            slope += self.kv_all_per_token
        if base >= budget:
            return 0
        if slope <= 0:
            return 1 << 30
        return int((budget - base) / slope)

    def prefix_budget_tokens(self, mil: int, chunk: int = 2048,
                             kv_keep: Optional[int] = None) -> int:
        """Paper §3.1 profile run: after reserving the hybrid-prefill working
        set at MIL, the remaining HBM holds the prefix KV cache. Pricing the
        peak-layer footprint via ``kv_keep`` shrinks the reservation, so the
        same HBM yields a LARGER effective device cache (BENCH_offload)."""
        reserve = self.peak_bytes(mil, "hybrid", chunk, kv_keep=kv_keep)
        free = self.budget_bytes() - reserve
        if free <= 0 or self.kv_all_per_token == 0:
            return 0
        return int(free / self.kv_all_per_token)

    def mil_table(self, chunk: int = 2048, k: int = 2) -> Dict[str, int]:
        return {t: self.max_input_length(t, chunk, k)
                for t in ("paged", "chunked", "discard", "tp", "pp", "hybrid")}
