"""KV-cache policy + prefill memory model — paper §3.1 (profile run), §4, §5.

Answers three questions, all from one analytic model validated against the
dry-run's ``memory_analysis()``:
  * peak prefill memory of a technique at input length S  (Fig 3/4/10)
  * MIL — max input length a technique can serve            (Table 2)
  * prefix-KV budget: HBM left over for the prefix cache after reserving the
    peak working set at MIL                                  (profile run)

Techniques modeled (per paper §2.5/§4):
  paged       vLLM PagedAttention: full activations + full KV, no chunking
  chunked     chunked prefill: chunk-bounded activations, but KV of ALL
              layers retained between chunks
  discard     naive KV discard (§2.6): one layer of KV, but full-length
              linear-layer intermediates (the paper's 1.6x disappointment)
  hybrid      PrefillOnly hybrid prefilling: chunk-bounded MLP intermediates
              + one layer of transient K/V + suffix discard
  tp / pp     k-way tensor / pipeline parallel variants of ``paged``
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig
from repro.runtime.hw import ChipSpec, DEFAULT_CHIP

BYTES = 2  # bf16


@dataclasses.dataclass
class MemoryModel:
    cfg: ModelConfig
    chip: ChipSpec = DEFAULT_CHIP
    utilization: float = 0.9          # HBM headroom kept for the allocator
    weight_bytes_per_param: float = BYTES  # 1.0 = fp8 (paper's quantized setups)
    # hybrid-prefilling micro-optimizations (paper §4.3): without output
    # preallocation the chunked output is double-buffered; without in-place
    # reuse each grouped-linear keeps input+output copies.
    output_prealloc: bool = True
    inplace: bool = True

    # ---- per-token byte coefficients -------------------------------------
    @property
    def weights_bytes(self) -> float:
        return self.cfg.param_count() * self.weight_bytes_per_param

    @property
    def kv_all_per_token(self) -> float:
        return float(self.cfg.kv_bytes_per_token(BYTES))

    @property
    def kv_one_layer_per_token(self) -> float:
        n = max(1, self.cfg.num_layers if self.cfg.family != "hybrid"
                else self.cfg.num_layers // max(self.cfg.attn_every, 1))
        return self.kv_all_per_token / n

    @property
    def mlp_int_per_token(self) -> float:
        """gate+up intermediates — the paper's Fig 4 villain (14x one-layer KV
        on Llama-3.1-8B)."""
        d_ff = self.cfg.d_ff if self.cfg.d_ff else self.cfg.d_inner * 2
        mult = 1.0
        if not self.output_prealloc:
            mult += 0.5               # concat copy of the chunked output
        if not self.inplace:
            mult += 0.5               # separate in/out buffers per linear
        return 2.0 * d_ff * BYTES * mult

    @property
    def attn_stream_per_token(self) -> float:
        """Transient full-sequence q/k/v + residual streams for ONE layer."""
        cfg = self.cfg
        if not cfg.has_attention:
            return 4.0 * cfg.d_model * BYTES
        qkv = (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim * BYTES
        resid = 4.0 * cfg.d_model * BYTES
        return qkv + resid

    # ---- peak memory per technique ---------------------------------------
    def peak_bytes(self, S: int, technique: str, chunk: int = 2048,
                   k: int = 2) -> float:
        W = self.weights_bytes
        act_full = self.mlp_int_per_token + self.attn_stream_per_token
        if technique == "paged":
            return W + S * act_full + S * self.kv_all_per_token
        if technique == "chunked":
            return W + chunk * act_full + S * self.kv_all_per_token
        if technique == "discard":
            return W + S * act_full + S * self.kv_one_layer_per_token
        if technique == "hybrid":
            return (W + chunk * self.mlp_int_per_token
                    + S * self.attn_stream_per_token
                    + S * self.kv_one_layer_per_token)
        if technique == "tp":
            return (W + S * act_full + S * self.kv_all_per_token) / k
        if technique == "pp":
            # weights and KV split across stages; activations of one stage
            return (W + S * self.kv_all_per_token) / k + S * act_full
        raise ValueError(technique)

    # ---- MIL + prefix budget ----------------------------------------------
    def budget_bytes(self) -> float:
        return self.chip.hbm_bytes * self.utilization

    def max_input_length(self, technique: str, chunk: int = 2048,
                         k: int = 2) -> int:
        """Closed-form MIL: peak_bytes is affine in S."""
        budget = self.budget_bytes()
        base = self.peak_bytes(0, technique, chunk, k)
        slope = self.peak_bytes(1, technique, chunk, k) - base
        if base >= budget:
            return 0
        if slope <= 0:
            return 1 << 30
        return int((budget - base) / slope)

    def prefix_budget_tokens(self, mil: int, chunk: int = 2048) -> int:
        """Paper §3.1 profile run: after reserving the hybrid-prefill working
        set at MIL, the remaining HBM holds the prefix KV cache."""
        reserve = self.peak_bytes(mil, "hybrid", chunk)
        free = self.budget_bytes() - reserve
        if free <= 0 or self.kv_all_per_token == 0:
            return 0
        return int(free / self.kv_all_per_token)

    def mil_table(self, chunk: int = 2048, k: int = 2) -> Dict[str, int]:
        return {t: self.max_input_length(t, chunk, k)
                for t in ("paged", "chunked", "discard", "tp", "pp", "hybrid")}
