"""PrefillOnly engine — the real-compute serving loop (paper §3).

Workflow (Figure 2):
  profile run   -> JCT model fit + prefix-KV budget (kv_policy / measured)
  submit()      -> tokenize-equivalent: hash-chain the request, enqueue
  step()        -> Algorithm 1 pick (continuous JCT calibration) ->
                   hybrid prefill (cache-hit suffix path when possible) ->
                   suffix-KV discard into the block cache -> constrained
                   single-token output (the paper's P(Yes)/P(No) scoring)

This engine runs REAL forwards (CPU-scale models in tests/examples; the same
code drives a TPU instance mesh via launch/serve.py). Shapes are bucketed so
jit compiles a bounded set of programs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.jct import LinearProxyJCT, Sample
from repro.core.prefix_cache import PrefixCache, token_chain
from repro.core.scheduler import Request, Scheduler
from repro.models import transformer as tfm
from repro.models.model import cast_params


def _bucket(n: int, sizes: Sequence[int]) -> int:
    for s in sizes:
        if n <= s:
            return s
    return sizes[-1]


@dataclasses.dataclass
class EngineConfig:
    policy: str = "srjf_calibrated"
    lam: float = 0.05                 # starvation offset (JCT-sec per wait-sec)
    block_size: int = 16
    cache_capacity_tokens: int = 4096  # prefix-KV budget (profile run output)
    kv_keep_tokens: int = 10**9        # suffix discard threshold (per request)
    suffix_buckets: Tuple[int, ...] = (64, 128, 256, 512, 1024, 2048)
    prefix_bucket_blocks: int = 4      # reuse granularity: 4 blocks = 64 tok


class PrefillOnlyEngine:
    """Single-instance engine over a dense-family model (real arrays)."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig = EngineConfig()):
        assert cfg.family in ("dense", "vlm", "audio", "moe"), cfg.family
        self.cfg = cfg
        self.params = cast_params(params, cfg.dtype)
        self.ecfg = ecfg
        self.cache = PrefixCache(ecfg.cache_capacity_tokens // ecfg.block_size,
                                 ecfg.block_size)
        self.jct_model = LinearProxyJCT()
        self.scheduler = Scheduler(ecfg.policy, self.jct_model, ecfg.lam)
        self.queue: List[Request] = []
        self.results: Dict[int, Dict] = {}
        self._fresh_fns: Dict[Tuple[int, int], callable] = {}
        self._suffix_fns: Dict[Tuple[int, int, int], callable] = {}
        self.steps = 0
        self.hit_tokens = 0
        self.total_tokens = 0

    # ---- profile run (paper §3.1) ------------------------------------------
    def profile(self, lengths: Sequence[int] = (64, 128, 256, 512)) -> float:
        """Measure jct(n_input, 0) on this host, fit the linear proxy."""
        samples: List[Sample] = []
        rng = np.random.default_rng(0)
        for n in lengths:
            toks = rng.integers(0, self.cfg.vocab_size, size=n).tolist()
            self._run_fresh(toks)            # warm-up: exclude compile time
            for _ in range(2):               # steady-state samples
                t0 = time.perf_counter()
                logits, _, _ = self._run_fresh(toks)
                jax.block_until_ready(logits)
                samples.append((n, 0, time.perf_counter() - t0))
        self.jct_model.fit(samples)
        return self.jct_model.pearson_r

    # ---- request lifecycle ---------------------------------------------------
    def submit(self, tokens: Sequence[int],
               allowed_tokens: Optional[Sequence[int]] = None,
               user_id: Optional[str] = None, now: Optional[float] = None) -> int:
        now = time.perf_counter() if now is None else now
        r = Request(n_input=len(tokens), arrival=now,
                    chain=token_chain(tokens, self.ecfg.block_size),
                    tokens=list(tokens), user_id=user_id,
                    allowed_tokens=tuple(allowed_tokens) if allowed_tokens else None)
        r.n_cached_at_arrival = self.cache.match_len(r.chain)
        self.queue.append(r)
        return r.req_id

    def step(self) -> Optional[int]:
        """One scheduling step: pick (Algorithm 1), prefill, cache, score."""
        now = time.perf_counter()
        i = self.scheduler.pick(self.queue, self.cache, now)
        if i is None:
            return None
        r = self.queue.pop(i)
        r.start_time = now
        logits = self._execute(r)
        r.finish_time = time.perf_counter()
        self.results[r.req_id] = self._score(logits, r)
        self.steps += 1
        return r.req_id

    def run_until_drained(self) -> List[int]:
        done = []
        while self.queue:
            done.append(self.step())
        return done

    # ---- execution -----------------------------------------------------------
    def _execute(self, r: Request) -> jax.Array:
        bs = self.ecfg.block_size
        matched_blocks = self.cache.match_blocks(r.chain, touch=True)
        gran = self.ecfg.prefix_bucket_blocks
        use_blocks = (matched_blocks // gran) * gran  # bucketed prefix reuse
        prefix_len = use_blocks * bs
        # never consume the whole request from cache — the last token's
        # logits must be computed (ensure >=1 fresh token)
        if prefix_len >= r.n_input:
            prefix_len = max(0, ((r.n_input - 1) // (gran * bs)) * gran * bs)
            use_blocks = prefix_len // bs
        r.n_cached_at_start = prefix_len
        self.hit_tokens += prefix_len
        self.total_tokens += r.n_input

        keep = min(r.n_input, self.ecfg.kv_keep_tokens)
        if prefix_len == 0:
            logits, new_kv, n_new = self._run_fresh(r.tokens, keep)
            kv_from = 0
        else:
            self.cache.pin(r.chain, use_blocks)
            payloads = self.cache.match_payloads(r.chain)[:use_blocks]
            pk = jnp.concatenate([p[0] for p in payloads], axis=2)
            pv = jnp.concatenate([p[1] for p in payloads], axis=2)
            logits, new_kv, n_new = self._run_suffix(
                r.tokens[prefix_len:], pk, pv, prefix_len, keep)
            self.cache.unpin(r.chain, use_blocks)
            kv_from = prefix_len
        # split fresh KV into block payloads and insert (suffix discard:
        # only up to ``keep`` tokens total)
        n_insertable = max(0, min(keep, kv_from + n_new) - kv_from)
        n_blocks_new = n_insertable // bs
        payloads_all = self.cache.match_payloads(r.chain)[:use_blocks]
        for b in range(n_blocks_new):
            k_b = new_kv["k"][:, :, b * bs:(b + 1) * bs]
            v_b = new_kv["v"][:, :, b * bs:(b + 1) * bs]
            payloads_all.append((k_b, v_b))
        self.cache.insert(r.chain, kv_from + n_blocks_new * bs,
                          now=time.perf_counter(), payloads=payloads_all)
        return logits

    def _run_fresh(self, tokens: Sequence[int], keep: int = 0):
        S = _bucket(len(tokens), self.ecfg.suffix_buckets)
        keep_pad = min(keep, S)
        key = (S, keep_pad)
        if key not in self._fresh_fns:
            cfg = self.cfg

            @jax.jit
            def fn(params, toks, last_index):
                return tfm.prefill(params, cfg, {"tokens": toks},
                                   kv_keep=keep_pad, last_index=last_index)

            self._fresh_fns[key] = fn
        toks = np.zeros((1, S), np.int32)
        toks[0, :len(tokens)] = tokens
        logits, kv = self._fresh_fns[key](
            self.params, jnp.asarray(toks),
            jnp.asarray([len(tokens) - 1], jnp.int32))
        if kv is None:
            return logits, {"k": None, "v": None}, 0
        # kv: (L, 1, keep_pad, KV, hd); valid fresh tokens = len(tokens)
        n_new = min(keep_pad, len(tokens))
        return logits, kv, n_new

    def _run_suffix(self, tokens, pk, pv, prefix_len: int, keep: int):
        S = _bucket(len(tokens), self.ecfg.suffix_buckets)
        P = pk.shape[2]
        keep_new = max(0, min(keep, prefix_len + S) - prefix_len)
        key = (S, P, keep_new)
        if key not in self._suffix_fns:
            cfg = self.cfg

            @jax.jit
            def fn(params, toks, pk, pv, last_index):
                return tfm.prefill_with_prefix(
                    params, cfg, {"tokens": toks}, {"k": pk, "v": pv},
                    prefix_len=P, kv_keep=P + keep_new, last_index=last_index)

            self._suffix_fns[key] = fn
        toks = np.zeros((1, S), np.int32)
        toks[0, :len(tokens)] = tokens
        logits, kv = self._suffix_fns[key](
            self.params, jnp.asarray(toks), pk, pv,
            jnp.asarray([len(tokens) - 1], jnp.int32))
        n_new = min(keep_new, len(tokens))
        return logits, kv, n_new

    # ---- output --------------------------------------------------------------
    def _score(self, logits: jax.Array, r: Request) -> Dict:
        """Constrained single-token output: renormalize over allowed ids
        (paper §2.3 — P(Yes)/P(No) without fine-tuning)."""
        out = {"req_id": r.req_id, "latency": r.latency,
               "n_cached": r.n_cached_at_start, "n_input": r.n_input}
        logits = np.asarray(logits[0], np.float64)
        if r.allowed_tokens:
            sub = logits[list(r.allowed_tokens)]
            sub = np.exp(sub - sub.max())
            sub /= sub.sum()
            out["scores"] = {int(t): float(p)
                             for t, p in zip(r.allowed_tokens, sub)}
            out["token"] = int(r.allowed_tokens[int(np.argmax(sub))])
        else:
            out["token"] = int(np.argmax(logits))
        return out

    def stats(self) -> Dict:
        return {
            "steps": self.steps,
            "hit_rate": self.hit_tokens / max(1, self.total_tokens),
            "cache": self.cache.stats(),
        }
