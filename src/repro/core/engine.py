"""PrefillOnly engine — the real-compute serving loop (paper §3).

Workflow (Figure 2):
  profile run   -> JCT model fit + prefix-KV budget (kv_policy / measured)
  submit()      -> tokenize-equivalent: hash-chain the request, enqueue
  step()        -> Algorithm 1 pick (continuous JCT calibration) -> batch
                   formation (prepacking) -> hybrid prefill (cache-hit
                   suffix path when possible) -> suffix-KV discard into the
                   block cache -> constrained single-token output (the
                   paper's P(Yes)/P(No) scoring)

This engine runs REAL forwards (CPU-scale models in tests/examples; the same
code drives a TPU instance mesh via launch/serve.py). Shapes are bucketed so
jit compiles a bounded set of programs.

Prepacked prefill (arXiv:2404.09529 / BatchLLM arXiv:2412.03594)
----------------------------------------------------------------
Bucketing rounds every suffix up to the next shape in ``suffix_buckets``, so
a 65-token request pays the FLOPs of a 128-token forward — on the paper's
short discriminative workloads up to ~50% of prefill compute is padding.
Instead of widening the batch axis (which §6.1 rejects for latency), the
engine packs several requests end-to-end into ONE sequence and restricts
attention to same-segment pairs (segment ids drive both tile-level skipping
and element masking in the kernels; RoPE positions restart at each segment
boundary). Single-token output makes this safe: each packed request needs
only its own last-row logits.

Batch formation preserves Algorithm 1: the *anchor* request is still the
scheduler's pick. First-fit-decreasing backfill fills the remaining
``pack_token_budget`` (counted in COMPUTED tokens) with further requests,
largest first — short requests ride in the padding slack that bucketing
would have burned anyway. Each packed request's KV is sliced out of the
packed forward and inserted into the prefix cache under its own hash chain
(suffix discard still applies), and the JCT model observes (computed tokens,
wall time) so SRJF-calibrated scoring stays calibrated for packed steps.

Prefix-aware packing (the packed cache-HIT path)
------------------------------------------------
Cache-hit requests co-pack too: each hit segment contributes only its
SUFFIX tokens to the packed forward and attends its cached prefix KV
through a gathered per-segment prefix buffer (position-masked
segment-restricted attention — ``tfm.prefill_packed_with_prefix``). A small
per-candidate cost model chooses between {solo suffix, packed miss, packed
hit}: a candidate joins the batch only when the packed-step JCT estimate
over bucketed forward sizes beats running it sequentially. Prefix sharers
whose shared prefix is ALREADY cached can therefore co-pack (each attends
its own gathered copy); sharers whose prefix is not yet cached still run
sequentially so the later one hits the earlier one's freshly inserted KV
(BatchLLM's global-prefix observation).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.jct import LinearProxyJCT, PackedShapeJCT, Sample
from repro.core.kv_policy import KVLifecycle, bucket as _bucket
from repro.core.offload import (HostKVStore, OffloadPolicy,
                                TieredPrefixCache)
from repro.core.prefix_cache import PrefixCache, token_chain
from repro.core.scheduler import Request, Scheduler
from repro.models import transformer as tfm
from repro.models.layers import PAD_POS
from repro.models.model import cast_params
from repro.runtime.fault_tolerance import NaNGuard
from repro.serving.tracing import BatchRecord, JCTCalibrationMonitor


@dataclasses.dataclass
class EngineConfig:
    policy: str = "srjf_calibrated"
    lam: float = 0.05                 # starvation offset (JCT-sec per wait-sec)
    block_size: int = 16
    cache_capacity_tokens: int = 4096  # prefix-KV budget (profile run output)
    kv_keep_tokens: int = 10**9        # suffix discard threshold (per request)
    suffix_buckets: Tuple[int, ...] = (64, 128, 256, 512, 1024, 2048)
    prefix_bucket_blocks: int = 4      # reuse granularity: 4 blocks = 64 tok
    pack_token_budget: int = 2048      # prepacking: max COMPUTED tokens/step
    max_pack_requests: int = 16        # prepacking: max segments per step
                                       # (<=1 disables batch formation)
    pack_prefix_budget: int = 4096     # packed-hit path: max gathered prefix
                                       # tokens per step (attended, not
                                       # computed — cheaper than suffix toks)
    prefix_buckets: Tuple[int, ...] = (128, 256, 384, 512, 1024, 2048, 4096)
                                       # per-segment gathered-prefix pad
                                       # ladder: 128-steps below 512 (the
                                       # batched hit attention pays compute
                                       # proportional to pmax, so tight pads
                                       # matter), doubling above (the jit
                                       # key is (S, Nb, smax, pmax, K) and
                                       # batch composition shifts step to
                                       # step — a fine ladder up high would
                                       # recompile in steady state)
    autotune_pack: bool = True         # retune both from the profile() fit
    pack_inflation: float = 2.0        # max anchor-step slowdown autotune
                                       # accepts vs a typical solo step
    shape_cost_model: bool = True      # price batch formation with the
                                       # shape-aware PackedShapeJCT (marginal
                                       # padded-shape cost); False falls back
                                       # to the token-linear proxy on the
                                       # same marginal rule (benchmark arm)
    shape_pad_discount: float = 0.25   # unfitted-prior rent per padded slot,
                                       # as a fraction of the linear proxy's
                                       # per-computed-token rate
    offload: bool = False              # DRAM tier: evicted prefix blocks
                                       # demote to a HostKVStore instead of
                                       # being discarded (paper §9)
    host_cache_bytes: int = 256 << 20  # DRAM tier capacity per instance
    offload_host_bw: Optional[float] = None
                                       # override the OffloadPolicy's link
                                       # bandwidth (bytes/s). None = the
                                       # ChipSpec value, later replaced by
                                       # profile()'s measured bandwidth.
                                       # The worth_restoring economics are
                                       # priced for the TARGET chip, so CPU
                                       # smoke/benchmark runs of reduced
                                       # models pass a large value here to
                                       # force the restore path.


class PrefillOnlyEngine:
    """Single-instance engine over a dense-family model (real arrays)."""

    def __init__(self, cfg: ModelConfig, params,
                 ecfg: Optional[EngineConfig] = None):
        assert cfg.family in ("dense", "vlm", "audio", "moe"), cfg.family
        self.cfg = cfg
        self.params = cast_params(params, cfg.dtype)
        # per-engine config: a shared default instance would alias mutable
        # state (autotune) across every engine in a pool
        self.ecfg = ecfg = EngineConfig() if ecfg is None else ecfg
        # Guards queue / cache / results / jct_model. The engine is driven by
        # ONE worker thread (step) while router/server threads concurrently
        # submit, cancel, shed, and probe backlog — the forward itself runs
        # outside the lock so probes never wait on compute.
        self.lock = threading.RLock()
        # KV keep/discard has ONE owner: every keep-budget / residency /
        # insert-bound decision in this file asks self.kv (kv_policy).
        self.kv = KVLifecycle(block_size=ecfg.block_size,
                              kv_keep_tokens=ecfg.kv_keep_tokens,
                              buckets=ecfg.suffix_buckets)
        if ecfg.offload:
            # hierarchical KV memory: device blocks demote to host DRAM on
            # eviction, restore on match when cheaper than recompute
            self.cache: PrefixCache = TieredPrefixCache(
                ecfg.cache_capacity_tokens // ecfg.block_size,
                ecfg.block_size,
                host_store=HostKVStore(ecfg.host_cache_bytes), cfg=cfg,
                policy=OffloadPolicy(host_bw=ecfg.offload_host_bw))
        else:
            self.cache = PrefixCache(
                ecfg.cache_capacity_tokens // ecfg.block_size,
                ecfg.block_size)
        self.jct_model = LinearProxyJCT()
        # shape-aware step pricing (ISSUE 10): batch formation admits by
        # marginal padded-shape cost; routers/admission/Algorithm-1 keep the
        # per-request linear proxy on the miss-token axis
        self.shape_jct = PackedShapeJCT(
            fallback=self.jct_model, pad_discount=ecfg.shape_pad_discount)
        # usable_prefix hook: Algorithm-1 scores must price requests against
        # the prefix a forward would actually reuse, matching the hit-aware
        # predict_jct/pending_jct/shed probes — not the raw token match
        self.scheduler = Scheduler(ecfg.policy, self.jct_model, ecfg.lam,
                                   usable_prefix=self._usable_prefix_len)
        self.queue: List[Request] = []
        self.results: Dict[int, Dict] = {}
        self._fresh_fns: Dict[Tuple[int, int], callable] = {}
        self._suffix_fns: Dict[Tuple[int, int, int], callable] = {}
        self._packed_fns: Dict[Tuple[int, int], callable] = {}
        self._packed_hit_fns: Dict[Tuple[int, int, int], callable] = {}
        self._last_step_ids: List[int] = []    # all requests served by the
                                               # most recent step()
        self._inflight: List[int] = []         # popped by step(), not yet in
                                               # results (crash accounting)
        self._inflight_pred = 0.0              # predicted cost of that batch
        self._inflight_t0 = 0.0                # and when it started
        self.steps = 0
        self.hit_tokens = 0
        self.total_tokens = 0
        self.packed_steps = 0          # steps that executed >1 request
        self.packed_requests = 0       # requests served via prepacking
        self.packed_hit_requests = 0   # ...of which rode a cached prefix
        self.padded_slots = 0          # bucketed forward slots actually paid
        self.pack_skew_splits = 0      # packs closed early because the best
                                       # remaining candidate's padding
                                       # externality exceeded its benefit
        self._formed_cost = 0.0        # shape-priced cost of the last pack
        self._step_compiled = False    # step hit a fresh jit shape
        # result validation: a forward can emit non-finite logits (bad
        # checkpoint cast, accelerator fault) — such results are flagged
        # "corrupt" so the serving layer quarantines them instead of
        # delivering NaN scores; consecutive corruption advises a reload
        # via the training-side NaNGuard policy
        self.result_guard = NaNGuard(limit=3)
        self.nonfinite_results = 0
        # brownout hook (serving): when degraded, cache-HIT requests skip
        # the batched gathered-prefix path and run the cheap solo-suffix
        # path instead — per-step cost variance collapses under overload
        self.degraded = False
        # observability: always-on bounded per-step BatchRecords + online
        # JCT-calibration monitoring (residuals per bucket class, drift ->
        # forced refit). Prometheus/trace export activates via
        # bind_telemetry(); unbound, the only cost is the ring append.
        self.batch_records: "deque[BatchRecord]" = deque(maxlen=256)
        self.jct_monitor = JCTCalibrationMonitor(
            self.jct_model, buckets=ecfg.suffix_buckets,
            shape_model=self.shape_jct)
        self.metrics = None
        self.instance_name = ""
        self.tracer = None
        self._last_jit: Tuple[str, Tuple, bool] = ("", (), False)
        self._last_shape: Dict[str, int] = {}

    # ---- profile run (paper §3.1) ------------------------------------------
    def profile(self, lengths: Sequence[int] = (64, 128, 256, 512)) -> float:
        """Measure jct(n_input, 0) on this host, fit the linear proxy."""
        samples: List[Sample] = []
        rng = np.random.default_rng(0)
        for n in lengths:
            toks = rng.integers(0, self.cfg.vocab_size, size=n).tolist()
            self._run_fresh(toks)            # warm-up: exclude compile time
            for _ in range(2):               # steady-state samples
                t0 = time.perf_counter()
                logits, _, _ = self._run_fresh(toks)
                jax.block_until_ready(logits)
                samples.append((n, 0, time.perf_counter() - t0))
        self.jct_model.fit(samples)
        if (isinstance(self.cache, TieredPrefixCache)
                and self.ecfg.offload_host_bw is None):
            # override the ChipSpec host-bandwidth constant with THIS host's
            # measured device<->host copy rate: worth_restoring's break-even
            # then prices transfers the way this machine actually pays them.
            # An explicit offload_host_bw config wins over the measurement.
            self.cache.policy.host_bw = self._measure_host_bw()
        if self.ecfg.autotune_pack:
            self.autotune_packing(ref_len=max(lengths))
        return self.jct_model.pearson_r

    def _measure_host_bw(self, nbytes: int = 8 << 20) -> float:
        """Measured device->host->device round-trip bandwidth (bytes/s)."""
        arr = jnp.zeros((nbytes // 4,), jnp.float32)
        jax.block_until_ready(arr)
        t0 = time.perf_counter()
        host = np.asarray(arr)                       # device -> host
        back = jnp.asarray(host)                     # host -> device
        jax.block_until_ready(back)
        dt = max(time.perf_counter() - t0, 1e-9)
        return 2.0 * nbytes / dt

    def autotune_packing(self, ref_len: int) -> Tuple[int, int]:
        """Tune ``pack_token_budget`` / ``max_pack_requests`` from the fitted
        JCT curve instead of fixed defaults (ROADMAP follow-up).

        Packing trades anchor latency for throughput: a packed step costs
        jct(total tokens) instead of jct(anchor tokens). Accept that trade up
        to ``pack_inflation``x the cost of a typical solo step (a ``ref_len``
        request — the largest profiled length): with jct = a*S + b the budget
        solves a*S + b <= inflation * (a*ref + b), so hosts with a large
        fixed overhead b relative to per-token cost a (where amortizing b is
        the whole win) get a proportionally larger budget. The request cap
        follows as budget / smallest-bucket, i.e. the most segments a full
        budget could plausibly hold.
        """
        m, ecfg = self.jct_model, self.ecfg
        if m.a <= 0:
            return ecfg.pack_token_budget, ecfg.max_pack_requests
        max_step = ecfg.pack_inflation * m.predict(ref_len)
        floor = _bucket(ref_len, ecfg.suffix_buckets)
        budget = max([floor] + [s for s in ecfg.suffix_buckets
                                if m.predict(s) <= max_step])
        n_max = int(np.clip(budget // max(1, ecfg.suffix_buckets[0]), 1, 64))
        # gathered prefix tokens are attended, not computed — the per-token
        # cost the proxy fits barely sees them, so the hit path can carry a
        # proportionally larger prefix buffer than its computed budget
        self.ecfg = dataclasses.replace(ecfg, pack_token_budget=budget,
                                        max_pack_requests=n_max,
                                        pack_prefix_budget=max(
                                            ecfg.pack_prefix_budget,
                                            2 * budget))
        return budget, n_max

    # ---- request lifecycle ---------------------------------------------------
    def submit(self, tokens: Sequence[int],
               allowed_tokens: Optional[Sequence[int]] = None,
               user_id: Optional[str] = None, now: Optional[float] = None,
               deadline: Optional[float] = None,
               chain: Optional[Tuple[int, ...]] = None) -> int:
        now = time.perf_counter() if now is None else now
        r = Request(n_input=len(tokens), arrival=now,
                    chain=(token_chain(tokens, self.ecfg.block_size)
                           if chain is None else chain),
                    tokens=list(tokens), user_id=user_id,
                    allowed_tokens=tuple(allowed_tokens) if allowed_tokens else None,
                    deadline=deadline)
        with self.lock:
            # probe_len: serveable prefix incl. the host tier, restore-free
            r.n_cached_at_arrival = self.cache.probe_len(r.chain)
            self.queue.append(r)
        return r.req_id

    def cancel(self, req_id: int) -> Optional[Request]:
        """Remove a QUEUED request (no effect once executing). Returns the
        removed request, or None if it was not waiting here."""
        with self.lock:
            for i, r in enumerate(self.queue):
                if r.req_id == req_id:
                    return self.queue.pop(i)
        return None

    def shed_expired(self, now: Optional[float] = None) -> List[Request]:
        """Pop queued requests that cannot meet their deadline anymore:
        even starting RIGHT NOW, now + predicted JCT > deadline. Shedding
        them early converts a guaranteed tail-latency blowup into a cheap
        typed rejection (admission control's in-queue half)."""
        now = time.perf_counter() if now is None else now
        shed: List[Request] = []
        with self.lock:
            keep = []
            for r in self.queue:
                if r.deadline is not None and (
                        now + self.jct_model.predict(
                            r.n_input, self._usable_prefix_len(
                                r.n_input,
                                self.cache.probe_blocks(r.chain)))
                        > r.deadline):
                    shed.append(r)
                else:
                    keep.append(r)
            if shed:
                self.queue[:] = keep
        return shed

    def pending_jct(self, now: Optional[float] = None) -> float:
        """Predicted seconds of queued work PLUS the predicted remainder of
        the batch executing right now — the backlog signal JCT-aware routing
        ranks instances by. Only meaningful because prefill-only JCT is
        precisely predictable.

        Queued requests are scored against their ARRIVAL-time cache match
        (already computed by submit), not re-walked against the live cache:
        the router calls this for every instance on every arrival, and an
        O(queue x chain) walk under the engine lock would contend with the
        worker exactly when routing matters most. The estimate only errs
        conservative (the cache can have warmed since arrival, never
        cooled for a queued request's own prefix).

        Hit-aware: the raw match is first bucketed down to the prefix the
        engine would actually REUSE (``_usable_prefix_len``), so the backlog
        the router ranks by reflects real computed-token cost, not an
        optimistic token-granular match."""
        now = time.perf_counter() if now is None else now
        bs = self.ecfg.block_size
        with self.lock:
            queued = sum(
                self.jct_model.predict(
                    r.n_input, self._usable_prefix_len(
                        r.n_input, r.n_cached_at_arrival // bs))
                for r in self.queue)
            running = 0.0
            if self._inflight:
                running = max(0.0, self._inflight_pred
                              - (now - self._inflight_t0))
            return queued + running

    def predict_jct(self, n_input: int, chain: Tuple[int, ...] = ()) -> float:
        """Predicted JCT of a PROSPECTIVE request given this instance's
        cache state (router's per-instance cost probe). Hit-aware: predicts
        against the reuse-granularity prefix the engine would actually use,
        never the raw (token-granular, whole-request-consuming) match."""
        with self.lock:
            return self.jct_model.predict(
                n_input, self._usable_prefix_len(
                    n_input, self.cache.probe_blocks(chain)))

    def cached_prefix_len(self, chain: Tuple[int, ...]) -> int:
        with self.lock:
            return self.cache.probe_len(chain)

    def probe(self, n_input: int,
              chain: Tuple[int, ...] = ()) -> Tuple[float, float, int]:
        """All three router probes — ``(pending_jct, predict_jct,
        cached_prefix_len)`` — in ONE lock acquisition. The RPC worker
        plane serves a router scan as a single round trip through this
        instead of three, and in-process callers get the same atomicity
        (the three values describe one consistent cache/queue state)."""
        with self.lock:
            return (self.pending_jct(), self.predict_jct(n_input, chain),
                    self.cache.probe_len(chain))

    @property
    def last_step_ids(self) -> List[int]:
        return list(self._last_step_ids)

    def inflight_snapshot(self) -> Tuple[List[int], float, float]:
        """(in-flight request ids, predicted batch JCT, start timestamp) —
        the serving watchdog's hang probe. A batch still in flight past
        ``factor x`` the predicted JCT is provably wedged (prefill-only JCT
        is precisely predictable), so this triple is all a watchdog needs.

        A step that triggered a fresh jit compile reports EMPTY: compile
        time is unbounded and outside the JCT model (the same reason step()
        excludes compile steps from the fit), so "provably wedged" does not
        hold — the deadline applies from the first warm execution of a
        shape on."""
        with self.lock:
            if self._step_compiled:
                return [], 0.0, 0.0
            return (list(self._inflight), self._inflight_pred,
                    self._inflight_t0)

    def bind_telemetry(self, metrics=None, instance: str = "",
                       tracer=None) -> None:
        """Attach the serving registry and/or a SpanTracer. The JCT monitor
        exports coefficient gauges immediately so a scrape before the first
        warm step still sees the profile() fit."""
        self.metrics = metrics
        self.instance_name = instance
        self.tracer = tracer
        self.jct_monitor.bind(metrics, instance)

    def set_degraded(self, flag: bool) -> None:
        """Brownout level >=2 hook: disable hit co-packing's batched
        gathered-prefix forward (hits run the cheap solo-suffix path,
        misses still co-pack). Takes effect at the next batch formation."""
        with self.lock:
            self.degraded = bool(flag)

    # ---- DRAM offload tier (paper §9) ---------------------------------------
    def _match_restoring(self, chain: Tuple[int, ...],
                         rid: Optional[int] = None) -> int:
        """``match_blocks(touch=True)`` with restore observability: on the
        tiered cache a match can pull blocks back from the host store —
        time it, count it, emit the ``restore`` span + series. Execution
        path only; call under the engine lock."""
        c = self.cache
        if not isinstance(c, TieredPrefixCache):
            return c.match_blocks(chain, touch=True)
        r0, b0 = c.restored_blocks, c.host.restore_bytes
        t0 = time.perf_counter()
        matched = c.match_blocks(chain, now=t0, touch=True)
        blocks = c.restored_blocks - r0
        if blocks:
            self._note_tier("restore", rid, blocks,
                            c.host.restore_bytes - b0, t0,
                            time.perf_counter())
        return matched

    def _note_tier(self, kind: str, rid: Optional[int], blocks: int,
                   nbytes: int, t0: float, t1: float) -> None:
        """Export one restore/prefetch episode as Prometheus series and (when
        a request id is known) a SpanTracer phase."""
        m, inst = self.metrics, self.instance_name
        if m is not None:
            m.counter(f"kv_{kind}_blocks", inst,
                      help=f"KV blocks moved host->device by {kind}").inc(
                blocks)
            m.counter(f"kv_{kind}_bytes", inst).inc(nbytes)
            m.histogram(f"kv_{kind}_seconds", inst,
                        help=f"wall seconds per {kind} episode").observe(
                t1 - t0)
        tr = self.tracer
        if tr is not None and rid is not None:
            tr.span_rid(rid, kind, t0, t1, instance=inst,
                        blocks=blocks, bytes=int(nbytes))

    def restore_estimate(self, chain: Tuple[int, ...]) -> Dict[str, float]:
        """Restorable host-tier continuation of ``chain`` and its priced
        transfer time — admission folds ``restore_s`` into the JCT bound,
        the router-time prefetch decides off ``blocks``. Zeros on an
        un-tiered engine."""
        c = self.cache
        if not isinstance(c, TieredPrefixCache):
            return {"device_blocks": 0, "blocks": 0, "bytes": 0,
                    "restore_s": 0.0}
        with self.lock:
            return c.restore_estimate(chain)

    def prefetch_prefix(self, chain: Tuple[int, ...],
                        rid: Optional[int] = None) -> int:
        """Async host->device prefetch of ``chain``'s restorable
        continuation, triggered at routing time (the router knows the
        usable prefix before the forward runs). Returns the block count
        scheduled (0 = nothing restorable / no tier). The transfer runs on
        a daemon thread: restore into the device cache under the lock, then
        materialize the payloads as device arrays OUTSIDE the lock so the
        execute-path concatenate hits device-resident KV."""
        c = self.cache
        if not isinstance(c, TieredPrefixCache):
            return 0
        with self.lock:
            est = c.restore_estimate(chain)
        if not est["blocks"]:
            return 0
        threading.Thread(target=self._prefetch_worker,
                         args=(tuple(chain), rid),
                         daemon=True, name="kv-prefetch").start()
        return int(est["blocks"])

    def _prefetch_worker(self, chain: Tuple[int, ...],
                         rid: Optional[int]) -> None:
        c = self.cache
        t0 = time.perf_counter()
        with self.lock:
            r0, b0 = c.restored_blocks, c.host.restore_bytes
            matched = c.match_blocks(chain, now=t0, touch=True)
            blocks = c.restored_blocks - r0
            nbytes = c.host.restore_bytes - b0
            hs = chain[matched - blocks:matched] if blocks else ()
            host_payloads = [(h, c.blocks[h].payload) for h in hs
                             if h in c.blocks
                             and c.blocks[h].payload is not None]
        if not blocks:
            return
        # host -> device outside the lock (the copy is the slow part)
        dev = [(h, tuple(jnp.asarray(p) for p in payload))
               for h, payload in host_payloads]
        for _, payload in dev:
            jax.block_until_ready(payload)
        with self.lock:
            for h, payload in dev:
                blk = c.blocks.get(h)
                # only upgrade a still-host-resident numpy payload — never
                # clobber KV a concurrent insert refreshed on device
                if blk is not None and blk.payload is not None and isinstance(
                        blk.payload[0], np.ndarray):
                    blk.payload = payload
        self._note_tier("prefetch", rid, blocks, nbytes, t0,
                        time.perf_counter())

    def step(self) -> Optional[int]:
        """One scheduling step: pick (Algorithm 1), form a packed batch,
        prefill, cache, score. Returns the anchor request's id."""
        now = time.perf_counter()
        batch = self._form_batch(now)
        if batch is None:
            return None
        for r in batch:
            r.start_time = now
        with self.lock:
            self._inflight = [r.req_id for r in batch]
            # the shape-priced cost of the formed pack — the watchdog
            # deadline and BatchRecord.predicted_jct consume the same number
            # batch formation admitted against
            self._inflight_pred = self._formed_cost
            self._inflight_t0 = now
        self._step_compiled = False
        padded0 = self.padded_slots
        if len(batch) == 1:
            r = batch[0]
            logits = self._execute(r)
            # async dispatch: sync before timestamping, or the JCT model
            # observes launch latency instead of compute time
            jax.block_until_ready(logits)
            done = r.finish_time = time.perf_counter()
            with self.lock:
                self.results[r.req_id] = self._score(logits, r)
                # steps that compiled a fresh shape are NOT JCT samples — a
                # multi-second jit compile recorded as serving cost wrecks the
                # refit (profile() excludes compiles the same way via warm-up)
                if not self._step_compiled:
                    self.jct_model.observe(r.n_input, r.n_cached_at_start,
                                           r.finish_time - now)
        else:
            logits = self._execute_packed(batch)
            jax.block_until_ready(logits)
            done = time.perf_counter()
            with self.lock:
                for n, r in enumerate(batch):
                    r.finish_time = done
                    self.results[r.req_id] = self._score(logits[n:n + 1], r)
                # packed cost is a function of COMPUTED tokens — misses
                # compute all their tokens, hits only their suffixes: report
                # it on the same miss-token axis Algorithm 1 scores with, so
                # mixed hit/miss batches don't skew the fit that
                # autotune_packing and admission feasibility consume
                if not self._step_compiled:
                    self.jct_model.observe(
                        sum(r.n_input - r.n_cached_at_start for r in batch),
                        0, done - now)
            self.packed_steps += 1
            self.packed_requests += len(batch)
            self.packed_hit_requests += sum(
                1 for r in batch if r.n_cached_at_start > 0)
        self.steps += 1
        self._last_step_ids = [r.req_id for r in batch]
        self._record_step(batch, now, done, time.perf_counter(), padded0)
        with self.lock:
            self._inflight = []
            self._inflight_pred = 0.0
        return batch[0].req_id

    def _record_step(self, batch: List[Request], t0: float, t_done: float,
                     t_scored: float, padded0: int) -> None:
        """Observability epilogue of step(): BatchRecord into the ring, JCT
        calibration sample (warm steps only), per-request trace spans."""
        pred = self._inflight_pred
        computed = sum(r.n_input - r.n_cached_at_start for r in batch)
        kind = ("solo" if len(batch) == 1
                else "hit" if any(r.n_cached_at_start for r in batch)
                else "miss")
        path, key, _ = self._last_jit
        shape = self._last_shape
        rec = BatchRecord(
            step=self.steps, ts=t_done, instance=self.instance_name,
            kind=kind, n_requests=len(batch),
            req_ids=tuple(r.req_id for r in batch),
            computed_tokens=computed,
            padded_tokens=self.padded_slots - padded0,
            S=shape.get("S", 0), Nb=shape.get("Nb", 0),
            smax=shape.get("smax", 0), pmax=shape.get("pmax", 0),
            K=shape.get("K", 0), jit_path=path, jit_key=key,
            compiled=self._step_compiled, predicted_jct=pred,
            wall=t_done - t0)
        self.batch_records.append(rec)
        # compile steps are excluded from calibration for the same reason
        # they are excluded from the JCT fit: compile time is unbounded and
        # not a prediction error
        if not self._step_compiled:
            self.jct_monitor.observe(pred, t_done - t0, computed, kind=kind)
            # the shape model learns from the realized (shape, wall) pair —
            # the same BatchRecord axes formation priced the pack on
            self.shape_jct.observe(computed, rec.S, rec.Nb, rec.smax,
                                   rec.pmax, rec.wall)
        m = self.metrics
        if m is not None:
            m.gauge("step_padding_waste", self.instance_name).set(
                rec.padding_waste)
            m.histogram("padding_waste", self.instance_name).observe(
                rec.padding_waste)
            m.counter("padded_slots", self.instance_name).inc(
                rec.padded_tokens)
            m.counter(f"pack_{kind}_steps", self.instance_name).inc()
            m.histogram("batch_wall_seconds", self.instance_name).observe(
                rec.wall)
            if isinstance(self.cache, TieredPrefixCache):
                hs = self.cache.host.stats()
                m.gauge("host_kv_used_bytes", self.instance_name,
                        help="DRAM offload tier occupancy").set(
                    hs["used_bytes"])
                m.gauge("host_kv_blocks", self.instance_name).set(
                    hs["blocks"])
                m.gauge("kv_offload_blocks", self.instance_name,
                        help="KV blocks demoted device->host (cumulative)"
                        ).set(hs["offloads"])
                m.gauge("kv_offload_bytes", self.instance_name).set(
                    hs["offload_bytes"])
        tr = self.tracer
        if tr is None:
            return
        tr.record_batch(rec)
        inst = self.instance_name
        peers = [r.req_id for r in batch]
        for r in batch:
            tr.span_rid(r.req_id, "queue", r.arrival, t0, instance=inst)
            tr.span_rid(r.req_id, "execute", t0, t_done, instance=inst,
                        pack=kind, compiled=self._step_compiled,
                        jit_path=path)
            tr.span_rid(r.req_id, "score", t_done, t_scored, instance=inst)
            tr.event_rid(r.req_id, "batch", kind=kind, step=self.steps,
                         peers=[p for p in peers if p != r.req_id],
                         predicted_jct=pred, computed_tokens=computed,
                         n_cached=r.n_cached_at_start)
            if self._step_compiled:
                tr.event_rid(r.req_id, "jit_compile", path=path,
                             key=list(key))

    # ---- batch formation (prepacking) ---------------------------------------
    def _usable_prefix_len(self, n_input: int, matched_blocks: int) -> int:
        """Bucketed prefix-reuse length given a raw cache match in blocks
        (granularity ``prefix_bucket_blocks``; >=1 fresh token guaranteed —
        the last token's logits must be computed). Static arithmetic shared
        by execution and by the hit-aware routing/shedding probes, so
        predictions match what a forward would actually reuse."""
        bs = self.ecfg.block_size
        gran = self.ecfg.prefix_bucket_blocks
        prefix_len = (matched_blocks // gran) * gran * bs
        if prefix_len >= n_input:
            prefix_len = max(0, ((n_input - 1) // (gran * bs)) * gran * bs)
        return prefix_len

    def _usable_prefix(self, r: Request, touch: bool = False) -> int:
        """Bucketed prefix-reuse length for ``r`` against the current cache.
        Non-touch callers (batch formation, inflight pricing) get the
        side-effect-free probe — on the tiered cache an eager match here
        would restore host blocks for requests that may never run."""
        if touch:
            matched = self.cache.match_blocks(r.chain, touch=True)
        else:
            matched = self.cache.probe_blocks(r.chain)
        return self._usable_prefix_len(r.n_input, matched)

    def _pack_shape(self, rows: List[Tuple[int, int]]) -> Tuple[
            int, int, int, int, int]:
        """Realized step shape ``(S, Nb, smax, pmax, pad_slots)`` for a pack
        of ``rows`` = [(suffix_tokens, usable_prefix), ...].

        Mirrors ``_execute_packed``'s layout arithmetic exactly so formation
        prices the same shape execution will pay. A single row prices the
        solo path: S = bucketed suffix, exact prefix buffer (Nb/smax = 0 by
        the ``step_features`` canonicalization). ``pad_slots`` counts the
        padded-but-dead slots a candidate's admission is charged for:
        Σ(pmax−pref_i) + Σ(smax−suf_i) over the REAL rows packed, bucket
        slack solo. The pow2 ghost rows (Nb−N) are deliberately not charged
        here: they are a step-function layout artifact that would make
        marginal admission oscillate at row-power boundaries — the fitted
        model prices them from data (Nb is in its feature basis).
        """
        ecfg = self.ecfg
        if len(rows) == 1:
            suffix, pref = rows[0]
            S = _bucket(suffix, ecfg.suffix_buckets)
            return S, 0, 0, pref, S - suffix
        suffixes = [s for s, _ in rows]
        total = sum(suffixes)
        S = _bucket(total, ecfg.suffix_buckets)
        P_max = max(p for _, p in rows)
        pmax = _bucket(P_max, ecfg.prefix_buckets) if P_max else 0
        Nb = 1
        while Nb < len(rows):
            Nb *= 2
        smax = _bucket(max(suffixes), (32, 48) + ecfg.suffix_buckets)
        if not pmax:
            # all-miss pack executes as ONE flat (1, S) sequence — no row
            # padding; only the bucket slack is dead
            return S, Nb, smax, 0, S - total
        pad = (sum(pmax - p for _, p in rows)
               + sum(smax - s for s in suffixes))
        return S, Nb, smax, pmax, pad

    def _pack_cost(self, rows: List[Tuple[int, int]]) -> float:
        """Predicted wall seconds for one step over ``rows``.

        ``shape_cost_model=False`` keeps the legacy token-linear pricing
        (cost depends only on bucketed computed tokens) — the marginal admit
        rule then reduces exactly to the old
        ``jct(bucket(total+suffix)) <= jct(bucket(total)) + jct(bucket(suffix))``
        inequality, which is the benchmark's comparison arm.
        """
        computed = sum(s for s, _ in rows)
        if not self.ecfg.shape_cost_model:
            return self.jct_model.predict(
                _bucket(computed, self.ecfg.suffix_buckets))
        S, Nb, smax, pmax, pad = self._pack_shape(rows)
        return self.shape_jct.predict(computed, S, Nb, smax, pmax,
                                      pad_slots=pad)

    def _form_batch(self, now: float) -> Optional[List[Request]]:
        """Algorithm 1 pick + marginal-cost backfill (shape-priced).

        The anchor is exactly the scheduler's pick, so SRJF-calibrated order
        is preserved. Backfill then grows the pack greedily: every queued
        candidate is priced by its MARGINAL shape-aware batch cost
        ``cost(pack + r) − cost(pack)`` against its solo cost, and the
        scheduler's ``pick_backfill`` admits the candidate with the largest
        benefit ``solo(r) − marginal(r)``. Cache misses contribute their
        full length, cache hits only their suffix — hit segments attend
        their cached prefix KV through the gathered prefix buffer, so hit
        anchors are backfillable and hit candidates co-pack.

        ``cost`` is the PackedShapeJCT prediction over the realized padded
        shape (S, Nb, smax, pmax): a long-prefix or long-suffix row that
        re-prices every already-admitted row's padding shows up as a large
        marginal and is rejected by PRICE — this replaces the old
        ``pb > 2*pmax_b`` / ``pref > 4*(total+suffix)`` heuristic blowup
        gates. When the best remaining candidate's benefit is negative the
        pack CLOSES (skew split, counted in ``pack_skew_splits``): the
        rejected candidates stay queued and seed the next step's low-skew
        pack instead of inflating this one.

        Hard gates (not priced): computed tokens <= ``pack_token_budget``;
        gathered prefix tokens <= ``pack_prefix_budget``; brownout skips hit
        gathers. Requests sharing a prefix root (same first hash-chain
        block) co-pack ONLY when both sides already hit the cache (each
        attends its own gathered copy of the shared KV). A miss sharing a
        root still runs sequentially, so the later request hits the earlier
        one's freshly inserted KV — that reuse beats any packing win
        (BatchLLM's global-prefix observation).
        """
        with self.lock:
            i = self.scheduler.pick(self.queue, self.cache, now)
            if i is None:
                return None
            anchor = self.queue.pop(i)
            batch = [anchor]
            ecfg = self.ecfg
            pref_a = self._usable_prefix(anchor)
            rows = [(anchor.n_input - pref_a, pref_a)]
            if (ecfg.max_pack_requests <= 1 or ecfg.pack_token_budget <= 0
                    or not self.queue or (self.degraded and pref_a)):
                # brownout: a hit anchor runs the cheap solo-suffix path
                # instead of anchoring a batched gathered-prefix forward
                self._formed_cost = self._pack_cost(rows)
                return batch
            total = rows[0][0]                     # computed suffix tokens
            pref_total = pref_a
            hit_roots = ({anchor.chain[0]: pref_a > 0} if anchor.chain
                         else {})
            # one cache walk per candidate (the same O(chain) walk pick()
            # already paid this step) — suffix lengths drive the budget
            # gates and the shape pricing, so they must be known up front
            cands = [(r, self._usable_prefix(r)) for r in self.queue]
            pack_cost = self._pack_cost(rows)

            def benefit(r: Request, pref: int) -> Optional[float]:
                if self.degraded and pref:
                    return None    # brownout: no batched hit gather
                suffix = r.n_input - pref
                if total + suffix > ecfg.pack_token_budget:
                    return None
                if pref and pref_total + pref > ecfg.pack_prefix_budget:
                    return None
                root = r.chain[0] if r.chain else None
                if root is not None and root in hit_roots and not (
                        hit_roots[root] and pref > 0):
                    return None
                marginal = self._pack_cost(rows + [(suffix, pref)]) - pack_cost
                return self._pack_cost([(suffix, pref)]) - marginal

            while len(batch) < ecfg.max_pack_requests and cands:
                j = self.scheduler.pick_backfill(cands, benefit)
                if j is None:
                    break
                r, pref = cands[j]
                if benefit(r, pref) < 0:
                    # the BEST remaining candidate would cost more in this
                    # pack than solo: its padding externality on admitted
                    # rows exceeds the co-packing gain — close the pack
                    self.pack_skew_splits += 1
                    break
                cands.pop(j)
                batch.append(r)
                rows.append((r.n_input - pref, pref))
                total += r.n_input - pref
                pref_total += pref
                pack_cost = self._pack_cost(rows)
                root = r.chain[0] if r.chain else None
                if root is not None:
                    hit_roots.setdefault(root, pref > 0)
            self._formed_cost = pack_cost
            for r in batch[1:]:
                self.queue.remove(r)
            return batch

    def run_until_drained(self) -> List[int]:
        """Serve until the queue is empty; returns one id per served request
        in completion order (a packed step contributes its whole batch,
        anchor first)."""
        done = []
        while self.queue:
            if self.step() is not None:
                done.extend(self._last_step_ids)
        return done

    # ---- execution -----------------------------------------------------------
    def _execute(self, r: Request) -> jax.Array:
        bs = self.ecfg.block_size
        # cache probe + pin under the lock; the forward itself runs outside
        # it so router/admission probes never block on compute
        with self.lock:
            matched = self._match_restoring(r.chain, rid=r.req_id)
            prefix_len = self._usable_prefix_len(r.n_input, matched)
            use_blocks = prefix_len // bs
            r.n_cached_at_start = prefix_len
            self.hit_tokens += prefix_len
            self.total_tokens += r.n_input
            self.padded_slots += prefix_len + _bucket(
                r.n_input - prefix_len, self.ecfg.suffix_buckets)
            keep = self.kv.keep(r.n_input)
            # chain already resident past the keep bound: the insert below
            # would only re-slice and re-touch existing blocks — skip it
            # (the match walk above refreshed their LRU standing)
            resident = self.kv.resident(matched, r.n_input)
            if prefix_len:
                self.cache.pin(r.chain, use_blocks)
                payloads = self.cache.match_payloads(r.chain)[:use_blocks]
                pk = jnp.concatenate([p[0] for p in payloads], axis=2)
                pv = jnp.concatenate([p[1] for p in payloads], axis=2)
        if prefix_len == 0:
            logits, new_kv, n_new = self._run_fresh(r.tokens, keep)
            kv_from = 0
        else:
            logits, new_kv, n_new = self._run_suffix(
                r.tokens[prefix_len:], pk, pv, prefix_len, keep)
            kv_from = prefix_len
        # split fresh KV into block payloads and insert (suffix discard:
        # only up to ``keep`` tokens total)
        with self.lock:
            if prefix_len:
                self.cache.unpin(r.chain, use_blocks)
            if not resident:
                n_insertable = self.kv.insertable_tokens(keep, kv_from, n_new)
                n_blocks_new = n_insertable // bs
                payloads_all = self.cache.match_payloads(
                    r.chain)[:use_blocks]
                for b in range(n_blocks_new):
                    k_b = new_kv["k"][:, :, b * bs:(b + 1) * bs]
                    v_b = new_kv["v"][:, :, b * bs:(b + 1) * bs]
                    payloads_all.append((k_b, v_b))
                self.cache.insert(r.chain, kv_from + n_blocks_new * bs,
                                  now=time.perf_counter(),
                                  payloads=payloads_all)
        return logits

    def _run_fresh(self, tokens: Sequence[int], keep: int = 0):
        S = _bucket(len(tokens), self.ecfg.suffix_buckets)
        # jit-key bucketing of the keep budget is owned by KVLifecycle
        keep_pad = self.kv.keep_pad(keep, S)
        key = (S, keep_pad)
        self._last_jit = ("fresh", key, key not in self._fresh_fns)
        self._last_shape = {"S": S}
        if key not in self._fresh_fns:
            self._step_compiled = True
            cfg = self.cfg

            @jax.jit
            def fn(params, toks, last_index):
                return tfm.prefill(params, cfg, {"tokens": toks},
                                   kv_keep=keep_pad, last_index=last_index)

            self._fresh_fns[key] = fn
        toks = np.zeros((1, S), np.int32)
        toks[0, :len(tokens)] = tokens
        logits, kv = self._fresh_fns[key](
            self.params, jnp.asarray(toks),
            jnp.asarray([len(tokens) - 1], jnp.int32))
        if kv is None:
            return logits, {"k": None, "v": None}, 0
        # kv: (L, 1, keep_pad, KV, hd); valid fresh tokens = len(tokens),
        # usable budget = the caller's keep (keep_pad only pads the jit key)
        n_new = min(keep, keep_pad, len(tokens))
        return logits, kv, n_new

    def _execute_packed(self, batch: List[Request]) -> jax.Array:
        """Run N requests (cache hits AND misses) as one prepacked forward.

        Returns (N, V) logits — one row per request. Hit segments pack only
        their SUFFIX tokens; their cached prefix KV is gathered into one
        contiguous per-segment prefix buffer the packed attention reads
        through position-masked segment restriction
        (``tfm.prefill_packed_with_prefix``). All-miss batches take the
        plain ``tfm.prefill_packed`` path unchanged.

        Suffix discard is per-segment, which a packed-sequence prefix budget
        cannot express, so the forward gathers exactly each request's keep
        window via ``kv_indices``: the stacked KV costs K kept tokens (same
        bound as the solo path), not S, and each window is inserted under
        its own chain — hits extend their chain past the reused prefix, so
        cache inserts keep the solo-path memory bound.
        """
        bs = self.ecfg.block_size
        N = len(batch)
        # cache probe + pin under the lock; the forward runs outside it so
        # router/admission probes never block on compute (solo-path rule)
        prefs: List[Tuple[int, List, int]] = []
        with self.lock:
            for r in batch:
                matched = self._match_restoring(r.chain, rid=r.req_id)
                plen = self._usable_prefix_len(r.n_input, matched)
                r.n_cached_at_start = plen
                payloads = []
                if plen:
                    self.cache.pin(r.chain, plen // bs)
                    payloads = self.cache.match_payloads(
                        r.chain)[:plen // bs]
                prefs.append((plen, payloads, matched))
                self.hit_tokens += plen
                self.total_tokens += r.n_input
        suffixes = [r.n_input - p for r, (p, _, _) in zip(batch, prefs)]
        total = sum(suffixes)
        # realized step shape — the SAME arithmetic batch formation priced
        # the pack with (_pack_shape): per-segment prefix pad on a coarse
        # ladder (the jit key space is a product of ladders and batch
        # composition shifts step to step, so pmax must quantize hard or
        # steady state keeps compiling); rows padded to a power of two;
        # sub-bucket smax floor (hit suffixes are typically a few tens of
        # tokens and the batched attention's dominant einsum scales with
        # smax — padding 34 real tokens to the 64-token forward bucket
        # would burn ~2x there)
        S, Nb, smax, pmax, _ = self._pack_shape(
            [(r.n_input - p, p) for r, (p, _, _) in zip(batch, prefs)])
        # block-aligned NEW keep per request (only whole blocks are
        # insertable; a hit's cached prefix already covers its first
        # blocks). A chain already resident past its keep bound needs NO
        # fresh KV at all — steady-state repeat traffic then skips both the
        # forward's kv gather and the insert-side slicing entirely.
        keeps = [self.kv.keep_new(r.n_input, p, matched)
                 for r, (p, _, matched) in zip(batch, prefs)]
        # pad the gather length to a bucket so jit keys stay bounded; on the
        # hit path tie it to S outright (sum(keeps) <= packed suffix tokens)
        if not sum(keeps):
            K = 0
        elif pmax:
            K = S
        else:
            K = _bucket(sum(keeps), self.ecfg.suffix_buckets)
        toks = np.zeros((1, S), np.int32)
        segs = np.full((1, S), -1, np.int32)   # -1 = padding slack
        pos = np.zeros((1, S), np.int32)
        # last_idx is padded to max_pack_requests so the jit cache keys only
        # on the bucket shape, not on the batch size (duplicate rows of the
        # last real segment's logits are computed and dropped — N x V is
        # noise next to the forward)
        last_idx = np.zeros((max(N, self.ecfg.max_pack_requests),), np.int32)
        kv_idx = np.zeros((K,), np.int32)
        seg_qidx = np.full((Nb, smax), -1, np.int32)
        inv_idx = np.zeros((S,), np.int32)
        # padding prefix slots get a huge position: the causal mask
        # (suffix pos >= prefix pos) kills them
        ppos = np.full((Nb, pmax), PAD_POS, np.int32)
        pk_rows: List = []
        pv_rows: List = []
        off = cum = 0
        for n, r in enumerate(batch):
            plen, payloads, _ = prefs[n]
            L = suffixes[n]
            toks[0, off:off + L] = r.tokens[plen:]
            segs[0, off:off + L] = n
            # RoPE restarts at each segment's OWN prefix length
            pos[0, off:off + L] = plen + np.arange(L)
            last_idx[n] = off + L - 1
            kv_idx[cum:cum + keeps[n]] = off + np.arange(keeps[n])
            seg_qidx[n, :L] = off + np.arange(L)
            inv_idx[off:off + L] = n * smax + np.arange(L)
            if pmax:
                ppos[n, :plen] = np.arange(plen)
                pk_rows.append((plen, [p[0] for p in payloads]))
                pv_rows.append((plen, [p[1] for p in payloads]))
            off += L
            cum += keeps[n]
        last_idx[N:] = last_idx[N - 1]
        # paid forward slots: the flat packed sequence S plus, on the hit
        # path, the per-row padded area the batched attention actually
        # computes over — Nb*pmax prefix slots AND the row slack
        # Nb*smax − S (a skewed pack's dominant waste term)
        self.padded_slots += S + Nb * pmax + (
            max(0, Nb * smax - S) if pmax else 0)
        self._last_shape = {"S": S, "Nb": Nb if pmax else 0, "smax": smax,
                            "pmax": pmax, "K": K}
        if pmax:
            logits, kv = self._run_packed_hit(
                S, Nb, smax, pmax, K, toks, pos, last_idx, kv_idx,
                seg_qidx, inv_idx, ppos, pk_rows, pv_rows)
        else:
            logits, kv = self._run_packed_miss(S, K, toks, segs, pos,
                                               last_idx, kv_idx)
        logits = logits[:N]
        now = time.perf_counter()
        cum = 0
        with self.lock:
            for n, r in enumerate(batch):
                plen, _, _ = prefs[n]
                if plen:
                    self.cache.unpin(r.chain, plen // bs)
                # keeps[n] == 0: nothing insertable (or already resident —
                # the probe's match walk refreshed its LRU standing)
                if kv is not None and keeps[n]:
                    payloads_all = (self.cache.match_payloads(
                        r.chain)[:plen // bs] if plen else [])
                    for b in range(keeps[n] // bs):
                        lo = cum + b * bs
                        payloads_all.append((kv["k"][:, :, lo:lo + bs],
                                             kv["v"][:, :, lo:lo + bs]))
                    self.cache.insert(r.chain, plen + keeps[n], now=now,
                                      payloads=payloads_all)
                cum += keeps[n]
        return logits

    def _run_packed_miss(self, S: int, K: int, toks, segs, pos, last_idx,
                         kv_idx):
        key = (S, K)
        self._last_jit = ("packed_miss", key, key not in self._packed_fns)
        if key not in self._packed_fns:
            self._step_compiled = True
            cfg = self.cfg

            @jax.jit
            def fn(params, toks, segs, pos, last_idx, kv_idx):
                return tfm.prefill_packed(
                    params, cfg, toks, segs, pos, last_idx,
                    kv_indices=kv_idx if K else None)

            self._packed_fns[key] = fn
        return self._packed_fns[key](
            self.params, jnp.asarray(toks), jnp.asarray(segs),
            jnp.asarray(pos), jnp.asarray(last_idx), jnp.asarray(kv_idx))

    def _run_packed_hit(self, S: int, Nb: int, smax: int, pmax: int, K: int,
                        toks, pos, last_idx, kv_idx, seg_qidx, inv_idx,
                        ppos, pk_rows, pv_rows):
        """Packed prefix-hit forward: assemble the pinned per-block prefix
        payloads into the batched (L, Nb, pmax, KV, hd) buffer (row n =
        segment n's prefix, zero-padded) and run
        ``prefill_packed_with_prefix``."""
        key = (S, Nb, smax, pmax, K)
        self._last_jit = ("packed_hit", key,
                          key not in self._packed_hit_fns)
        if key not in self._packed_hit_fns:
            self._step_compiled = True
            cfg = self.cfg

            @jax.jit
            def fn(params, toks, pos, last_idx, pk, pv, ppos, seg_qidx,
                   inv_idx, kv_idx):
                return tfm.prefill_packed_with_prefix(
                    params, cfg, toks, pos, last_idx, {"k": pk, "v": pv},
                    ppos, seg_qidx, inv_idx,
                    kv_indices=kv_idx if K else None)

            self._packed_hit_fns[key] = fn

        zero_row = jnp.zeros((self.cfg.num_layers, 1, pmax,
                              self.cfg.num_kv_heads, self.cfg.head_dim),
                             jnp.dtype(self.cfg.dtype))

        def assemble(rows):
            # rows: per segment (plen, per-block (L, 1, bs, KV, hd)
            # payloads); -> the batched (L, Nb, pmax, KV, hd) buffer
            out = []
            for plen, parts in rows:
                if not parts:
                    out.append(zero_row)
                    continue
                buf = jnp.concatenate(parts, axis=2)
                if plen < pmax:
                    buf = jnp.pad(buf, ((0, 0), (0, 0), (0, pmax - plen),
                                        (0, 0), (0, 0)))
                out.append(buf)
            out += [zero_row] * (Nb - len(rows))
            return jnp.concatenate(out, axis=1)

        pk = assemble(pk_rows)
        pv = assemble(pv_rows)
        return self._packed_hit_fns[key](
            self.params, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(last_idx), pk, pv, jnp.asarray(ppos),
            jnp.asarray(seg_qidx), jnp.asarray(inv_idx),
            jnp.asarray(kv_idx))

    def _run_suffix(self, tokens, pk, pv, prefix_len: int, keep: int):
        S = _bucket(len(tokens), self.ecfg.suffix_buckets)
        P = pk.shape[2]
        keep_new = self.kv.suffix_keep_new(keep, prefix_len, S)
        # jit-key bucketing of the fresh-KV budget (see _run_fresh)
        keep_pad = self.kv.keep_pad(keep_new, S)
        key = (S, P, keep_pad)
        self._last_jit = ("suffix", key, key not in self._suffix_fns)
        self._last_shape = {"S": S, "pmax": P}
        if key not in self._suffix_fns:
            self._step_compiled = True
            cfg = self.cfg

            @jax.jit
            def fn(params, toks, pk, pv, last_index):
                return tfm.prefill_with_prefix(
                    params, cfg, {"tokens": toks}, {"k": pk, "v": pv},
                    prefix_len=P, kv_keep=P + keep_pad, last_index=last_index)

            self._suffix_fns[key] = fn
        toks = np.zeros((1, S), np.int32)
        toks[0, :len(tokens)] = tokens
        logits, kv = self._suffix_fns[key](
            self.params, jnp.asarray(toks), pk, pv,
            jnp.asarray([len(tokens) - 1], jnp.int32))
        n_new = min(keep_new, len(tokens))
        return logits, kv, n_new

    # ---- output --------------------------------------------------------------
    def _score(self, logits: jax.Array, r: Request) -> Dict:
        """Constrained single-token output: renormalize over allowed ids
        (paper §2.3 — P(Yes)/P(No) without fine-tuning)."""
        out = {"req_id": r.req_id, "latency": r.latency,
               "n_cached": r.n_cached_at_start, "n_input": r.n_input,
               "deadline": r.deadline}
        logits = np.asarray(logits[0], np.float64)
        # non-finite guard: NaN logits reach scoring silently (softmax of
        # NaN is NaN, argmax of NaN is garbage) — flag the result corrupt
        # instead of delivering it; the serving layer quarantines and
        # retries on a peer. Constrained scoring needs every allowed logit
        # finite (renormalization); unconstrained argmax tolerates -inf
        # ("never this token") but not NaN or an all-non-finite row.
        if r.allowed_tokens:
            bad = not bool(np.isfinite(logits[list(r.allowed_tokens)]).all())
        else:
            bad = bool(np.isnan(logits).any()
                       or not np.isfinite(logits).any())
        if bad:
            self.nonfinite_results += 1
            self.result_guard.observe(float("nan"))
            out["corrupt"] = "nonfinite_logits"
            out["token"] = -1
            if r.allowed_tokens:
                out["scores"] = {}
            return out
        self.result_guard.observe(0.0)
        if r.allowed_tokens:
            sub = logits[list(r.allowed_tokens)]
            sub = np.exp(sub - sub.max())
            sub /= sub.sum()
            out["scores"] = {int(t): float(p)
                             for t, p in zip(r.allowed_tokens, sub)}
            out["token"] = int(r.allowed_tokens[int(np.argmax(sub))])
        else:
            out["token"] = int(np.argmax(logits))
        return out

    def stats(self) -> Dict:
        return {
            "steps": self.steps,
            "hit_rate": self.hit_tokens / max(1, self.total_tokens),
            "packed_steps": self.packed_steps,
            "packed_requests": self.packed_requests,
            "packed_hit_requests": self.packed_hit_requests,
            "pack_skew_splits": self.pack_skew_splits,
            "nonfinite_results": self.nonfinite_results,
            # fraction of paid forward slots that were padding/cache slack
            "padding_waste": 1.0 - (self.total_tokens
                                    / max(1, self.padded_slots)),
            "cache": self.cache.stats(),
            # JCT-calibration summary: coefficients, residual p50/p95,
            # refit counts — readable without scraping Prometheus
            "jct": self.jct_monitor.summary(),
        }
