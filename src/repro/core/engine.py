"""PrefillOnly engine — the real-compute serving loop (paper §3).

Workflow (Figure 2):
  profile run   -> JCT model fit + prefix-KV budget (kv_policy / measured)
  submit()      -> tokenize-equivalent: hash-chain the request, enqueue
  step()        -> Algorithm 1 pick (continuous JCT calibration) -> batch
                   formation (prepacking) -> hybrid prefill (cache-hit
                   suffix path when possible) -> suffix-KV discard into the
                   block cache -> constrained single-token output (the
                   paper's P(Yes)/P(No) scoring)

This engine runs REAL forwards (CPU-scale models in tests/examples; the same
code drives a TPU instance mesh via launch/serve.py). Shapes are bucketed so
jit compiles a bounded set of programs.

Prepacked prefill (arXiv:2404.09529 / BatchLLM arXiv:2412.03594)
----------------------------------------------------------------
Bucketing rounds every suffix up to the next shape in ``suffix_buckets``, so
a 65-token request pays the FLOPs of a 128-token forward — on the paper's
short discriminative workloads up to ~50% of prefill compute is padding.
Instead of widening the batch axis (which §6.1 rejects for latency), the
engine packs several requests end-to-end into ONE sequence and restricts
attention to same-segment pairs (segment ids drive both tile-level skipping
and element masking in the kernels; RoPE positions restart at each segment
boundary). Single-token output makes this safe: each packed request needs
only its own last-row logits.

Batch formation preserves Algorithm 1: the *anchor* request is still the
scheduler's pick. If the anchor has a usable cached prefix it runs solo via
the suffix path; otherwise first-fit-decreasing backfill fills the remaining
``pack_token_budget`` with further cache-miss requests, largest first —
short requests ride in the padding slack that bucketing would have burned
anyway. Each packed request's KV is sliced out of the packed forward and
inserted into the prefix cache under its own hash chain (suffix discard
still applies), and the JCT model observes (total packed tokens, wall time)
so SRJF-calibrated scoring stays calibrated for packed steps.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.jct import LinearProxyJCT, Sample
from repro.core.prefix_cache import PrefixCache, token_chain
from repro.core.scheduler import Request, Scheduler
from repro.models import transformer as tfm
from repro.models.model import cast_params


def _bucket(n: int, sizes: Sequence[int]) -> int:
    for s in sizes:
        if n <= s:
            return s
    # grow geometrically past the largest configured bucket — clamping to
    # sizes[-1] would truncate (and crash) requests longer than the table
    s = sizes[-1]
    while s < n:
        s *= 2
    return s


@dataclasses.dataclass
class EngineConfig:
    policy: str = "srjf_calibrated"
    lam: float = 0.05                 # starvation offset (JCT-sec per wait-sec)
    block_size: int = 16
    cache_capacity_tokens: int = 4096  # prefix-KV budget (profile run output)
    kv_keep_tokens: int = 10**9        # suffix discard threshold (per request)
    suffix_buckets: Tuple[int, ...] = (64, 128, 256, 512, 1024, 2048)
    prefix_bucket_blocks: int = 4      # reuse granularity: 4 blocks = 64 tok
    pack_token_budget: int = 2048      # prepacking: max packed tokens/step
    max_pack_requests: int = 16        # prepacking: max segments per step
                                       # (<=1 disables batch formation)
    autotune_pack: bool = True         # retune both from the profile() fit
    pack_inflation: float = 2.0        # max anchor-step slowdown autotune
                                       # accepts vs a typical solo step


class PrefillOnlyEngine:
    """Single-instance engine over a dense-family model (real arrays)."""

    def __init__(self, cfg: ModelConfig, params,
                 ecfg: Optional[EngineConfig] = None):
        assert cfg.family in ("dense", "vlm", "audio", "moe"), cfg.family
        self.cfg = cfg
        self.params = cast_params(params, cfg.dtype)
        # per-engine config: a shared default instance would alias mutable
        # state (autotune) across every engine in a pool
        self.ecfg = ecfg = EngineConfig() if ecfg is None else ecfg
        # Guards queue / cache / results / jct_model. The engine is driven by
        # ONE worker thread (step) while router/server threads concurrently
        # submit, cancel, shed, and probe backlog — the forward itself runs
        # outside the lock so probes never wait on compute.
        self.lock = threading.RLock()
        self.cache = PrefixCache(ecfg.cache_capacity_tokens // ecfg.block_size,
                                 ecfg.block_size)
        self.jct_model = LinearProxyJCT()
        self.scheduler = Scheduler(ecfg.policy, self.jct_model, ecfg.lam)
        self.queue: List[Request] = []
        self.results: Dict[int, Dict] = {}
        self._fresh_fns: Dict[Tuple[int, int], callable] = {}
        self._suffix_fns: Dict[Tuple[int, int, int], callable] = {}
        self._packed_fns: Dict[Tuple[int, int], callable] = {}
        self._last_step_ids: List[int] = []    # all requests served by the
                                               # most recent step()
        self._inflight: List[int] = []         # popped by step(), not yet in
                                               # results (crash accounting)
        self._inflight_pred = 0.0              # predicted cost of that batch
        self._inflight_t0 = 0.0                # and when it started
        self.steps = 0
        self.hit_tokens = 0
        self.total_tokens = 0
        self.packed_steps = 0          # steps that executed >1 request
        self.packed_requests = 0       # requests served via prepacking
        self.padded_slots = 0          # bucketed forward slots actually paid
        self._step_compiled = False    # step hit a fresh jit shape

    # ---- profile run (paper §3.1) ------------------------------------------
    def profile(self, lengths: Sequence[int] = (64, 128, 256, 512)) -> float:
        """Measure jct(n_input, 0) on this host, fit the linear proxy."""
        samples: List[Sample] = []
        rng = np.random.default_rng(0)
        for n in lengths:
            toks = rng.integers(0, self.cfg.vocab_size, size=n).tolist()
            self._run_fresh(toks)            # warm-up: exclude compile time
            for _ in range(2):               # steady-state samples
                t0 = time.perf_counter()
                logits, _, _ = self._run_fresh(toks)
                jax.block_until_ready(logits)
                samples.append((n, 0, time.perf_counter() - t0))
        self.jct_model.fit(samples)
        if self.ecfg.autotune_pack:
            self.autotune_packing(ref_len=max(lengths))
        return self.jct_model.pearson_r

    def autotune_packing(self, ref_len: int) -> Tuple[int, int]:
        """Tune ``pack_token_budget`` / ``max_pack_requests`` from the fitted
        JCT curve instead of fixed defaults (ROADMAP follow-up).

        Packing trades anchor latency for throughput: a packed step costs
        jct(total tokens) instead of jct(anchor tokens). Accept that trade up
        to ``pack_inflation``x the cost of a typical solo step (a ``ref_len``
        request — the largest profiled length): with jct = a*S + b the budget
        solves a*S + b <= inflation * (a*ref + b), so hosts with a large
        fixed overhead b relative to per-token cost a (where amortizing b is
        the whole win) get a proportionally larger budget. The request cap
        follows as budget / smallest-bucket, i.e. the most segments a full
        budget could plausibly hold.
        """
        m, ecfg = self.jct_model, self.ecfg
        if m.a <= 0:
            return ecfg.pack_token_budget, ecfg.max_pack_requests
        max_step = ecfg.pack_inflation * m.predict(ref_len)
        floor = _bucket(ref_len, ecfg.suffix_buckets)
        budget = max([floor] + [s for s in ecfg.suffix_buckets
                                if m.predict(s) <= max_step])
        n_max = int(np.clip(budget // max(1, ecfg.suffix_buckets[0]), 1, 64))
        self.ecfg = dataclasses.replace(ecfg, pack_token_budget=budget,
                                        max_pack_requests=n_max)
        return budget, n_max

    # ---- request lifecycle ---------------------------------------------------
    def submit(self, tokens: Sequence[int],
               allowed_tokens: Optional[Sequence[int]] = None,
               user_id: Optional[str] = None, now: Optional[float] = None,
               deadline: Optional[float] = None,
               chain: Optional[Tuple[int, ...]] = None) -> int:
        now = time.perf_counter() if now is None else now
        r = Request(n_input=len(tokens), arrival=now,
                    chain=(token_chain(tokens, self.ecfg.block_size)
                           if chain is None else chain),
                    tokens=list(tokens), user_id=user_id,
                    allowed_tokens=tuple(allowed_tokens) if allowed_tokens else None,
                    deadline=deadline)
        with self.lock:
            r.n_cached_at_arrival = self.cache.match_len(r.chain)
            self.queue.append(r)
        return r.req_id

    def cancel(self, req_id: int) -> Optional[Request]:
        """Remove a QUEUED request (no effect once executing). Returns the
        removed request, or None if it was not waiting here."""
        with self.lock:
            for i, r in enumerate(self.queue):
                if r.req_id == req_id:
                    return self.queue.pop(i)
        return None

    def shed_expired(self, now: Optional[float] = None) -> List[Request]:
        """Pop queued requests that cannot meet their deadline anymore:
        even starting RIGHT NOW, now + predicted JCT > deadline. Shedding
        them early converts a guaranteed tail-latency blowup into a cheap
        typed rejection (admission control's in-queue half)."""
        now = time.perf_counter() if now is None else now
        shed: List[Request] = []
        with self.lock:
            keep = []
            for r in self.queue:
                if r.deadline is not None and (
                        now + self.jct_model.predict(
                            r.n_input, self.cache.match_len(r.chain))
                        > r.deadline):
                    shed.append(r)
                else:
                    keep.append(r)
            if shed:
                self.queue[:] = keep
        return shed

    def pending_jct(self, now: Optional[float] = None) -> float:
        """Predicted seconds of queued work PLUS the predicted remainder of
        the batch executing right now — the backlog signal JCT-aware routing
        ranks instances by. Only meaningful because prefill-only JCT is
        precisely predictable.

        Queued requests are scored against their ARRIVAL-time cache match
        (already computed by submit), not re-walked against the live cache:
        the router calls this for every instance on every arrival, and an
        O(queue x chain) walk under the engine lock would contend with the
        worker exactly when routing matters most. The estimate only errs
        conservative (the cache can have warmed since arrival, never
        cooled for a queued request's own prefix)."""
        now = time.perf_counter() if now is None else now
        with self.lock:
            queued = sum(self.jct_model.predict(r.n_input,
                                                r.n_cached_at_arrival)
                         for r in self.queue)
            running = 0.0
            if self._inflight:
                running = max(0.0, self._inflight_pred
                              - (now - self._inflight_t0))
            return queued + running

    def predict_jct(self, n_input: int, chain: Tuple[int, ...] = ()) -> float:
        """Predicted JCT of a PROSPECTIVE request given this instance's
        cache state (router's per-instance cost probe)."""
        with self.lock:
            return self.jct_model.predict(n_input, self.cache.match_len(chain))

    def cached_prefix_len(self, chain: Tuple[int, ...]) -> int:
        with self.lock:
            return self.cache.match_len(chain)

    @property
    def last_step_ids(self) -> List[int]:
        return list(self._last_step_ids)

    def step(self) -> Optional[int]:
        """One scheduling step: pick (Algorithm 1), form a packed batch,
        prefill, cache, score. Returns the anchor request's id."""
        now = time.perf_counter()
        batch = self._form_batch(now)
        if batch is None:
            return None
        for r in batch:
            r.start_time = now
        with self.lock:
            self._inflight = [r.req_id for r in batch]
            self._inflight_pred = sum(
                self.jct_model.predict(r.n_input,
                                       self.cache.match_len(r.chain))
                for r in batch)
            self._inflight_t0 = now
        self._step_compiled = False
        if len(batch) == 1:
            r = batch[0]
            logits = self._execute(r)
            # async dispatch: sync before timestamping, or the JCT model
            # observes launch latency instead of compute time
            jax.block_until_ready(logits)
            r.finish_time = time.perf_counter()
            with self.lock:
                self.results[r.req_id] = self._score(logits, r)
                # steps that compiled a fresh shape are NOT JCT samples — a
                # multi-second jit compile recorded as serving cost wrecks the
                # refit (profile() excludes compiles the same way via warm-up)
                if not self._step_compiled:
                    self.jct_model.observe(r.n_input, r.n_cached_at_start,
                                           r.finish_time - now)
        else:
            logits = self._execute_packed(batch)
            jax.block_until_ready(logits)
            done = time.perf_counter()
            with self.lock:
                for n, r in enumerate(batch):
                    r.finish_time = done
                    self.results[r.req_id] = self._score(logits[n:n + 1], r)
                # packed cost is a function of TOTAL packed tokens: report it
                # on the same miss-token axis Algorithm 1 scores with
                if not self._step_compiled:
                    self.jct_model.observe(sum(r.n_input for r in batch), 0,
                                           done - now)
            self.packed_steps += 1
            self.packed_requests += len(batch)
        self.steps += 1
        self._last_step_ids = [r.req_id for r in batch]
        with self.lock:
            self._inflight = []
            self._inflight_pred = 0.0
        return batch[0].req_id

    # ---- batch formation (prepacking) ---------------------------------------
    def _usable_prefix(self, r: Request, touch: bool = False) -> int:
        """Bucketed prefix-reuse length for ``r`` against the current cache
        (granularity ``prefix_bucket_blocks``; >=1 fresh token guaranteed)."""
        bs = self.ecfg.block_size
        gran = self.ecfg.prefix_bucket_blocks
        matched = self.cache.match_blocks(r.chain, touch=touch)
        prefix_len = (matched // gran) * gran * bs
        if prefix_len >= r.n_input:
            # never consume the whole request from cache — the last token's
            # logits must be computed
            prefix_len = max(0, ((r.n_input - 1) // (gran * bs)) * gran * bs)
        return prefix_len

    def _form_batch(self, now: float) -> Optional[List[Request]]:
        """Algorithm 1 pick + first-fit-decreasing backfill.

        The anchor is exactly the scheduler's pick, so SRJF-calibrated order
        is preserved. A cache-hit anchor runs solo (the suffix path computes
        fewer tokens than any packed forward would). A cache-miss anchor's
        padding slack is backfilled with further cache-miss requests, largest
        first (FFD maximizes bucket fill), up to ``pack_token_budget`` /
        ``max_pack_requests``. Requests sharing a prefix root (same first
        hash-chain block) are never co-packed: running sharers sequentially
        lets the later ones hit the earlier one's cached KV, which beats the
        packing win (BatchLLM's global-prefix observation).
        """
        with self.lock:
            i = self.scheduler.pick(self.queue, self.cache, now)
            if i is None:
                return None
            anchor = self.queue.pop(i)
            batch = [anchor]
            ecfg = self.ecfg
            if (ecfg.max_pack_requests <= 1 or ecfg.pack_token_budget <= 0
                    or not self.queue or self._usable_prefix(anchor) > 0):
                return batch
            total = anchor.n_input
            roots = {anchor.chain[0]} if anchor.chain else set()
            cands = sorted(self.queue, key=lambda r: (-r.n_input, r.arrival,
                                                      r.req_id))
            for r in cands:
                if len(batch) >= ecfg.max_pack_requests:
                    break
                if total + r.n_input > ecfg.pack_token_budget:
                    continue
                root = r.chain[0] if r.chain else None
                if root is not None and root in roots:
                    continue
                # cache walk LAST and only for requests that actually fit —
                # pick() already probed the whole queue this step; don't
                # re-walk every chain a second time for the candidate list
                if self._usable_prefix(r) > 0:
                    continue
                batch.append(r)
                total += r.n_input
                if root is not None:
                    roots.add(root)
            for r in batch[1:]:
                self.queue.remove(r)
            return batch

    def run_until_drained(self) -> List[int]:
        """Serve until the queue is empty; returns one id per served request
        in completion order (a packed step contributes its whole batch,
        anchor first)."""
        done = []
        while self.queue:
            if self.step() is not None:
                done.extend(self._last_step_ids)
        return done

    # ---- execution -----------------------------------------------------------
    def _execute(self, r: Request) -> jax.Array:
        bs = self.ecfg.block_size
        # cache probe + pin under the lock; the forward itself runs outside
        # it so router/admission probes never block on compute
        with self.lock:
            prefix_len = self._usable_prefix(r, touch=True)
            use_blocks = prefix_len // bs
            r.n_cached_at_start = prefix_len
            self.hit_tokens += prefix_len
            self.total_tokens += r.n_input
            self.padded_slots += prefix_len + _bucket(
                r.n_input - prefix_len, self.ecfg.suffix_buckets)
            keep = min(r.n_input, self.ecfg.kv_keep_tokens)
            if prefix_len:
                self.cache.pin(r.chain, use_blocks)
                payloads = self.cache.match_payloads(r.chain)[:use_blocks]
                pk = jnp.concatenate([p[0] for p in payloads], axis=2)
                pv = jnp.concatenate([p[1] for p in payloads], axis=2)
        if prefix_len == 0:
            logits, new_kv, n_new = self._run_fresh(r.tokens, keep)
            kv_from = 0
        else:
            logits, new_kv, n_new = self._run_suffix(
                r.tokens[prefix_len:], pk, pv, prefix_len, keep)
            kv_from = prefix_len
        # split fresh KV into block payloads and insert (suffix discard:
        # only up to ``keep`` tokens total)
        with self.lock:
            if prefix_len:
                self.cache.unpin(r.chain, use_blocks)
            n_insertable = max(0, min(keep, kv_from + n_new) - kv_from)
            n_blocks_new = n_insertable // bs
            payloads_all = self.cache.match_payloads(r.chain)[:use_blocks]
            for b in range(n_blocks_new):
                k_b = new_kv["k"][:, :, b * bs:(b + 1) * bs]
                v_b = new_kv["v"][:, :, b * bs:(b + 1) * bs]
                payloads_all.append((k_b, v_b))
            self.cache.insert(r.chain, kv_from + n_blocks_new * bs,
                              now=time.perf_counter(), payloads=payloads_all)
        return logits

    def _run_fresh(self, tokens: Sequence[int], keep: int = 0):
        S = _bucket(len(tokens), self.ecfg.suffix_buckets)
        # bucket the keep budget too: kv_keep only bounds how much KV leaves
        # each layer (keeping more is safe, callers slice), and a raw
        # per-request value would put every distinct length in its own jit key
        keep_pad = min(_bucket(keep, self.ecfg.suffix_buckets) if keep else 0,
                       S)
        key = (S, keep_pad)
        if key not in self._fresh_fns:
            self._step_compiled = True
            cfg = self.cfg

            @jax.jit
            def fn(params, toks, last_index):
                return tfm.prefill(params, cfg, {"tokens": toks},
                                   kv_keep=keep_pad, last_index=last_index)

            self._fresh_fns[key] = fn
        toks = np.zeros((1, S), np.int32)
        toks[0, :len(tokens)] = tokens
        logits, kv = self._fresh_fns[key](
            self.params, jnp.asarray(toks),
            jnp.asarray([len(tokens) - 1], jnp.int32))
        if kv is None:
            return logits, {"k": None, "v": None}, 0
        # kv: (L, 1, keep_pad, KV, hd); valid fresh tokens = len(tokens),
        # usable budget = the caller's keep (keep_pad only pads the jit key)
        n_new = min(keep, keep_pad, len(tokens))
        return logits, kv, n_new

    def _execute_packed(self, batch: List[Request]) -> jax.Array:
        """Run N cache-miss requests as one prepacked forward.

        Returns (N, V) logits — one row per request. Suffix discard is
        per-segment, which a packed-sequence prefix budget cannot express,
        so the forward gathers exactly each request's keep window via
        ``kv_indices``: the stacked KV costs K kept tokens (same bound as
        the solo path), not S, and each window is inserted under its own
        chain.
        """
        bs = self.ecfg.block_size
        total = sum(r.n_input for r in batch)
        S = _bucket(total, self.ecfg.suffix_buckets)
        N = len(batch)
        # block-aligned keep per request (only whole blocks are insertable)
        keeps = [(min(r.n_input, self.ecfg.kv_keep_tokens) // bs) * bs
                 for r in batch]
        # pad the gather length to a bucket so jit keys stay bounded
        K = _bucket(sum(keeps), self.ecfg.suffix_buckets) if sum(keeps) else 0
        key = (S, K)
        if key not in self._packed_fns:
            self._step_compiled = True
            cfg = self.cfg

            @jax.jit
            def fn(params, toks, segs, pos, last_idx, kv_idx):
                return tfm.prefill_packed(
                    params, cfg, toks, segs, pos, last_idx,
                    kv_indices=kv_idx if K else None)

            self._packed_fns[key] = fn
        toks = np.zeros((1, S), np.int32)
        segs = np.full((1, S), -1, np.int32)   # -1 = padding slack
        pos = np.zeros((1, S), np.int32)
        # last_idx is padded to max_pack_requests so the jit cache keys only
        # on the bucket shape, not on the batch size (duplicate rows of the
        # last real segment's logits are computed and dropped — N x V is
        # noise next to the forward)
        last_idx = np.zeros((max(N, self.ecfg.max_pack_requests),), np.int32)
        kv_idx = np.zeros((K,), np.int32)
        off = cum = 0
        for n, r in enumerate(batch):
            L = r.n_input
            toks[0, off:off + L] = r.tokens
            segs[0, off:off + L] = n
            pos[0, off:off + L] = np.arange(L)   # RoPE restarts per segment
            last_idx[n] = off + L - 1
            kv_idx[cum:cum + keeps[n]] = off + np.arange(keeps[n])
            r.n_cached_at_start = 0
            off += L
            cum += keeps[n]
        last_idx[N:] = last_idx[N - 1]
        self.total_tokens += total
        self.padded_slots += S
        logits, kv = self._packed_fns[key](
            self.params, jnp.asarray(toks), jnp.asarray(segs),
            jnp.asarray(pos), jnp.asarray(last_idx), jnp.asarray(kv_idx))
        logits = logits[:N]
        if kv is not None:
            now = time.perf_counter()
            cum = 0
            with self.lock:
                for n, r in enumerate(batch):
                    payloads = []
                    for b in range(keeps[n] // bs):
                        lo = cum + b * bs
                        payloads.append((kv["k"][:, :, lo:lo + bs],
                                         kv["v"][:, :, lo:lo + bs]))
                    self.cache.insert(r.chain, keeps[n], now=now,
                                      payloads=payloads)
                    cum += keeps[n]
        return logits

    def _run_suffix(self, tokens, pk, pv, prefix_len: int, keep: int):
        S = _bucket(len(tokens), self.ecfg.suffix_buckets)
        P = pk.shape[2]
        keep_new = max(0, min(keep, prefix_len + S) - prefix_len)
        # bucket the fresh-KV budget in the jit key (see _run_fresh)
        keep_pad = min(_bucket(keep_new, self.ecfg.suffix_buckets)
                       if keep_new else 0, S)
        key = (S, P, keep_pad)
        if key not in self._suffix_fns:
            self._step_compiled = True
            cfg = self.cfg

            @jax.jit
            def fn(params, toks, pk, pv, last_index):
                return tfm.prefill_with_prefix(
                    params, cfg, {"tokens": toks}, {"k": pk, "v": pv},
                    prefix_len=P, kv_keep=P + keep_pad, last_index=last_index)

            self._suffix_fns[key] = fn
        toks = np.zeros((1, S), np.int32)
        toks[0, :len(tokens)] = tokens
        logits, kv = self._suffix_fns[key](
            self.params, jnp.asarray(toks), pk, pv,
            jnp.asarray([len(tokens) - 1], jnp.int32))
        n_new = min(keep_new, len(tokens))
        return logits, kv, n_new

    # ---- output --------------------------------------------------------------
    def _score(self, logits: jax.Array, r: Request) -> Dict:
        """Constrained single-token output: renormalize over allowed ids
        (paper §2.3 — P(Yes)/P(No) without fine-tuning)."""
        out = {"req_id": r.req_id, "latency": r.latency,
               "n_cached": r.n_cached_at_start, "n_input": r.n_input}
        logits = np.asarray(logits[0], np.float64)
        if r.allowed_tokens:
            sub = logits[list(r.allowed_tokens)]
            sub = np.exp(sub - sub.max())
            sub /= sub.sum()
            out["scores"] = {int(t): float(p)
                             for t, p in zip(r.allowed_tokens, sub)}
            out["token"] = int(r.allowed_tokens[int(np.argmax(sub))])
        else:
            out["token"] = int(np.argmax(logits))
        return out

    def stats(self) -> Dict:
        return {
            "steps": self.steps,
            "hit_rate": self.hit_tokens / max(1, self.total_tokens),
            "packed_steps": self.packed_steps,
            "packed_requests": self.packed_requests,
            # fraction of paid forward slots that were padding/cache slack
            "padding_waste": 1.0 - (self.total_tokens
                                    / max(1, self.padded_slots)),
            "cache": self.cache.stats(),
        }
