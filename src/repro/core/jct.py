"""JCT (job completion time) models — paper §6.3.

Prefill-only requests have deterministic JCT given (n_input, n_cached). The
paper profiles jct(n_input, n_cached) on a 1000-token grid and fits a linear
model, then observes the cache-miss-token count is a near-perfect proxy
(Pearson r = 0.987 on A100/Qwen-32B). We provide:

  * LinearProxyJCT  — the paper's default:  a * (n_input - n_cached) + b
  * GridJCT         — full bilinear(+quadratic attention) regression
  * RooflineJCT     — analytic TPU model (simulator default; no hardware
                      needed, calibratable against measured samples)
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.runtime.hw import ChipSpec, DEFAULT_CHIP

Sample = Tuple[int, int, float]  # (n_input, n_cached, seconds)


def pearson(xs: Sequence[float], ys: Sequence[float]) -> float:
    x = np.asarray(xs, np.float64)
    y = np.asarray(ys, np.float64)
    if len(x) < 2 or x.std() == 0 or y.std() == 0:
        # Degenerate input carries no correlation evidence; report 0 so a
        # zero-variance fit can't masquerade as a perfect one on the
        # jct_pearson_r gauge.
        return 0.0
    return float(np.corrcoef(x, y)[0, 1])


class LinearProxyJCT:
    """jct ≈ a * miss_tokens + b (the paper's default proxy).

    ``observe`` keeps the proxy calibrated online: the engine reports every
    executed step as (tokens, cached, wall-seconds) — a PREPACKED batch
    reports its *total packed tokens*, so the model learns packed-batch cost
    on the same miss-token axis and Algorithm 1's scores stay comparable
    between solo and packed execution. Refits over a sliding window every
    ``refit_every`` observations (cheap: 2-param lstsq).
    """

    def __init__(self, a: float = 1e-4, b: float = 0.01, window: int = 256,
                 refit_every: int = 16):
        self.a, self.b = a, b
        self.pearson_r: float = 1.0
        self.window = window
        self.refit_every = refit_every
        self.fits = 0
        self.clamped_fits = 0
        self._recent: List[Sample] = []
        self._since_fit = 0

    def observe(self, n_input: int, n_cached: int, seconds: float) -> None:
        """Record one executed step; refit periodically."""
        self._recent.append((n_input, n_cached, seconds))
        if len(self._recent) > self.window:
            del self._recent[: len(self._recent) - self.window]
        self._since_fit += 1
        if self._since_fit >= self.refit_every and len(self._recent) >= 4:
            self.fit(self._recent)
            self._since_fit = 0

    def fit(self, samples: Sequence[Sample]) -> "LinearProxyJCT":
        miss = np.array([s[0] - s[1] for s in samples], np.float64)
        t = np.array([s[2] for s in samples], np.float64)
        A = np.stack([miss, np.ones_like(miss)], axis=1)
        coef, *_ = np.linalg.lstsq(A, t, rcond=None)
        if coef[0] < 1e-12 or coef[1] < 0.0:
            # The projection left the physically-meaningful region (negative
            # slope/intercept) — we still clamp, but count it so calibration
            # drift from a mis-specified model is observable.
            self.clamped_fits += 1
        self.a, self.b = float(max(coef[0], 1e-12)), float(max(coef[1], 0.0))
        self.pearson_r = pearson(miss, t)
        self.fits += 1
        return self

    def predict(self, n_input: int, n_cached: int = 0) -> float:
        return self.a * max(n_input - n_cached, 0) + self.b


ShapeSample = Tuple[Tuple[float, ...], float]  # (features, seconds)

SHAPE_FEATURES = ("const", "computed", "seq", "row_tokens", "prefix_slots",
                  "attn_area")


def step_features(computed: int, S: int, Nb: int, smax: int,
                  pmax: int) -> Tuple[float, ...]:
    """Feature vector for one executed step's realized shape.

    Canonicalizes the three step kinds onto one basis so formation-time
    pricing and ``BatchRecord`` observations agree:

      * fresh/solo-miss:   (S,)            → rows=0, no padded dims
      * solo-suffix (hit): (S, pmax)       → one row of (S, pmax)
      * packed:            (S, Nb, smax, pmax)

    ``row_tokens`` = rows*smax (row padding the batched hit attention pays),
    ``prefix_slots`` = rows*pmax (padded prefix keys every row attends over),
    ``attn_area`` = rows*smax*(smax+pmax) — the dense masked einsum area.
    """
    rows = Nb if Nb else (1 if pmax else 0)
    sm = smax if smax else (S if pmax else 0)
    return (1.0, float(computed), float(S), float(rows * sm),
            float(rows * pmax), float(rows * sm * (sm + pmax)) * 1e-6)


class PackedShapeJCT:
    """Prices a step from its realized padded shape (ISSUE 10 tentpole).

    The token-linear proxy can't see that the batched hit attention pads
    every row to (smax, pmax): one long row re-prices the whole pack. This
    model regresses wall time on shape features — computed tokens, row
    padding, prefix slots, quadratic attention area — fitted online from the
    per-step (shape, wall) pairs the engine already emits as BatchRecords.

    Coefficients are constrained non-negative (scipy NNLS, clipped-lstsq
    fallback) so marginal pack costs are monotone in every padded dimension;
    before ``min_samples`` warm observations it falls back to a prior that
    charges the linear proxy's per-token rate on computed tokens plus
    ``pad_discount`` of that rate on padded slots.
    """

    def __init__(self, fallback: LinearProxyJCT | None = None,
                 pad_discount: float = 0.25, window: int = 512,
                 refit_every: int = 16, min_samples: int = 16):
        self.fallback = fallback or LinearProxyJCT()
        self.pad_discount = pad_discount
        self.window = window
        self.refit_every = refit_every
        self.min_samples = min_samples
        self.coef = np.zeros(len(SHAPE_FEATURES))
        self.fits = 0
        self.pearson_r: float = 0.0
        self._recent: List[ShapeSample] = []
        self._since_fit = 0

    @property
    def fitted(self) -> bool:
        return self.fits > 0

    def observe(self, computed: int, S: int, Nb: int, smax: int, pmax: int,
                seconds: float) -> None:
        """Record one executed step's (shape, wall); refit periodically."""
        self._recent.append((step_features(computed, S, Nb, smax, pmax),
                             seconds))
        if len(self._recent) > self.window:
            del self._recent[: len(self._recent) - self.window]
        self._since_fit += 1
        if (self._since_fit >= self.refit_every
                and len(self._recent) >= self.min_samples):
            self.refit_recent()
            self._since_fit = 0

    def refit_recent(self) -> None:
        if len(self._recent) >= self.min_samples:
            self.fit(self._recent)

    def fit(self, samples: Sequence[ShapeSample]) -> "PackedShapeJCT":
        X = np.array([s[0] for s in samples], np.float64)
        t = np.array([s[1] for s in samples], np.float64)
        try:
            from scipy.optimize import nnls
            coef, _ = nnls(X, t)
        except Exception:  # pragma: no cover - scipy always present in image
            coef, *_ = np.linalg.lstsq(X, t, rcond=None)
            coef = np.clip(coef, 0.0, None)
        self.coef = np.asarray(coef, np.float64)
        self.pearson_r = pearson(X @ self.coef, t)
        self.fits += 1
        return self

    def predict(self, computed: int, S: int, Nb: int, smax: int,
                pmax: int, pad_slots: float | None = None) -> float:
        """Predicted wall seconds for a step of this realized shape.

        ``pad_slots`` (when the caller knows the exact row layout, e.g. batch
        formation) is the number of padded-but-dead slots the step pays:
        Σ(pmax-pref_i) + Σ(smax-suf_i) + (Nb-N)·(smax+pmax). Without it the
        prior falls back to the feature-derived upper bound.
        """
        feats = step_features(computed, S, Nb, smax, pmax)
        if self.fitted:
            return float(np.dot(self.coef, feats))
        # Prior: linear proxy on computed tokens + discounted padding rent.
        _, comp, _, row_tokens, prefix_slots, _ = feats
        if pad_slots is None:
            pad_slots = max(row_tokens - comp, 0.0) + prefix_slots
        return (self.fallback.a * (comp + self.pad_discount * pad_slots)
                + self.fallback.b)

    def coefficients(self) -> dict:
        return {name: float(c) for name, c in zip(SHAPE_FEATURES, self.coef)}


class GridJCT:
    """Bilinear + quadratic-attention regression over the profiling grid."""

    def __init__(self):
        self.coef = np.zeros(4)

    @staticmethod
    def _features(n_input, n_cached):
        n_input = np.asarray(n_input, np.float64)
        n_cached = np.asarray(n_cached, np.float64)
        return np.stack([
            np.ones_like(n_input),
            n_input - n_cached,
            n_cached,
            (n_input ** 2 - n_cached ** 2) * 1e-6,
        ], axis=-1)

    def fit(self, samples: Sequence[Sample]) -> "GridJCT":
        X = self._features([s[0] for s in samples], [s[1] for s in samples])
        t = np.array([s[2] for s in samples], np.float64)
        self.coef, *_ = np.linalg.lstsq(X, t, rcond=None)
        return self

    def predict(self, n_input: int, n_cached: int = 0) -> float:
        return float(self._features(n_input, n_cached) @ self.coef)


@dataclasses.dataclass
class RooflineJCT:
    """Analytic per-request prefill time on an instance of ``chips`` chips.

    compute = linear-layer FLOPs of the miss tokens + causal-attention FLOPs
    (quadratic over total context, discounted by the cached prefix), memory =
    one weight sweep (batch==1 per PrefillOnly's one-at-a-time execution).
    ``efficiency`` is the achievable MFU (calibratable); ``comm_overhead``
    models TP all-reduce cost per token (0 for single-instance PrefillOnly).
    """

    cfg: ModelConfig
    chips: int = 1
    chip: ChipSpec = DEFAULT_CHIP
    efficiency: float = 0.55
    comm_bytes_per_token: float = 0.0   # TP: 2*(k-1)/k * d_model * 2L * bytes
    attn_efficiency: float = 1.0        # chunked-prefill kernel penalty < 1
    fixed_overhead: float = 0.003       # scheduling + launch
    weight_bytes_per_param: float = 2.0  # 1.0 = fp8

    def flops(self, n_input: int, n_cached: int = 0) -> float:
        cfg = self.cfg
        miss = max(n_input - n_cached, 0)
        linear = 2.0 * cfg.active_param_count() * miss
        attn = 0.0
        if cfg.has_attention:
            n_attn = cfg.num_layers
            if cfg.family == "hybrid":
                n_attn = max(1, cfg.num_layers // max(cfg.attn_every, 1))
            w = cfg.sliding_window
            hd, H = cfg.head_dim, cfg.num_heads
            # causal: sum over miss tokens of context length
            ctx_total = _causal_context_sum(n_input, n_cached, w,
                                            local_global=cfg.local_global)
            attn = 4.0 * n_attn * H * hd * ctx_total
        return linear + attn

    def predict(self, n_input: int, n_cached: int = 0) -> float:
        f = self.flops(n_input, n_cached)
        compute = f / (self.chips * self.chip.peak_flops_bf16
                       * self.efficiency * self.attn_efficiency)
        weight_bytes = self.weight_bytes_per_param * self.cfg.active_param_count()
        memory = weight_bytes / (self.chips * self.chip.hbm_bw)
        comm = 0.0
        if self.comm_bytes_per_token:
            miss = max(n_input - n_cached, 0)
            comm = self.comm_bytes_per_token * miss / self.chip.ici_bw
        return max(compute, memory) + comm + self.fixed_overhead

    def samples(self, max_len: int, granularity: int = 1000) -> List[Sample]:
        """The paper's profile run: jct over the (n_input, n_cached) grid."""
        out = []
        for n in range(granularity, max_len + 1, granularity):
            for c in range(0, n, granularity):
                out.append((n, c, self.predict(n, c)))
        return out


def _causal_context_sum(n_input: int, n_cached: int, window: int,
                        local_global: bool = False) -> float:
    """Sum of attended-context lengths for tokens n_cached..n_input-1."""
    def full(a: int, b: int) -> float:       # sum_{i=a}^{b-1} (i+1)
        return (b * (b + 1) - a * (a + 1)) / 2.0

    def windowed(a: int, b: int, w: int) -> float:
        total = 0.0
        if a < w:
            total += full(a, min(b, w))
        if b > w:
            total += (b - max(a, w)) * w
        return total

    if window and local_global:
        return 0.5 * (full(n_cached, n_input)
                      + windowed(n_cached, n_input, window))
    if window:
        return windowed(n_cached, n_input, window)
    return full(n_cached, n_input)


def tp_comm_bytes_per_token(cfg: ModelConfig, tp: int, bytes_per_el: int = 2) -> float:
    """All-reduce bytes/token for TP-k: 2 all-reduces per layer over d_model,
    ring cost 2*(k-1)/k of payload."""
    if tp <= 1:
        return 0.0
    payload = 2 * cfg.num_layers * cfg.d_model * bytes_per_el
    return 2.0 * (tp - 1) / tp * payload
