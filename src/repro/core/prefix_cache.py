"""Block-based radix prefix cache (vLLM-style hash chains) with LRU-leaf
eviction, reference pinning, and opaque per-block payloads.

Keys are precomputed *hash chains* (``token_chain``) rather than raw tokens:
continuous JCT calibration calls ``match_len`` for every waiting request on
every scheduling step, so the per-call cost must be O(matched blocks) with an
O(1) early exit on the first miss.

Used in three places:
  * the real CPU engine (payload = per-block KV arrays / SSM state checkpoints)
  * the discrete-event simulator (payload = None; pure accounting)
  * continuous JCT calibration (``match_len`` is the ``n_cached`` oracle)

Invariants (property-tested):
  * a block is resident only if its parent is resident (chains are prefixes)
  * eviction removes LRU *leaf* blocks only, never pinned ones
  * ``used_blocks <= capacity_blocks`` after any operation
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

ROOT = 0  # hash of the empty prefix

Chain = Tuple[int, ...]


def token_chain(tokens: Sequence[int], block_size: int) -> Chain:
    """Hash chain over full blocks of ``tokens`` (vLLM prefix hashing)."""
    out = []
    h = ROOT
    for i in range(0, len(tokens) - len(tokens) % block_size, block_size):
        h = hash((h, tuple(tokens[i:i + block_size])))
        out.append(h)
    return tuple(out)


@dataclasses.dataclass
class Block:
    hash: int
    parent: int
    payload: Any = None        # KV slab / SSM state / None (sim)
    ref_count: int = 0         # pinned by running requests
    children: int = 0          # resident child blocks
    last_used: float = 0.0


class PrefixCache:
    def __init__(self, capacity_blocks: int, block_size: int = 16):
        assert capacity_blocks >= 0 and block_size > 0
        self.capacity_blocks = capacity_blocks
        self.block_size = block_size
        self.blocks: Dict[int, Block] = {}
        self._leaf_lru: "OrderedDict[int, None]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- internals ----------------------------------------------------------

    def _touch(self, h: int, now: float):
        b = self.blocks[h]
        b.last_used = now
        if h in self._leaf_lru:
            self._leaf_lru.move_to_end(h)

    def _set_leaf(self, h: int, is_leaf: bool):
        if is_leaf:
            self._leaf_lru[h] = None
        else:
            self._leaf_lru.pop(h, None)

    def _evict_one(self, exclude: Optional[set] = None) -> bool:
        for h in self._leaf_lru:            # LRU order
            if self.blocks[h].ref_count == 0 and (
                    exclude is None or h not in exclude):
                self._remove(h)
                self.evictions += 1
                return True
        return False

    def _remove(self, h: int):
        b = self.blocks.pop(h)
        assert b.children == 0 and b.ref_count == 0
        self._set_leaf(h, False)
        if b.parent != ROOT and b.parent in self.blocks:
            parent = self.blocks[b.parent]
            parent.children -= 1
            if parent.children == 0 and parent.ref_count >= 0:
                self._set_leaf(b.parent, True)

    # -- public API ----------------------------------------------------------

    @property
    def used_blocks(self) -> int:
        return len(self.blocks)

    def match_blocks(self, chain: Chain, now: float = 0.0,
                     touch: bool = False) -> int:
        """Longest resident prefix, in blocks. O(1) exit on first miss."""
        n = 0
        for h in chain:
            if h not in self.blocks:
                break
            if touch:
                self._touch(h, now)
            n += 1
        return n

    def match_len(self, chain: Chain, now: float = 0.0,
                  touch: bool = False) -> int:
        """Longest resident prefix, in tokens."""
        return self.match_blocks(chain, now, touch) * self.block_size

    def probe_blocks(self, chain: Chain) -> int:
        """SERVEABLE prefix in blocks, side-effect free — what scheduling /
        routing / admission probes should price against. On the base cache
        this is just the resident run; the tiered cache extends it with the
        host-restorable continuation WITHOUT performing the restore (the
        restore happens on the execution path or via async prefetch)."""
        n = 0
        for h in chain:
            if h not in self.blocks:
                break
            n += 1
        return n

    def probe_len(self, chain: Chain) -> int:
        """``probe_blocks`` in tokens."""
        return self.probe_blocks(chain) * self.block_size

    def match_payloads(self, chain: Chain, now: float = 0.0) -> List[Any]:
        out = []
        for h in chain:
            if h not in self.blocks:
                break
            self._touch(h, now)
            out.append(self.blocks[h].payload)
        return out

    def pin(self, chain: Chain, n_blocks: int):
        for h in chain[:n_blocks]:
            if h not in self.blocks:
                break
            self.blocks[h].ref_count += 1

    def unpin(self, chain: Chain, n_blocks: int):
        for h in chain[:n_blocks]:
            if h not in self.blocks:
                break
            self.blocks[h].ref_count = max(0, self.blocks[h].ref_count - 1)

    def insert(self, chain: Chain, n_keep_tokens: int, now: float = 0.0,
               payloads: Optional[List[Any]] = None) -> int:
        """Insert blocks covering the first ``n_keep_tokens`` tokens
        (PrefillOnly suffix-KV discard: caller passes the prefix budget).
        Evicts LRU leaves as needed; stops early if the cache cannot grow
        (everything pinned). Returns resident blocks of this chain."""
        n_blocks = min(len(chain), n_keep_tokens // self.block_size)
        resident = 0
        parent = ROOT
        own = set()                          # never evict this chain's blocks
        for i in range(n_blocks):
            h = chain[i]
            if parent != ROOT and parent not in self.blocks:
                break                        # chain broken upstream: stop
            if h in self.blocks:
                self._touch(h, now)
            else:
                evicted_ok = True
                while self.used_blocks >= self.capacity_blocks:
                    if not self._evict_one(exclude=own):
                        evicted_ok = False
                        break
                if not evicted_ok:
                    return resident
                self.blocks[h] = Block(
                    hash=h, parent=parent, last_used=now,
                    payload=payloads[i] if payloads else None)
                self._set_leaf(h, True)
                if parent != ROOT and parent in self.blocks:
                    p = self.blocks[parent]
                    p.children += 1
                    self._set_leaf(parent, False)
            own.add(h)
            parent = h
            resident += 1
        return resident

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "used_blocks": self.used_blocks,
            "capacity_blocks": self.capacity_blocks,
            "evictions": self.evictions,
            "hit_rate": self.hits / total if total else 0.0,
        }
