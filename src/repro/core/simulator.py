"""Discrete-event simulator: the SAME scheduler + prefix-cache code as the
real engine, driven by an analytic JCT cost model instead of real forwards.

This is how the paper's QPS-latency curves (Fig 6/7/9/11) are reproduced on
a CPU-only box at TPU scale: engine variants differ only in their cost model
parameters (attention-efficiency penalty, TP comm term, PP bubble factor),
their MIL (infeasible requests are rejected — Table 2's ✗), their prefix
cache capacity, and their scheduling policy.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import numpy as np

from repro.core.jct import RooflineJCT, tp_comm_bytes_per_token
from repro.core.kv_policy import MemoryModel
from repro.core.prefix_cache import PrefixCache
from repro.core.scheduler import Request, Scheduler
from repro.configs.base import ModelConfig
from repro.runtime.hw import ChipSpec, DEFAULT_CHIP


@dataclasses.dataclass
class EngineSpec:
    """One serving configuration (PrefillOnly or a baseline)."""
    name: str
    policy: str                     # fifo | srjf | srjf_calibrated
    lam: float = 0.0
    chips_per_instance: int = 1
    attn_efficiency: float = 1.0    # chunked prefill kernel penalty
    tp: int = 1                     # adds all-reduce comm to JCT
    pp: int = 1                     # adds bubble factor to JCT
    technique: str = "hybrid"       # memory-model row for MIL + cache budget
    prefix_caching: bool = True
    kv_budget_override: Optional[int] = None  # tokens of prefix cache / inst.


def paper_engines(block: int = 16) -> List[EngineSpec]:
    """The paper's §7 lineup."""
    return [
        EngineSpec("prefillonly", "srjf_calibrated", lam=0.05,
                   technique="hybrid"),
        EngineSpec("paged_fcfs", "fifo", technique="paged"),
        EngineSpec("chunked_prefill", "fifo", technique="chunked",
                   attn_efficiency=0.86),   # paper §2.5: −14% e2e throughput
        EngineSpec("tensor_parallel", "fifo", technique="tp",
                   chips_per_instance=2, tp=2),
        EngineSpec("pipeline_parallel", "fifo", technique="pp",
                   chips_per_instance=2, pp=2),
    ]


@dataclasses.dataclass
class SimResult:
    name: str
    qps: float
    completed: int
    rejected: int
    mean_latency: float
    p50_latency: float
    p99_latency: float
    throughput: float               # completed requests / makespan
    hit_rate: float
    mil: int

    def row(self) -> Dict:
        return dataclasses.asdict(self)


class _Instance:
    def __init__(self, idx: int, spec: EngineSpec, jct_model,
                 scheduler: Scheduler, cache_blocks: int, block_size: int):
        self.idx = idx
        self.spec = spec
        self.jct = jct_model
        self.scheduler = scheduler
        self.cache = PrefixCache(cache_blocks if spec.prefix_caching else 0,
                                 block_size)
        self.queue: List[Request] = []
        # PP pipelines `pp` requests concurrently (one per stage)
        self.slots = max(1, spec.pp)
        self.in_flight = 0
        self.hit_tokens = 0
        self.total_tokens = 0

    def start_next(self, now: float) -> Optional[Request]:
        if self.in_flight >= self.slots:
            return None
        i = self.scheduler.pick(self.queue, self.cache, now)
        if i is None:
            return None
        self.in_flight += 1
        r = self.queue.pop(i)
        n_cached = self.cache.match_len(r.chain, now, touch=True)
        n_cached = min(n_cached, r.n_input)
        jct = self.jct.predict(r.n_input, n_cached)
        if self.spec.pp > 1:
            # bubble: stage imbalance across variable-length requests
            jct *= 1.0 + 0.5 * (self.spec.pp - 1) / self.spec.pp
        r.start_time = now
        r.n_cached_at_start = n_cached
        r.finish_time = now + jct
        self.hit_tokens += n_cached
        self.total_tokens += r.n_input
        # pin matched blocks for the duration, insert the new prefix KV
        self.cache.pin(r.chain, n_cached // self.cache.block_size)
        return r

    def finish(self, r: Request, now: float):
        self.in_flight -= 1
        self.cache.unpin(r.chain, r.n_cached_at_start // self.cache.block_size)
        # PrefillOnly: insert prefix KV up to budget (suffix discarded);
        # baselines keep all KV anyway — cache capacity enforces the budget.
        self.cache.insert(r.chain, r.n_input, now)


class Simulator:
    def __init__(self, cfg: ModelConfig, spec: EngineSpec, *,
                 total_chips: int = 2, chip: ChipSpec = DEFAULT_CHIP,
                 block_size: int = 16, efficiency: float = 0.55,
                 hybrid_chunk: int = 2048, weight_bytes_per_param: float = 2.0,
                 user_mil: int = 32_768):
        """``user_mil`` is the paper's §3.1 profile-run input: the maximum
        request length the deployment must handle. Every engine reserves its
        peak working set at min(user_mil, own MIL); leftover HBM becomes the
        prefix cache."""
        self.cfg = cfg
        self.spec = spec
        self.chip = chip
        self.block_size = block_size
        k = max(spec.tp, spec.pp)
        mem = MemoryModel(cfg, chip,
                          weight_bytes_per_param=weight_bytes_per_param)
        self.mil = mem.max_input_length(spec.technique, chunk=hybrid_chunk, k=k)
        if spec.kv_budget_override is not None:
            kv_tokens = spec.kv_budget_override
        else:
            reserve_at = min(user_mil, self.mil)
            free_per_chip = (mem.budget_bytes()
                             - mem.peak_bytes(reserve_at, spec.technique,
                                              chunk=hybrid_chunk, k=k))
            kv_tokens = max(0, int(free_per_chip / max(mem.kv_all_per_token, 1)))
            # parallelism shards the prefix cache across k chips (paper Fig 9:
            # "parallelize the prefix caches across GPUs")
            kv_tokens *= k
        self.cache_blocks = kv_tokens // block_size
        self.n_instances = max(1, total_chips // spec.chips_per_instance)
        jct_model = RooflineJCT(
            cfg, chips=spec.tp, chip=chip, efficiency=efficiency,
            attn_efficiency=spec.attn_efficiency,
            comm_bytes_per_token=tp_comm_bytes_per_token(cfg, spec.tp),
            weight_bytes_per_param=weight_bytes_per_param)
        self.jct_model = jct_model
        self.scheduler = Scheduler(spec.policy, jct_model, spec.lam)

    def run(self, requests: List[Request], qps: float) -> SimResult:
        insts = [_Instance(i, self.spec, self.jct_model, self.scheduler,
                           self.cache_blocks, self.block_size)
                 for i in range(self.n_instances)]
        # user-id routing, round-robin over first appearance (paper §7.1)
        user_map: Dict[str, int] = {}
        completed: List[Request] = []
        rejected = 0

        events: List = []           # (time, seq, kind, payload)
        seq = 0
        for r in requests:
            heapq.heappush(events, (r.arrival, seq, "arrive", r))
            seq += 1

        while events:
            now, _, kind, payload = heapq.heappop(events)
            if kind == "arrive":
                r: Request = payload
                if r.n_input > self.mil:
                    rejected += 1
                    continue
                uid = r.user_id or str(r.req_id)
                if uid not in user_map:
                    user_map[uid] = len(user_map) % self.n_instances
                inst = insts[user_map[uid]]
                r.n_cached_at_arrival = inst.cache.match_len(r.chain)
                inst.queue.append(r)
                started = inst.start_next(now)
                if started is not None:
                    heapq.heappush(events, (started.finish_time, seq,
                                            "finish", (inst, started)))
                    seq += 1
            else:
                inst, r = payload
                inst.finish(r, now)
                completed.append(r)
                started = inst.start_next(now)
                if started is not None:
                    heapq.heappush(events, (started.finish_time, seq,
                                            "finish", (inst, started)))
                    seq += 1

        lats = np.array([r.latency for r in completed]) if completed else np.array([0.0])
        makespan = (max(r.finish_time for r in completed)
                    - min(r.arrival for r in completed)) if completed else 1.0
        hit = sum(i.hit_tokens for i in insts)
        tot = max(1, sum(i.total_tokens for i in insts))
        return SimResult(
            name=self.spec.name, qps=qps, completed=len(completed),
            rejected=rejected, mean_latency=float(lats.mean()),
            p50_latency=float(np.percentile(lats, 50)),
            p99_latency=float(np.percentile(lats, 99)),
            throughput=len(completed) / max(makespan, 1e-9),
            hit_rate=hit / tot, mil=self.mil)
