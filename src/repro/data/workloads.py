"""Workload traces — paper Table 1 (post recommendation, credit verification).

Requests are generated with precomputed prefix hash chains so simulator-side
prefix matching never touches raw tokens. Real-token variants (for the CPU
engine examples) are available via ``materialize_tokens=True``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.prefix_cache import token_chain
from repro.core.scheduler import Request


@dataclasses.dataclass
class Trace:
    name: str
    requests: List[Request]

    @property
    def total_tokens(self) -> int:
        return sum(r.n_input for r in self.requests)

    @property
    def max_len(self) -> int:
        return max(r.n_input for r in self.requests)


def _poisson_arrivals(rng: np.random.Generator, n: int, rate: float) -> np.ndarray:
    if rate <= 0:
        return np.zeros(n)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def post_recommendation(qps: float, *, num_users: int = 20,
                        posts_per_user: int = 50, post_len: int = 150,
                        profile_mean: int = 14_000, profile_std: int = 3_000,
                        block_size: int = 16, vocab: int = 32_000,
                        seed: int = 0, materialize_tokens: bool = False,
                        scale_tokens: float = 1.0) -> Trace:
    """Paper Table 1 row 1: 20 users x 50 posts; requests of one user share
    the (11k-17k token) profile prefix. ``qps`` is the request-level Poisson
    rate. ``scale_tokens`` shrinks lengths for CPU-engine runs."""
    rng = np.random.default_rng(seed)
    n = num_users * posts_per_user
    arrivals = _poisson_arrivals(rng, n, qps)
    requests: List[Request] = []
    i = 0
    for u in range(num_users):
        plen = max(block_size,
                   int(rng.normal(profile_mean, profile_std) * scale_tokens))
        profile = rng.integers(0, vocab, size=plen).tolist()
        for _ in range(posts_per_user):
            post = rng.integers(0, vocab, size=max(1, int(post_len * scale_tokens))).tolist()
            tokens = profile + post
            requests.append(Request(
                n_input=len(tokens),
                arrival=float(arrivals[i]),
                chain=token_chain(tokens, block_size),
                tokens=tokens if materialize_tokens else None,
                user_id=f"user{u}",
            ))
            i += 1
    # interleave users in arrival order (Poisson over the joint stream)
    order = rng.permutation(n)
    for j, r in enumerate(requests):
        r.arrival = float(arrivals[order[j]])
    requests.sort(key=lambda r: r.arrival)
    return Trace("post_recommendation", requests)


def credit_verification(qps: float, *, num_users: int = 60,
                        len_low: int = 40_000, len_high: int = 60_000,
                        block_size: int = 16, vocab: int = 32_000,
                        seed: int = 0, materialize_tokens: bool = False,
                        scale_tokens: float = 1.0) -> Trace:
    """Paper Table 1 row 2: 60 users, one long request each (40k-60k tokens),
    no prefix sharing — stresses MIL."""
    rng = np.random.default_rng(seed)
    arrivals = _poisson_arrivals(rng, num_users, qps)
    requests = []
    for u in range(num_users):
        ln = max(block_size, int(rng.integers(len_low, len_high) * scale_tokens))
        tokens = rng.integers(0, vocab, size=ln).tolist()
        requests.append(Request(
            n_input=ln,
            arrival=float(arrivals[u]),
            chain=token_chain(tokens, block_size),
            tokens=tokens if materialize_tokens else None,
            user_id=f"user{u}",
        ))
    return Trace("credit_verification", requests)


def get_trace(name: str, qps: float, **kw) -> Trace:
    if name == "post_recommendation":
        return post_recommendation(qps, **kw)
    if name == "credit_verification":
        return credit_verification(qps, **kw)
    raise KeyError(name)
