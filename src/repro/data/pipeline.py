"""Deterministic, resumable synthetic-token data pipeline.

Framework-shaped: the source is synthetic (a seeded LCG over vocab with a
Zipf-ish skew so losses move), but the machinery is real — host-sharded
loading, checkpointable iterator state (save the step counter, restore the
exact stream), and document-boundary labels for next-token prediction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    doc_len_mean: int = 512      # documents are packed; EOS id = 0


class TokenStream:
    """Stateless-random access: batch ``i`` is a pure function of (seed, i),
    so restore = set ``step``. Host-sharded via (host_id, num_hosts)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1,
                 step: int = 0):
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.step = step
        assert cfg.global_batch % num_hosts == 0
        self.local_batch = cfg.global_batch // num_hosts

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.cfg.seed,
                "host_id": self.host_id, "num_hosts": self.num_hosts}

    def restore(self, state: Dict):
        assert state["seed"] == self.cfg.seed, "seed mismatch on restore"
        self.step = state["step"]

    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = []
        for b in range(self.local_batch):
            row_seed = (cfg.seed * 1_000_003 + step) * 65_537 \
                       + self.host_id * self.local_batch + b
            rng = np.random.default_rng(row_seed)
            # Zipf-skewed token draw (clipped), packed docs with EOS=0
            toks = rng.zipf(1.3, size=cfg.seq_len + 1)
            toks = np.minimum(toks, cfg.vocab_size - 1).astype(np.int32)
            n_eos = max(1, (cfg.seq_len + 1) // max(cfg.doc_len_mean, 2))
            eos_pos = rng.integers(0, cfg.seq_len + 1, size=n_eos)
            toks[eos_pos] = 0
            rows.append(toks)
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self._batch_at(self.step)
        self.step += 1
        return batch


def shard_batch(batch: Dict[str, np.ndarray], shardings) -> Dict:
    """Place a host batch onto devices under the given NamedShardings."""
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}
