"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    Newer jax exposes ``jax.sharding.AxisType`` and expects explicit
    ``axis_types``; on older releases the attribute does not exist and
    ``make_mesh`` defaults every axis to Auto anyway. Tests and launch code
    build meshes through this helper so version drift stays localized here.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (data, model); 2 pods => (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate 1x1 (or 1xN) mesh for CPU smoke/integration tests."""
    n = jax.device_count()
    data = max(1, n // model_axis)
    return make_mesh((data, model_axis), ("data", "model"))
