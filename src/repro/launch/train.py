"""Training driver: fault-tolerant loop over the jitted train step.

Works at two scales with the same code path:
  * CPU smoke / examples: reduced config, host mesh (1 device)
  * TPU pods: production mesh (the dry-run proves these compile)

Fault tolerance wired in: async sharded checkpoints (atomic + CRC), NaN
skip/reload policy, SIGTERM preemption -> checkpoint-then-exit, straggler
watchdog, resume (including onto a different mesh — elastic re-shard).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint)
from repro.configs import get_config, reduce_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, TokenStream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import build_step, lower_step, rules_for
from repro.optim import adamw
from repro.runtime import sharding as shd
from repro.runtime.fault_tolerance import (NaNGuard, PreemptionHandler,
                                           StepWatchdog)


def train(arch: str, *, steps: int = 100, seq_len: int = 256,
          global_batch: int = 8, reduced: bool = True,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          production_mesh: bool = False, seed: int = 0,
          log_every: int = 10, grad_compression: str = "none",
          schedule: str = "cosine"):
    cfg = get_config(arch)
    if reduced:
        cfg = reduce_config(cfg)
    shp = ShapeConfig("custom", seq_len, global_batch, "train")
    mesh = (make_production_mesh() if production_mesh else make_host_mesh())
    rules = rules_for(cfg, shp, mesh)
    opt_cfg = adamw.AdamWConfig(total_steps=steps,
                                warmup_steps=max(1, steps // 10),
                                grad_compression=grad_compression,
                                schedule=schedule)
    bundle = build_step(cfg, shp, mesh, rules, opt_cfg)

    with mesh, shd.use_sharding(mesh, rules):
        step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                          out_shardings=bundle.out_shardings,
                          donate_argnums=bundle.donate_argnums)
        # materialize an initial state under the right shardings
        defs = bundle.api.defs()
        params = shd.materialize(jax.random.PRNGKey(seed), defs, jnp.float32)
        state = adamw.init_state(params)
        state_sh = bundle.in_shardings[0]
        state = jax.tree_util.tree_map(jax.device_put, state, state_sh)

        data = TokenStream(DataConfig(cfg.vocab_size, seq_len, global_batch,
                                      seed=seed))
        start_step = 0
        ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        if ckpt_dir and latest_step(ckpt_dir) is not None:
            data_sh = jax.tree_util.tree_map(lambda _: None, data.state())
            start_step, payload = restore_checkpoint(
                ckpt_dir, {"state": state, "data": data.state()},
                shardings={"state": state_sh, "data": data_sh})
            state = payload["state"]
            data.restore(jax.tree_util.tree_map(
                lambda x: int(np.asarray(x)), payload["data"]))
            print(f"[train] resumed from step {start_step}")

        guard = NaNGuard()
        watchdog = StepWatchdog()
        preempt = PreemptionHandler().install()
        losses = []
        last_good = None
        for step in range(start_step, steps):
            batch_np = next(data)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if watchdog.observe(dt):
                print(f"[train] straggler: step {step} took {dt:.2f}s "
                      f"(deadline {watchdog.deadline():.2f}s)")
            verdict = guard.observe(loss)
            if verdict == "reload" and last_good is not None:
                print(f"[train] NaN streak — reloading step {last_good[0]}")
                state = last_good[1]
                continue
            if verdict == "skip":
                print(f"[train] non-finite loss at step {step}; skipping")
                continue
            losses.append(loss)
            if step % log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['gnorm']):.3f} {dt*1e3:.0f}ms",
                      flush=True)
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, {"state": state, "data": data.state()})
                last_good = (step + 1, state)
            if preempt.requested:
                print("[train] preemption requested — checkpointing")
                if ckpt:
                    ckpt.save(step + 1, {"state": state,
                                         "data": data.state()})
                break
        if ckpt:
            ckpt.wait()
        preempt.uninstall()
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (TPU pods)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8"])
    args = ap.parse_args()
    losses = train(args.arch, steps=args.steps, seq_len=args.seq_len,
                   global_batch=args.global_batch,
                   reduced=not args.full_size,
                   production_mesh=args.production_mesh,
                   ckpt_dir=args.ckpt_dir,
                   grad_compression=args.grad_compression)
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
