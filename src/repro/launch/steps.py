"""Step builders: jit-able train / prefill / decode steps with full sharding
specifications for a given (arch config x workload shape x mesh).

Used by the dry-run (ShapeDtypeStruct lowering), the trainer, and the server.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import hybrid as hybrid_model
from repro.models import ssm_model
from repro.models import transformer as tfm
from repro.models.model import ModelAPI, build, input_specs
from repro.optim import adamw
from repro.runtime import sharding as shd

# a representative prefix-KV budget for prefill dry-run cells (tokens kept
# per request by suffix discard; the serving runtime derives the real value
# from kv_policy.MemoryModel.prefix_budget_tokens)
DEFAULT_KV_KEEP = 4096

# gradient-accumulation target: tokens per device per microbatch. Bounds the
# live activation footprint (remat keeps one block-input per layer per
# microbatch — measured f32 on the CPU backend, so budget conservatively)
# and lets the per-microbatch gradient psum overlap the next microbatch's
# backward.
MICROBATCH_TOKENS = 4096


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def _batch_axes(rules: Dict, mesh: Mesh):
    axes = rules.get("batch") or ()
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if a in mesh.shape)


def microbatches_for(shp: ShapeConfig, mesh: Mesh,
                     target_tokens: int = MICROBATCH_TOKENS,
                     dp: Optional[int] = None) -> int:
    if dp is None:
        dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    local_seqs = max(1, shp.global_batch // dp)
    want = max(1, (local_seqs * shp.seq_len) // target_tokens)
    # largest divisor of local_seqs that is <= want
    mb = 1
    for d in range(1, local_seqs + 1):
        if local_seqs % d == 0 and d <= want:
            mb = d
    return mb


# Named rule presets for perf hillclimbing (dryrun --preset <name>).
PRESETS = {
    # PrefillOnly's own thesis at pod scale: no model parallelism — the model
    # is replicated per chip (instance), batch shards over EVERY mesh axis.
    "dp_full": {
        "batch": ("pod", "data", "model"),
        "shards": ("pod", "data", "model"),
        "heads": None, "kv_heads": None, "qkv": None, "d_ff": None,
        "vocab": None, "d_model": None, "ssm_inner": None, "ssm_heads": None,
        "experts": None, "seq": None,
    },
    # Megatron sequence parallelism on top of the default TP layout.
    "sp": {"seq": "model"},
    # expert parallelism over the model axis (experts must divide it)
    "ep": {"experts": "model", "d_ff": None},
    # context-parallel serving: weights replicated (use with --fp8), tokens
    # sharded batch x data and seq x model; attention all-gathers only K/V
    # (GQA makes that small), MLP is fully token-parallel — no activation
    # psums at all.
    "cp_serve": {
        "seq": "model", "attn_seq": "model",
        "d_ff": None, "qkv": None, "heads": None, "kv_heads": None,
        "vocab": None, "d_model": None, "shards": ("pod", "data", "model"),
    },
}


def _family_module(cfg: ModelConfig):
    if cfg.family == "ssm":
        return ssm_model
    if cfg.family == "hybrid":
        return hybrid_model
    return tfm


def rules_for(cfg: ModelConfig, shp: ShapeConfig, mesh: Mesh,
              overrides: Optional[Dict] = None) -> Dict:
    """Per-cell logical->mesh rules. long-context decode (batch too small to
    shard) turns on KV-sequence context parallelism over the data axis."""
    rules = shd.make_rules()
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    tp = mesh.shape.get("model", 1)
    # FSDP: when TP alone leaves > ~25% of HBM in weights (the big MoEs),
    # additionally shard every weight's d_model dim over the data axes.
    # Weights enter the layer scan as xs, so XLA all-gathers ONE LAYER at a
    # time inside the loop — classic FSDP gather-per-layer behaviour.
    from repro.runtime.hw import TPU_V5E
    wbytes = (2 if shp.kind == "train"
              else jnp.dtype(cfg.param_dtype).itemsize)
    if cfg.param_count() * wbytes / tp > 0.25 * TPU_V5E.hbm_bytes:
        rules["d_model"] = ("pod", "data")
        if shp.kind == "train":
            # Megatron-style sequence parallelism: the residual stream (and
            # with it the remat-saved activation stacks) shards over the
            # model axis between blocks; attention/MLP gather per layer
            # ("attn_seq" stays unsharded).
            rules["seq"] = "model"
    if shp.kind == "decode" and shp.global_batch < dp:
        rules["kv_seq"] = "data"
        rules["seq"] = None
    if (shp.kind == "decode" and cfg.has_attention
            and cfg.num_kv_heads % tp != 0):
        # GQA with fewer KV heads than the TP degree: shard head_dim instead
        # so the 32k-deep KV cache still splits across the model axis
        rules["kv_heads"] = None
        rules["head_dim"] = "model"
    if cfg.is_moe and cfg.num_experts % mesh.shape.get("model", 1) == 0:
        # EP is available when experts divide the model axis — still TP by
        # default (see DESIGN.md perf log); flip via overrides.
        pass
    if overrides:
        rules.update(overrides)
    return rules


def num_shards_for(shp: ShapeConfig, mesh: Mesh,
                   rules: Optional[Dict] = None) -> int:
    """Device-local token grouping for the sort-based MoE dispatch."""
    dp = _axes_size(mesh, (rules or {}).get("shards", ("pod", "data")))
    tokens = shp.global_batch * (shp.seq_len if shp.kind != "decode" else 1)
    return dp if tokens % dp == 0 else 1


def _batch_shardings(specs: Dict, mesh: Mesh, rules: Dict) -> Dict:
    axes_by_rank = {
        2: ("batch", "seq"),
        3: ("batch", "seq", "d_model"),
        1: ("batch",),
    }

    def shard(leaf):
        axes = axes_by_rank[len(leaf.shape)]
        return NamedSharding(mesh, shd.resolve_spec(axes, shape=leaf.shape,
                                                    mesh=mesh, rules=rules))

    return jax.tree_util.tree_map(shard, specs)


def _cache_shardings(cfg: ModelConfig, cache_specs: Dict, mesh: Mesh,
                     rules: Dict) -> Dict:
    axes_tree = _family_module(cfg).cache_axes(cfg)

    def shard(leaf, axes):
        return NamedSharding(mesh, shd.resolve_spec(axes, shape=leaf.shape,
                                                    mesh=mesh, rules=rules))

    return {k: shard(cache_specs[k], axes_tree[k]) for k in cache_specs}


@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower one (arch x shape x mesh) cell."""
    fn: Callable
    in_specs: Tuple              # ShapeDtypeStructs (positional)
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    api: ModelAPI
    meta: Dict


def build_step(cfg: ModelConfig, shp: ShapeConfig, mesh: Mesh,
               rules: Optional[Dict] = None,
               opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()) -> StepBundle:
    rules = rules or rules_for(cfg, shp, mesh)
    if rules.get("seq") == "model":
        # under SP, token-chunked slicing along a sharded seq axis would
        # reshard every chunk — the TP/SP sharding already bounds those
        # intermediates, so chunking is redundant here. MoE capacity drops
        # to 1.0 (the dispatch buffers are the next-largest train tensors).
        cfg = dataclasses.replace(cfg, hybrid_chunk=0, logits_chunk=0,
                                  capacity_factor=1.0)
    api = build(cfg)
    defs = api.defs()
    nsh = num_shards_for(shp, mesh, rules)
    dp_axes_b = _batch_axes(rules, mesh)
    dp_batch = _axes_size(mesh, dp_axes_b)
    params_abs = shd.abstract_params(defs, jnp.float32 if shp.kind == "train"
                                     else cfg.param_dtype)
    param_sh = shd.param_shardings(defs, mesh, rules)
    specs = input_specs(cfg, shp, api)
    repl = NamedSharding(mesh, P())

    if shp.kind == "train":
        if rules.get("d_model") is not None and \
                opt_cfg.moment_dtype == "float32":
            # weight-dominated (FSDP) cells: bf16 Adam moments halve the
            # optimizer-state footprint (master params stay fp32), and the
            # microbatch gradient accumulator runs in bf16 (the
            # grad-compression knob applied at the accumulation step)
            opt_cfg = dataclasses.replace(opt_cfg, moment_dtype="bfloat16",
                                          grad_compression="bf16")
        mdt = jnp.dtype(opt_cfg.moment_dtype)
        state_abs = {
            "params": params_abs,
            "m": shd.abstract_params(defs, mdt),
            "v": shd.abstract_params(defs, mdt),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        # ZeRO-1: fp32 master/moments sharded over DP axes as well
        opt_sh = shd.optimizer_shardings(defs, mesh, rules)
        state_sh = {"params": opt_sh, "m": opt_sh, "v": opt_sh,
                    "step": repl}
        batch_sh = _batch_shardings(specs["batch"], mesh, rules)
        mb = microbatches_for(shp, mesh, dp=dp_batch)

        def train_step(state, batch):
            from repro.models.model import cast_params

            # all-gather the DP-sharded master weights ONCE, in bf16
            params_c = cast_params(state["params"], cfg.dtype)
            params_c = jax.tree_util.tree_map(
                lambda a, s: jax.lax.with_sharding_constraint(a, s),
                params_c, param_sh)

            def loss_fn(p, mbatch):
                return api.train_loss(p, mbatch, num_shards=nsh)

            acc_dtype = (jnp.bfloat16 if opt_cfg.grad_compression == "bf16"
                         else jnp.float32)
            if mb == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params_c, batch)
                grads = jax.tree_util.tree_map(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g.astype(acc_dtype), s), grads, opt_sh)
            else:
                # gradient accumulation: scan over microbatches; activations
                # live only within one microbatch's grad computation, and the
                # per-microbatch grad psum overlaps the next one's backward.
                # The split is DEVICE-LOCAL: each device contributes
                # local/mb of its own rows to every microbatch (no resharding).
                dp_axes = dp_axes_b
                dp = dp_batch
                B = shp.global_batch
                local = B // dp

                def split(x):
                    tail = x.shape[1:]
                    x = x.reshape(dp, mb, local // mb, *tail)
                    x = jnp.moveaxis(x, 1, 0).reshape(mb, B // mb, *tail)
                    spec = P(None, dp_axes, *([None] * len(tail)))
                    return jax.lax.with_sharding_constraint(
                        x, NamedSharding(mesh, spec))

                mbatches = jax.tree_util.tree_map(split, batch)
                # the accumulator lives DP-sharded (ZeRO): each microbatch's
                # grads are reduce-scattered into it
                zero = jax.tree_util.tree_map(
                    lambda p, s: jax.lax.with_sharding_constraint(
                        jnp.zeros(p.shape, acc_dtype), s),
                    state["params"], opt_sh)

                def body(acc, mbatch):
                    g_acc, l_acc = acc
                    l, g = jax.value_and_grad(loss_fn)(params_c, mbatch)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, gg, s: jax.lax.with_sharding_constraint(
                            a + gg.astype(acc_dtype), s),
                        g_acc, g, opt_sh)
                    return (g_acc, l_acc + l), None

                (grads, loss), _ = jax.lax.scan(
                    body, (zero, jnp.zeros((), jnp.float32)), mbatches)
                grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
                loss = loss / mb

            # (bf16 compression already applied at accumulation when on)
            new_state = adamw.apply_updates(state, grads, opt_cfg)
            return new_state, {"loss": loss,
                               "gnorm": adamw.global_norm(grads)}

        return StepBundle(
            fn=train_step,
            in_specs=(state_abs, specs["batch"]),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, {"loss": repl, "gnorm": repl}),
            donate_argnums=(0,),
            api=api,
            meta={"kind": "train", "num_shards": nsh, "microbatches": mb},
        )

    if shp.kind == "prefill":
        kv_keep = min(DEFAULT_KV_KEEP, shp.seq_len)
        batch_sh = _batch_shardings(specs["batch"], mesh, rules)

        def prefill_step(params, batch):
            return api.prefill(params, batch, kv_keep=kv_keep,
                               num_shards=nsh)

        # explicit output shardings: the prefix-KV tree is large (layers x
        # batch x kv_keep x heads) — left unspecified XLA may replicate it
        logits_sh = NamedSharding(mesh, shd.resolve_spec(
            ("batch", "vocab"), shape=(shp.global_batch, cfg.vocab_size),
            mesh=mesh, rules=rules))
        with shd.use_sharding(mesh, rules):
            out_abs = jax.eval_shape(prefill_step, params_abs,
                                     specs["batch"])
        kv_abs = out_abs[1]
        kv_sh = None
        if kv_abs is not None:
            axes_tree = _family_module(cfg).cache_axes(cfg)
            kv_sh = {
                k: NamedSharding(mesh, shd.resolve_spec(
                    axes_tree[k], shape=kv_abs[k].shape, mesh=mesh,
                    rules=rules))
                for k in kv_abs
            }
        return StepBundle(
            fn=prefill_step,
            in_specs=(params_abs, specs["batch"]),
            in_shardings=(param_sh, batch_sh),
            out_shardings=(logits_sh, kv_sh),
            donate_argnums=(),
            api=api,
            meta={"kind": "prefill", "num_shards": nsh, "kv_keep": kv_keep},
        )

    # decode: serve_step(params, tokens, cache, position)
    cache_specs = specs["cache"]
    cache_sh = _cache_shardings(cfg, cache_specs, mesh, rules)
    tok_sh = _batch_shardings({"t": specs["tokens"]}, mesh, rules)["t"]

    def serve_step(params, tokens, cache, position):
        return api.decode_step(params, tokens, cache, position,
                               num_shards=nsh)

    logits_sh = NamedSharding(mesh, shd.resolve_spec(
        ("batch", "vocab"), shape=(shp.global_batch, cfg.vocab_size),
        mesh=mesh, rules=rules))
    return StepBundle(
        fn=serve_step,
        in_specs=(params_abs, specs["tokens"], cache_specs,
                  specs["position"]),
        in_shardings=(param_sh, tok_sh, cache_sh, repl),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(2,),
        api=api,
        meta={"kind": "decode", "num_shards": nsh},
    )


def lower_step(bundle: StepBundle, mesh: Mesh, rules: Optional[Dict] = None):
    """Trace + lower under the sharding context (zero allocation)."""
    with shd.use_sharding(mesh, rules):
        jitted = jax.jit(bundle.fn,
                         in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate_argnums)
        return jitted.lower(*bundle.in_specs)
