"""Serving driver: async PrefillOnly instance pool + trace replay.

The paper's deployment shape (§7.1): N single-model-copy engine instances
behind a router, each running Algorithm-1 scheduling with continuous JCT
calibration and suffix-KV discard. Since PR 2 the driver is ASYNC: an
``AsyncServer`` runs one worker thread per engine, the submitting thread
replays the trace open-loop in real time (sleep to each arrival, submit,
move on — no polling step loop), and every request resolves through a
``Future`` to either a scored result or a typed ``Rejected``.

Routing is pluggable (``--router user_hash`` is the paper's rendezvous user
hash; ``--router least_backlog`` routes on predicted-JCT backlog with
cache-affinity tie-break — exploiting the JCT predictability that is the
paper's whole point). Admission control (MIL + deadline feasibility) and
in-queue deadline shedding are on by default when ``--deadline`` is given.

On this CPU box the instances run reduced configs with REAL forwards; on TPU
each instance is one mesh tile (see DESIGN.md §5 instance sizing).
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from pathlib import Path
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.core.kv_policy import MemoryModel
from repro.data.workloads import get_trace
from repro.models.model import build
from repro.runtime.fault_tolerance import (InstancePool,
                                           JCTDeadlineWatchdog,
                                           PreemptionHandler)
from repro.runtime.sharding import materialize
from repro.serving import (AdmissionController, AsyncServer,
                           BrownoutController, ChaosConfig, FaultPlan,
                           Rejected, RetryPolicy, SpanTracer, get_router,
                           make_process_pool, wire_supervisor, wrap_pool,
                           wrap_pool_processes)


def make_pool(arch: str, n_instances: int = 2, *, reduced: bool = True,
              policy: str = "srjf_calibrated", lam: float = 0.05,
              cache_tokens: int = 4096, seed: int = 0,
              profile: bool = False, offload: bool = False,
              host_cache_mb: int = 256,
              profile_lengths=(32, 64, 128)) -> InstancePool:
    """Build N engine instances over ONE set of materialized weights.

    ``profile=True`` runs the paper's profile step per instance: fits the
    JCT linear proxy on measured forwards (so routing/admission predictions
    start calibrated, not from the generic default) and auto-tunes the
    prepacking budget from the fitted curve. ``offload=True`` gives every
    instance the DRAM KV tier (``host_cache_mb`` per instance): evicted
    prefix blocks demote to host memory and restore instead of recomputing.
    """
    cfg = get_config(arch)
    if reduced:
        cfg = reduce_config(cfg, hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(seed), api.defs(), jnp.float32)

    def make_engine(name: str) -> PrefillOnlyEngine:
        eng = PrefillOnlyEngine(cfg, params, EngineConfig(
            policy=policy, lam=lam, cache_capacity_tokens=cache_tokens,
            offload=offload, host_cache_bytes=host_cache_mb << 20))
        if profile:
            eng.profile(profile_lengths)
        return eng

    pool = InstancePool(make_engine)
    pool.scale_to([f"inst{i}" for i in range(n_instances)])
    return pool


def make_worker_pool(arch: str, n_workers: int, *, reduced: bool = True,
                     policy: str = "srjf_calibrated", lam: float = 0.05,
                     cache_tokens: int = 4096, seed: int = 0,
                     profile: bool = False, offload: bool = False,
                     host_cache_mb: int = 256,
                     rpc_fault_hook=None,
                     drain_grace: float = 30.0):
    """Process-mode pool: one supervised engine WORKER PROCESS per instance
    (each builds its own weights — crash isolation is the point), plus the
    supervisor that heartbeats, declares death, and restarts them. The
    supervision constants are sized for real engines on CPU: a jit compile
    can hold the GIL for seconds, so the miss budget tolerates ~6s of
    unanswered beats before declaring a freeze. ``offload`` rides the spec
    into each worker's EngineConfig; the worker's hello reports the tier
    back so the frontend only spends prefetch RPCs on tiered workers."""
    ecfg = ({"offload": True, "host_cache_bytes": host_cache_mb << 20}
            if offload else {})
    specs = {f"inst{i}": {"kind": "engine", "arch": arch, "reduced": reduced,
                          "policy": policy, "lam": lam,
                          "cache_tokens": cache_tokens, "seed": seed,
                          "profile": profile, "ecfg": ecfg}
             for i in range(n_workers)}
    return make_process_pool(
        specs, lease=30.0, heartbeat_interval=0.5, miss_budget=12,
        restart_backoff=0.5, restart_backoff_cap=8.0,
        drain_grace=drain_grace, spawn_timeout=600.0, step_timeout=300.0,
        rpc_fault_hook=rpc_fault_hook)


def start_metrics_server(registry, port: int = 0, host: str = "127.0.0.1",
                         tracer=None) -> ThreadingHTTPServer:
    """Plain-HTTP observability endpoint over a ``MetricsRegistry`` (and,
    when a ``SpanTracer`` is given, its trace rings).

    GET /metrics           Prometheus text exposition
    GET /trace             finished request timelines + batch records, JSONL
    GET /trace.chrome.json Chrome-trace JSON (open in Perfetto / about:tracing)

    Anything else is 404. Runs in a daemon thread; ``port=0`` binds an
    ephemeral port (read it back from ``server.server_address``). Call
    ``server.shutdown()`` to stop.
    """

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):                          # noqa: N802 (stdlib API)
            path = self.path.rstrip("/")
            if path in ("", "/metrics"):
                body = registry.render_prometheus().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif path == "/trace" and tracer is not None:
                body = tracer.dump_jsonl().encode()
                ctype = "application/x-ndjson; charset=utf-8"
            elif path == "/trace.chrome.json" and tracer is not None:
                body = json.dumps(tracer.chrome_trace()).encode()
                ctype = "application/json; charset=utf-8"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):                 # keep stdout clean
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    threading.Thread(target=server.serve_forever, daemon=True,
                     name="metrics-http").start()
    return server


def write_trace_dump(tracer, path) -> Path:
    """Write the JSONL dump to ``path`` plus the Chrome-trace JSON next to
    it (``<stem>.chrome.json``). Returns the chrome-trace path."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(tracer.dump_jsonl())
    cp = p.with_suffix(".chrome.json")
    cp.write_text(json.dumps(tracer.chrome_trace()))
    return cp


def serve_trace(arch: str = "qwen1.5-0.5b",
                trace_name: str = "post_recommendation",
                qps: float = 5.0, n_instances: int = 2, workers: int = 0,
                scale_tokens: float = 0.02, policy: str = "srjf_calibrated",
                lam: float = 0.05, seed: int = 0,
                max_requests: Optional[int] = None,
                router: str = "least_backlog",
                deadline: Optional[float] = None,
                admission: bool = True,
                max_input_tokens: Optional[int] = None,
                profile: bool = False,
                pool: Optional[InstancePool] = None,
                trace_kw: Optional[Dict] = None,
                metrics_port: Optional[int] = None,
                retry_budget: int = 2,
                watchdog: bool = True,
                watchdog_factor: float = 4.0,
                watchdog_min_deadline: float = 1.0,
                brownout: bool = False,
                chaos: Optional[ChaosConfig] = None,
                drain_timeout: Optional[float] = 30.0,
                trace_dump: Optional[str] = None,
                trace_capacity: int = 4096,
                offload: bool = False,
                host_cache_mb: int = 256,
                cache_tokens: int = 4096) -> Dict:
    """Replay a paper workload through the AsyncServer. Returns latency
    stats over SERVED requests plus rejection counts and a telemetry dump.

    ``deadline`` is seconds after each request's arrival; with
    ``admission=True`` doomed requests are rejected/shed instead of blowing
    out the tail. ``pool=None`` builds a fresh pool (pass one to reuse
    warmed engines across runs). ``metrics_port`` starts a plain-HTTP
    Prometheus scrape endpoint (GET /metrics) for the duration of the
    replay; 0 picks an ephemeral port.

    Robustness: the JCT-deadline watchdog and idempotent retry are ON by
    default (``watchdog=False`` / ``retry_budget=0`` disable); ``brownout``
    arms the graceful-degradation ladder; ``chaos`` wraps the pool in the
    seeded fault injector (``serving.chaos``). SIGTERM/SIGINT during the
    replay stops submitting and drains in-flight work for up to
    ``drain_timeout`` seconds instead of dying mid-batch.

    ``workers=N`` runs PROCESS mode: N supervised engine worker processes
    behind the RPC boundary instead of N in-process engine threads. Chaos
    in process mode injects the process/RPC fault kinds (``kill``,
    ``freeze``, ``rpc_drop``, ``rpc_delay``); the in-process step/submit
    kinds only apply in thread mode.
    """
    plan = FaultPlan(chaos) if chaos is not None else None
    sup = None
    if workers and pool is None:
        pool, sup = make_worker_pool(
            arch, workers, policy=policy, lam=lam, seed=seed,
            profile=profile, offload=offload, host_cache_mb=host_cache_mb,
            cache_tokens=cache_tokens,
            rpc_fault_hook=plan.rpc_fault if plan is not None else None,
            drain_grace=min(drain_timeout or 30.0, 30.0))
    elif pool is None:
        pool = make_pool(arch, n_instances, policy=policy, lam=lam,
                         seed=seed, profile=profile, offload=offload,
                         host_cache_mb=host_cache_mb,
                         cache_tokens=cache_tokens)
    if plan is not None and sup is None:
        wrap_pool(pool, plan)
    ctrl = None
    if admission:
        # MIL from the engines' own model config unless given explicitly —
        # the same closed form the profile run sizes the KV budget with.
        # Remote engines hold no model config frontend-side; rebuild the
        # (weights-free) config the workers were spawned with.
        eng_cfg = getattr(next(iter(pool.engines.values())), "cfg", None)
        if eng_cfg is None:
            eng_cfg = reduce_config(get_config(arch), hybrid_chunk=0)
        # price the engines' actual KV lifecycle into the MIL gate: finite
        # kv_keep means peak-layer suffix footprint, not all-layers
        any_eng = next(iter(pool.engines.values()))
        kv_keep = getattr(getattr(any_eng, "ecfg", None),
                          "kv_keep_tokens", None)
        if kv_keep is not None and kv_keep >= 10**9:
            kv_keep = None
        ctrl = AdmissionController(max_input_tokens=max_input_tokens,
                                   memory_model=MemoryModel(eng_cfg),
                                   kv_keep=kv_keep)
    # always-on request-lifecycle tracing: the ring bounds memory and the
    # per-event cost is one lock + list append (<3% on the packing
    # benchmark — see BENCH_packing.json), so the replay always records
    # full timelines; --trace-dump / the /trace endpoint just export them
    tracer = SpanTracer(capacity=trace_capacity)
    server = AsyncServer(
        pool, router=get_router(router), admission=ctrl,
        retry=RetryPolicy(budget=retry_budget),
        watchdog=(JCTDeadlineWatchdog(factor=watchdog_factor,
                                      min_deadline=watchdog_min_deadline)
                  if watchdog else None),
        brownout=BrownoutController() if brownout else None,
        tracer=tracer)
    if sup is not None:
        wire_supervisor(sup, server)
        if plan is not None:
            wrap_pool_processes(pool, plan, sup)
    server.start()
    if sup is not None:
        sup.start()
        print(f"workers: " + " ".join(
            f"{n}=pid:{sup.handles[n].pid}" for n in sorted(sup.handles)),
            flush=True)
    exporter = None
    # SIGTERM/SIGINT -> drain instead of dying mid-batch (satellite of the
    # chaos-hardening PR: a preempted serve CLI must resolve every future)
    handler = PreemptionHandler().install()
    if metrics_port is not None:
        exporter = start_metrics_server(server.metrics, metrics_port,
                                        tracer=tracer)
        print(f"metrics: http://{exporter.server_address[0]}:"
              f"{exporter.server_address[1]}/metrics  "
              f"(+ /trace, /trace.chrome.json)")
    try:
        out = _replay(server, arch, trace_name, qps, scale_tokens, seed,
                      max_requests, deadline, pool, trace_kw,
                      stop=lambda: handler.requested,
                      drain_timeout=drain_timeout)
        if plan is not None:
            out["faults_injected"] = plan.counts()
        if trace_dump:
            cp = write_trace_dump(tracer, trace_dump)
            print(f"trace dump: {trace_dump} + {cp}")
        return out
    finally:
        handler.uninstall()
        if sup is not None:
            sup.stop(graceful=True)
        # shutdown() stops serve_forever; server_close() releases the bound
        # socket — without it a second serve_trace on the same port (the
        # documented warmed-pool reuse pattern) dies with EADDRINUSE
        if exporter is not None:
            exporter.shutdown()
            exporter.server_close()


def _replay(server, arch, trace_name, qps, scale_tokens, seed, max_requests,
            deadline, pool, trace_kw, stop=None,
            drain_timeout=None) -> Dict:
    trace = get_trace(trace_name, qps, scale_tokens=scale_tokens,
                      materialize_tokens=True,
                      vocab=min(512, get_config(arch).vocab_size), seed=seed,
                      **(trace_kw or {}))
    requests = trace.requests[:max_requests] if max_requests else trace.requests
    yes_no = (5, 9)

    t0 = time.perf_counter()
    futures = []
    preempted = False
    for r in requests:                      # open loop: real-time arrivals
        # sleep to the arrival in short slices so a SIGTERM mid-gap stops
        # the replay within ~100ms, not after the longest arrival gap
        while True:
            if stop is not None and stop():
                preempted = True
                break
            delay = t0 + r.arrival - time.perf_counter()
            if delay <= 0:
                break
            time.sleep(min(delay, 0.1))
        if preempted:
            break
        futures.append(server.submit(
            r.user_id, r.tokens, allowed_tokens=yes_no,
            deadline=(t0 + r.arrival + deadline) if deadline else None))
    server.drain(timeout=drain_timeout)
    wall = time.perf_counter() - t0
    # if the drain timed out, shutdown resolves the stragglers Rejected
    # ("shutdown") — a preempted/overloaded replay still resolves every
    # future before reporting
    server.shutdown(drain=True, timeout=1.0 if drain_timeout else None)

    outcomes = [f.result() for f in futures]
    served = [o for o in outcomes if not isinstance(o, Rejected)]
    rejected = [o for o in outcomes if isinstance(o, Rejected)]
    # no fabricated samples: a fully-shed run reports NaN latency, not a
    # vacuous 0.0 that would read as a perfect tail
    lats = np.array([o["latency"] for o in served]) if served \
        else np.array([np.nan])
    hit = sum(o["n_cached"] for o in served)
    tot = sum(o["n_input"] for o in served)
    reasons: Dict[str, int] = {}
    for o in rejected:
        reasons[o.reason] = reasons.get(o.reason, 0) + 1
    return {
        "requests": len(outcomes),
        "served": len(served),
        "rejected": len(rejected),
        "reject_reasons": reasons,
        "preempted": preempted,
        "retried": server.metrics.total("requests_retried"),
        "watchdog_trips": server.metrics.total("watchdog_trips"),
        "wall_seconds": wall,
        "throughput_rps": len(served) / wall,
        "mean_latency": float(lats.mean()),
        "p50_latency": float(np.percentile(lats, 50)),
        "p99_latency": float(np.percentile(lats, 99)),
        "token_hit_rate": hit / max(tot, 1),
        # JCT-calibration fit per instance: coefficients, residual p50/p95,
        # refit counts — readable from results without scraping Prometheus
        "jct_fit": {n: e.stats().get("jct")
                    for n, e in pool.engines.items()},
        "trace": (server.tracer.stats()
                  if server.tracer is not None else None),
        "metrics": server.metrics.render(),
        "per_instance": {n: e.stats() for n, e in pool.engines.items()},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--trace", default="post_recommendation")
    ap.add_argument("--qps", type=float, default=5.0)
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--workers", type=int, default=0, metavar="N",
                    help="process mode: N supervised engine worker "
                         "PROCESSES behind the RPC boundary (0 = classic "
                         "in-process thread mode with --instances engines)")
    ap.add_argument("--policy", default="srjf_calibrated",
                    choices=["fifo", "srjf", "srjf_calibrated"])
    ap.add_argument("--router", default="least_backlog",
                    choices=["user_hash", "least_backlog"])
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline, seconds after arrival")
    ap.add_argument("--no-admission", action="store_true")
    ap.add_argument("--profile", action="store_true",
                    help="run the JCT profile fit per instance first")
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--scale-tokens", type=float, default=0.02)
    ap.add_argument("--max-requests", type=int, default=60)
    ap.add_argument("--dump-metrics", action="store_true")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text metrics on this port "
                         "(GET /metrics, /trace, /trace.chrome.json) "
                         "during the replay; 0 = ephemeral")
    ap.add_argument("--trace-dump", default=None, metavar="PATH",
                    help="write request/batch timelines as JSONL to PATH "
                         "(+ PATH stem .chrome.json for Perfetto) on exit")
    ap.add_argument("--retry-budget", type=int, default=2,
                    help="idempotent re-submissions per lost request "
                         "(0 disables retry)")
    ap.add_argument("--no-watchdog", action="store_true",
                    help="disable the JCT-deadline hang watchdog")
    ap.add_argument("--watchdog-factor", type=float, default=4.0,
                    help="trip when an in-flight batch exceeds this "
                         "multiple of its predicted JCT")
    ap.add_argument("--watchdog-min-deadline", type=float, default=1.0,
                    help="absolute floor on the per-batch deadline, sec")
    ap.add_argument("--brownout", action="store_true",
                    help="arm the graceful-degradation ladder")
    ap.add_argument("--offload", action="store_true",
                    help="DRAM KV tier: evicted prefix blocks demote to "
                         "host memory and restore (or router-prefetch) "
                         "instead of recomputing")
    ap.add_argument("--host-cache-mb", type=int, default=256,
                    help="DRAM tier capacity per instance, MiB")
    ap.add_argument("--cache-tokens", type=int, default=4096,
                    help="device prefix-KV cache capacity per instance, "
                         "tokens")
    ap.add_argument("--drain-timeout", type=float, default=30.0,
                    help="max seconds to drain on completion or SIGTERM")
    chaos = ap.add_argument_group(
        "chaos", "seeded fault injection (any rate > 0 wraps the pool)")
    chaos.add_argument("--chaos-seed", type=int, default=0)
    chaos.add_argument("--chaos-step-error", type=float, default=0.0,
                       help="P(step crashes after the forward, results lost)")
    chaos.add_argument("--chaos-hang", type=float, default=0.0,
                       help="P(step hangs past the watchdog deadline)")
    chaos.add_argument("--chaos-hang-seconds", type=float, default=1.0)
    chaos.add_argument("--chaos-straggler", type=float, default=0.0,
                       help="P(step dawdles below the watchdog deadline)")
    chaos.add_argument("--chaos-straggler-seconds", type=float, default=0.1)
    chaos.add_argument("--chaos-nan", type=float, default=0.0,
                       help="P(step results corrupted to non-finite scores)")
    chaos.add_argument("--chaos-submit-error", type=float, default=0.0,
                       help="P(submit raises transiently)")
    chaos.add_argument("--chaos-max-faults", type=int, default=None,
                       help="total fault budget across the run")
    chaos.add_argument("--chaos-kill", type=float, default=0.0,
                       help="process mode: P(SIGKILL the worker mid-batch)")
    chaos.add_argument("--chaos-freeze", type=float, default=0.0,
                       help="process mode: P(SIGSTOP-freeze the worker)")
    chaos.add_argument("--chaos-freeze-seconds", type=float, default=1.0)
    chaos.add_argument("--chaos-rpc-drop", type=float, default=0.0,
                       help="process mode: P(drop a submit/step response)")
    chaos.add_argument("--chaos-rpc-delay", type=float, default=0.0,
                       help="process mode: P(delay a submit/step response)")
    chaos.add_argument("--chaos-rpc-delay-seconds", type=float,
                       default=0.05)
    args = ap.parse_args()
    chaos_cfg = None
    if any(r > 0 for r in (args.chaos_step_error, args.chaos_hang,
                           args.chaos_straggler, args.chaos_nan,
                           args.chaos_submit_error, args.chaos_kill,
                           args.chaos_freeze, args.chaos_rpc_drop,
                           args.chaos_rpc_delay)):
        chaos_cfg = ChaosConfig(
            seed=args.chaos_seed, step_error=args.chaos_step_error,
            hang=args.chaos_hang, hang_seconds=args.chaos_hang_seconds,
            straggler=args.chaos_straggler,
            straggler_seconds=args.chaos_straggler_seconds,
            nan_score=args.chaos_nan,
            submit_error=args.chaos_submit_error,
            max_faults=args.chaos_max_faults,
            kill=args.chaos_kill, freeze=args.chaos_freeze,
            freeze_seconds=args.chaos_freeze_seconds,
            rpc_drop=args.chaos_rpc_drop, rpc_delay=args.chaos_rpc_delay,
            rpc_delay_seconds=args.chaos_rpc_delay_seconds)
    out = serve_trace(args.arch, args.trace, qps=args.qps,
                      n_instances=args.instances, workers=args.workers,
                      policy=args.policy,
                      lam=args.lam, scale_tokens=args.scale_tokens,
                      max_requests=args.max_requests, router=args.router,
                      deadline=args.deadline,
                      admission=not args.no_admission, profile=args.profile,
                      metrics_port=args.metrics_port,
                      retry_budget=args.retry_budget,
                      watchdog=not args.no_watchdog,
                      watchdog_factor=args.watchdog_factor,
                      watchdog_min_deadline=args.watchdog_min_deadline,
                      brownout=args.brownout, chaos=chaos_cfg,
                      drain_timeout=args.drain_timeout,
                      trace_dump=args.trace_dump,
                      offload=args.offload,
                      host_cache_mb=args.host_cache_mb,
                      cache_tokens=args.cache_tokens)
    for k, v in out.items():
        if k == "metrics":
            if args.dump_metrics:
                print("--- metrics ---")
                print(v)
        elif k not in ("per_instance", "jct_fit"):
            print(f"{k}: {v}")
    for n, fit in sorted((out.get("jct_fit") or {}).items()):
        if fit:
            print(f"jct_fit[{n}]: a={fit['a']:.3g} b={fit['b']:.3g} "
                  f"r={fit['pearson_r']:.3f} "
                  f"resid_p50={fit['residual_p50']:.4f} "
                  f"resid_p95={fit['residual_p95']:.4f} "
                  f"refits={fit['refits']}+{fit['drift_refits']}")


if __name__ == "__main__":
    main()
