"""Serving driver: async PrefillOnly instance pool + trace replay.

The paper's deployment shape (§7.1): N single-model-copy engine instances
behind a router, each running Algorithm-1 scheduling with continuous JCT
calibration and suffix-KV discard. Since PR 2 the driver is ASYNC: an
``AsyncServer`` runs one worker thread per engine, the submitting thread
replays the trace open-loop in real time (sleep to each arrival, submit,
move on — no polling step loop), and every request resolves through a
``Future`` to either a scored result or a typed ``Rejected``.

Routing is pluggable (``--router user_hash`` is the paper's rendezvous user
hash; ``--router least_backlog`` routes on predicted-JCT backlog with
cache-affinity tie-break — exploiting the JCT predictability that is the
paper's whole point). Admission control (MIL + deadline feasibility) and
in-queue deadline shedding are on by default when ``--deadline`` is given.

On this CPU box the instances run reduced configs with REAL forwards; on TPU
each instance is one mesh tile (see DESIGN.md §5 instance sizing).
"""
from __future__ import annotations

import argparse
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.core.kv_policy import MemoryModel
from repro.data.workloads import get_trace
from repro.models.model import build
from repro.runtime.fault_tolerance import InstancePool
from repro.runtime.sharding import materialize
from repro.serving import (AdmissionController, AsyncServer, Rejected,
                           get_router)


def make_pool(arch: str, n_instances: int = 2, *, reduced: bool = True,
              policy: str = "srjf_calibrated", lam: float = 0.05,
              cache_tokens: int = 4096, seed: int = 0,
              profile: bool = False,
              profile_lengths=(32, 64, 128)) -> InstancePool:
    """Build N engine instances over ONE set of materialized weights.

    ``profile=True`` runs the paper's profile step per instance: fits the
    JCT linear proxy on measured forwards (so routing/admission predictions
    start calibrated, not from the generic default) and auto-tunes the
    prepacking budget from the fitted curve.
    """
    cfg = get_config(arch)
    if reduced:
        cfg = reduce_config(cfg, hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(seed), api.defs(), jnp.float32)

    def make_engine(name: str) -> PrefillOnlyEngine:
        eng = PrefillOnlyEngine(cfg, params, EngineConfig(
            policy=policy, lam=lam, cache_capacity_tokens=cache_tokens))
        if profile:
            eng.profile(profile_lengths)
        return eng

    pool = InstancePool(make_engine)
    pool.scale_to([f"inst{i}" for i in range(n_instances)])
    return pool


def start_metrics_server(registry, port: int = 0,
                         host: str = "127.0.0.1") -> ThreadingHTTPServer:
    """Plain-HTTP Prometheus scrape endpoint over a ``MetricsRegistry``.

    GET /metrics returns ``registry.render_prometheus()``; anything else is
    404. Runs in a daemon thread; ``port=0`` binds an ephemeral port (read
    it back from ``server.server_address``). Call ``server.shutdown()`` to
    stop.
    """

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):                          # noqa: N802 (stdlib API)
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_error(404)
                return
            body = registry.render_prometheus().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):                 # keep stdout clean
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    threading.Thread(target=server.serve_forever, daemon=True,
                     name="metrics-http").start()
    return server


def serve_trace(arch: str = "qwen1.5-0.5b",
                trace_name: str = "post_recommendation",
                qps: float = 5.0, n_instances: int = 2,
                scale_tokens: float = 0.02, policy: str = "srjf_calibrated",
                lam: float = 0.05, seed: int = 0,
                max_requests: Optional[int] = None,
                router: str = "least_backlog",
                deadline: Optional[float] = None,
                admission: bool = True,
                max_input_tokens: Optional[int] = None,
                profile: bool = False,
                pool: Optional[InstancePool] = None,
                trace_kw: Optional[Dict] = None,
                metrics_port: Optional[int] = None) -> Dict:
    """Replay a paper workload through the AsyncServer. Returns latency
    stats over SERVED requests plus rejection counts and a telemetry dump.

    ``deadline`` is seconds after each request's arrival; with
    ``admission=True`` doomed requests are rejected/shed instead of blowing
    out the tail. ``pool=None`` builds a fresh pool (pass one to reuse
    warmed engines across runs). ``metrics_port`` starts a plain-HTTP
    Prometheus scrape endpoint (GET /metrics) for the duration of the
    replay; 0 picks an ephemeral port.
    """
    if pool is None:
        pool = make_pool(arch, n_instances, policy=policy, lam=lam,
                         seed=seed, profile=profile)
    ctrl = None
    if admission:
        # MIL from the engines' own model config unless given explicitly —
        # the same closed form the profile run sizes the KV budget with
        eng_cfg = next(iter(pool.engines.values())).cfg
        ctrl = AdmissionController(max_input_tokens=max_input_tokens,
                                   memory_model=MemoryModel(eng_cfg))
    server = AsyncServer(pool, router=get_router(router), admission=ctrl)
    server.start()
    exporter = None
    if metrics_port is not None:
        exporter = start_metrics_server(server.metrics, metrics_port)
        print(f"metrics: http://{exporter.server_address[0]}:"
              f"{exporter.server_address[1]}/metrics")
    try:
        return _replay(server, arch, trace_name, qps, scale_tokens, seed,
                       max_requests, deadline, pool, trace_kw)
    finally:
        # shutdown() stops serve_forever; server_close() releases the bound
        # socket — without it a second serve_trace on the same port (the
        # documented warmed-pool reuse pattern) dies with EADDRINUSE
        if exporter is not None:
            exporter.shutdown()
            exporter.server_close()


def _replay(server, arch, trace_name, qps, scale_tokens, seed, max_requests,
            deadline, pool, trace_kw) -> Dict:
    trace = get_trace(trace_name, qps, scale_tokens=scale_tokens,
                      materialize_tokens=True,
                      vocab=min(512, get_config(arch).vocab_size), seed=seed,
                      **(trace_kw or {}))
    requests = trace.requests[:max_requests] if max_requests else trace.requests
    yes_no = (5, 9)

    t0 = time.perf_counter()
    futures = []
    for r in requests:                      # open loop: real-time arrivals
        delay = t0 + r.arrival - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        futures.append(server.submit(
            r.user_id, r.tokens, allowed_tokens=yes_no,
            deadline=(t0 + r.arrival + deadline) if deadline else None))
    server.drain()
    wall = time.perf_counter() - t0
    server.shutdown()

    outcomes = [f.result() for f in futures]
    served = [o for o in outcomes if not isinstance(o, Rejected)]
    rejected = [o for o in outcomes if isinstance(o, Rejected)]
    # no fabricated samples: a fully-shed run reports NaN latency, not a
    # vacuous 0.0 that would read as a perfect tail
    lats = np.array([o["latency"] for o in served]) if served \
        else np.array([np.nan])
    hit = sum(o["n_cached"] for o in served)
    tot = sum(o["n_input"] for o in served)
    reasons: Dict[str, int] = {}
    for o in rejected:
        reasons[o.reason] = reasons.get(o.reason, 0) + 1
    return {
        "requests": len(outcomes),
        "served": len(served),
        "rejected": len(rejected),
        "reject_reasons": reasons,
        "wall_seconds": wall,
        "throughput_rps": len(served) / wall,
        "mean_latency": float(lats.mean()),
        "p50_latency": float(np.percentile(lats, 50)),
        "p99_latency": float(np.percentile(lats, 99)),
        "token_hit_rate": hit / max(tot, 1),
        "metrics": server.metrics.render(),
        "per_instance": {n: e.stats() for n, e in pool.engines.items()},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--trace", default="post_recommendation")
    ap.add_argument("--qps", type=float, default=5.0)
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--policy", default="srjf_calibrated",
                    choices=["fifo", "srjf", "srjf_calibrated"])
    ap.add_argument("--router", default="least_backlog",
                    choices=["user_hash", "least_backlog"])
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline, seconds after arrival")
    ap.add_argument("--no-admission", action="store_true")
    ap.add_argument("--profile", action="store_true",
                    help="run the JCT profile fit per instance first")
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--scale-tokens", type=float, default=0.02)
    ap.add_argument("--max-requests", type=int, default=60)
    ap.add_argument("--dump-metrics", action="store_true")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text metrics on this port "
                         "(GET /metrics) during the replay; 0 = ephemeral")
    args = ap.parse_args()
    out = serve_trace(args.arch, args.trace, qps=args.qps,
                      n_instances=args.instances, policy=args.policy,
                      lam=args.lam, scale_tokens=args.scale_tokens,
                      max_requests=args.max_requests, router=args.router,
                      deadline=args.deadline,
                      admission=not args.no_admission, profile=args.profile,
                      metrics_port=args.metrics_port)
    for k, v in out.items():
        if k == "metrics":
            if args.dump_metrics:
                print("--- metrics ---")
                print(v)
        elif k != "per_instance":
            print(f"{k}: {v}")


if __name__ == "__main__":
    main()
