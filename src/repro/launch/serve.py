"""Serving driver: PrefillOnly instance pool + user-id routing + trace replay.

This is the paper's deployment shape (§7.1 "Routing"): N single-model-copy
engine instances, requests routed by user id (rendezvous hashing here, which
additionally gives the elastic minimal-remap property), each instance running
Algorithm-1 scheduling with continuous JCT calibration and suffix-KV discard.

On this CPU box the instances run reduced configs with REAL forwards; on TPU
each instance is one mesh tile (see DESIGN.md §5 instance sizing).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.core.kv_policy import MemoryModel
from repro.data.workloads import get_trace
from repro.models.model import build
from repro.runtime.fault_tolerance import InstancePool
from repro.runtime.sharding import materialize


def make_pool(arch: str, n_instances: int = 2, *, reduced: bool = True,
              policy: str = "srjf_calibrated", lam: float = 0.05,
              cache_tokens: int = 4096, seed: int = 0) -> InstancePool:
    cfg = get_config(arch)
    if reduced:
        cfg = reduce_config(cfg, hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(seed), api.defs(), jnp.float32)

    def make_engine(name: str) -> PrefillOnlyEngine:
        return PrefillOnlyEngine(cfg, params, EngineConfig(
            policy=policy, lam=lam, cache_capacity_tokens=cache_tokens))

    pool = InstancePool(make_engine)
    pool.scale_to([f"inst{i}" for i in range(n_instances)])
    return pool


def serve_trace(arch: str = "qwen1.5-0.5b", trace_name: str = "post_recommendation",
                qps: float = 5.0, n_instances: int = 2,
                scale_tokens: float = 0.02, policy: str = "srjf_calibrated",
                lam: float = 0.05, seed: int = 0,
                max_requests: Optional[int] = None) -> Dict:
    """Replay a paper workload through real engines. Returns latency stats."""
    pool = make_pool(arch, n_instances, policy=policy, lam=lam, seed=seed)
    trace = get_trace(trace_name, qps, scale_tokens=scale_tokens,
                      materialize_tokens=True,
                      vocab=min(512, get_config(arch).vocab_size), seed=seed)
    requests = trace.requests[:max_requests] if max_requests else trace.requests
    yes_no = (5, 9)

    t0 = time.perf_counter()
    results = []
    submitted = 0
    i = 0
    while i < len(requests) or any(
            e.queue for e in pool.engines.values()):
        now = time.perf_counter() - t0
        while i < len(requests) and requests[i].arrival <= now:
            r = requests[i]
            pool.submit(r.user_id, r.tokens, allowed_tokens=yes_no)
            submitted += 1
            i += 1
        if pool.step_all() == 0 and i < len(requests):
            time.sleep(min(0.005, max(0.0, requests[i].arrival - now)))
    wall = time.perf_counter() - t0

    for eng in pool.engines.values():
        results.extend(eng.results.values())
    lats = np.array([r["latency"] for r in results])
    hit = sum(r["n_cached"] for r in results)
    tot = sum(r["n_input"] for r in results)
    return {
        "requests": len(results),
        "wall_seconds": wall,
        "throughput_rps": len(results) / wall,
        "mean_latency": float(lats.mean()),
        "p99_latency": float(np.percentile(lats, 99)),
        "token_hit_rate": hit / max(tot, 1),
        "per_instance": {n: e.stats() for n, e in pool.engines.items()},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--trace", default="post_recommendation")
    ap.add_argument("--qps", type=float, default=5.0)
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--policy", default="srjf_calibrated",
                    choices=["fifo", "srjf", "srjf_calibrated"])
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--scale-tokens", type=float, default=0.02)
    ap.add_argument("--max-requests", type=int, default=60)
    args = ap.parse_args()
    out = serve_trace(args.arch, args.trace, qps=args.qps,
                      n_instances=args.instances, policy=args.policy,
                      lam=args.lam, scale_tokens=args.scale_tokens,
                      max_requests=args.max_requests)
    for k, v in out.items():
        if k != "per_instance":
            print(f"{k}: {v}")


if __name__ == "__main__":
    main()
