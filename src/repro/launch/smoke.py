"""Serve-smoke validator: boot a tiny pool, scrape /metrics + /trace, and
check the observability plane end to end.

CI runs this after the plain serve soak. It validates, with hard exits:

  * the Prometheus payload PARSES (strict line-format check: HELP/TYPE
    comments, sample syntax, cumulative ``le`` buckets ending ``+Inf``,
    ``_count`` == the ``+Inf`` bucket) and contains the JCT-calibration
    series (``jct_coef_a`` gauge, ``jct_residual_seconds`` histogram);
  * the /trace JSONL dump contains at least one COMPLETE submit→deliver
    timeline (submit, route, enqueue, finish events; queue + execute
    spans) for a delivered request;
  * /trace.chrome.json is valid JSON whose phase spans nest inside their
    request's umbrella span (what Perfetto renders as containment).

``--jsonl FILE`` instead validates an existing ``--trace-dump`` file pair
written by a prior ``repro.launch.serve`` run (used by CI to check the CLI
path produced a loadable dump).

The pool is deliberately solo-packing with same-length requests: after the
first (compile) step every step is warm, so the JCT monitor has observed
samples and the residual histograms are non-empty by scrape time.
"""
from __future__ import annotations

import argparse
import json
import math
import re
import sys
import urllib.request
from pathlib import Path
from typing import Dict, List

_SAMPLE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>[^}]*)\})? '
    r'(?P<value>[^ ]+)$')
_LABEL = re.compile(r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"$')


def parse_prometheus(text: str) -> Dict[str, List[Dict]]:
    """Strict parse of the text exposition format; raises ValueError on any
    malformed line. Returns {metric_name: [{labels, value}, ...]} keyed by
    the SAMPLE name (``foo_bucket`` etc., not the family name)."""
    series: Dict[str, List[Dict]] = {}
    typed = set()
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            parts = line.split(" ", 3)
            if len(parts) < 4 or not parts[2]:
                raise ValueError(f"line {ln}: malformed comment: {line!r}")
            if parts[1] == "TYPE":
                if parts[3] not in ("counter", "gauge", "histogram",
                                    "summary", "untyped"):
                    raise ValueError(f"line {ln}: bad TYPE {parts[3]!r}")
                typed.add(parts[2])
            continue
        if line.startswith("#"):
            raise ValueError(f"line {ln}: unknown comment: {line!r}")
        m = _SAMPLE.match(line)
        if m is None:
            raise ValueError(f"line {ln}: malformed sample: {line!r}")
        labels = {}
        if m.group("labels"):
            for pair in re.split(r',(?=[a-zA-Z_])', m.group("labels")):
                if not _LABEL.match(pair):
                    raise ValueError(f"line {ln}: bad label {pair!r}")
                k, v = pair.split("=", 1)
                labels[k] = v[1:-1]
        try:
            value = float(m.group("value"))
        except ValueError:
            raise ValueError(f"line {ln}: bad value {m.group('value')!r}")
        family = re.sub(r'_(bucket|sum|count)$', '', m.group("name"))
        if family not in typed and m.group("name") not in typed:
            raise ValueError(f"line {ln}: sample {m.group('name')!r} has "
                             f"no preceding # TYPE")
        series.setdefault(m.group("name"), []).append(
            {"labels": labels, "value": value})
    return series


def validate_histograms(series: Dict[str, List[Dict]]) -> List[str]:
    """Cumulative-bucket + _sum/_count consistency across every histogram
    family in a parsed exposition. Returns the family names checked."""
    fams = sorted({n[:-len("_bucket")] for n in series if
                   n.endswith("_bucket")})
    for fam in fams:
        by_inst: Dict[str, List[Dict]] = {}
        for s in series[fam + "_bucket"]:
            by_inst.setdefault(s["labels"].get("instance", ""),
                               []).append(s)
        for inst, buckets in by_inst.items():
            les = [b["labels"].get("le") for b in buckets]
            if "+Inf" not in les:
                raise ValueError(f"{fam}{{{inst}}}: no +Inf bucket")
            if les[-1] != "+Inf":
                raise ValueError(f"{fam}{{{inst}}}: +Inf not last")
            vals = [b["value"] for b in buckets]
            if vals != sorted(vals):
                raise ValueError(f"{fam}{{{inst}}}: buckets not cumulative")
            count = [s["value"] for s in series.get(fam + "_count", [])
                     if s["labels"].get("instance", "") == inst]
            if not count or count[0] != vals[-1]:
                raise ValueError(f"{fam}{{{inst}}}: _count != +Inf bucket")
            ssum = [s["value"] for s in series.get(fam + "_sum", [])
                    if s["labels"].get("instance", "") == inst]
            if not ssum or not math.isfinite(ssum[0]):
                raise ValueError(f"{fam}{{{inst}}}: bad _sum")
    return fams


def validate_trace_jsonl(text: str) -> Dict:
    """Require one complete submit→deliver timeline; returns that record."""
    requests = []
    batches = 0
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        row = json.loads(line)
        if row.get("type") == "request":
            requests.append(row)
        elif row.get("type") == "batch":
            batches += 1
    delivered = [r for r in requests if r.get("outcome") == "delivered"]
    if not delivered:
        raise ValueError(f"no delivered request in trace dump "
                         f"({len(requests)} requests, {batches} batches)")
    for r in delivered:
        events = [e["name"] for e in r["events"]]
        spans = {s["name"] for s in r["spans"]}
        missing = {"submit", "route", "enqueue", "finish"} - set(events)
        if not missing and {"queue", "execute"} <= spans:
            ts = [e["t"] for e in r["events"]]
            if ts != sorted(ts):
                raise ValueError(f"req {r['req_id']}: events out of order")
            for s in r["spans"]:
                if s["t1"] < s["t0"]:
                    raise ValueError(f"req {r['req_id']}: negative span "
                                     f"{s['name']}")
            return r
    raise ValueError(
        "no delivered request has a complete timeline; first delivered "
        f"has events={delivered[0]['events']} spans={delivered[0]['spans']}")


def validate_chrome(obj: Dict) -> int:
    """Perfetto-loadability proxy: the JSON parsed, every event carries the
    required keys, and each phase span nests inside a request umbrella span
    on the same (pid, tid). Returns the number of nested phase spans."""
    events = obj["traceEvents"]
    umbrellas = [e for e in events if e["ph"] == "X"
                 and e["name"].startswith("request ")]
    if not umbrellas:
        raise ValueError("no request umbrella spans")
    nested = 0
    for e in events:
        if e["ph"] not in ("X", "i", "M"):
            raise ValueError(f"unknown phase {e['ph']!r}")
        if e["ph"] == "X" and (e["ts"] < 0 or e["dur"] <= 0):
            raise ValueError(f"bad X event timing: {e}")
        if (e["ph"] == "X" and not e["name"].startswith("request ")
                and not e["name"].startswith("step ")):
            host = [u for u in umbrellas
                    if u["pid"] == e["pid"] and u["tid"] == e["tid"]
                    and u["ts"] <= e["ts"] + 1e-6
                    and e["ts"] + e["dur"] <= u["ts"] + u["dur"] + 1e-3]
            if not host:
                raise ValueError(f"span {e['name']!r} (tid {e['tid']}) not "
                                 f"nested in any request span")
            nested += 1
    return nested


def _fetch(url: str) -> str:
    with urllib.request.urlopen(url, timeout=10) as r:
        return r.read().decode()


def run_live_smoke(n_requests: int = 12, arch: str = "qwen1.5-0.5b",
                   workers: int = 0) -> None:
    """In-process end-to-end: pool -> AsyncServer(+tracer) -> HTTP scrape.

    ``workers=N`` runs the SAME strict validation against the process-mode
    plane: N supervised engine worker processes behind the RPC boundary.
    The scrape then exercises the full telemetry bridge — worker-side JCT
    series ride the heartbeat ``dump_state`` merge, spans/batches are
    replayed off step responses — and every validator (prometheus line
    discipline, complete submit→deliver timelines, chrome nesting) must
    hold with the engines in separate processes.
    """
    import numpy as np

    from repro.configs import get_config, reduce_config
    from repro.launch.serve import start_metrics_server
    from repro.serving import AsyncServer, SpanTracer

    cfg = reduce_config(get_config(arch), hybrid_chunk=0)
    sup = None
    # tier-exercising engine shape: the device cache holds only 4 blocks
    # (64 tokens — two 40-token requests' kept KV), so the first submission
    # round FORCES evictions into the DRAM tier; re-submitting the same
    # token lists then restores/prefetches from host. offload_host_bw is
    # pinned huge because worth_restoring prices the TARGET chip's
    # recompute rate, which this CPU box can't approach.
    tier_ecfg = {"max_pack_requests": 1, "cache_capacity_tokens": 64,
                 "offload": True, "offload_host_bw": 1e18,
                 "prefix_bucket_blocks": 1}
    if workers:
        from repro.serving import make_process_pool, wire_supervisor
        # solo packing + same-length requests below: after the first
        # (compile) step every step is warm -> JCT monitor has samples
        specs = {f"inst{i}": {"kind": "engine", "arch": arch,
                              "reduced": True, "seed": 0,
                              "ecfg": dict(tier_ecfg)}
                 for i in range(workers)}
        pool, sup = make_process_pool(
            specs, lease=30.0, heartbeat_interval=0.4, miss_budget=12,
            spawn_timeout=600.0, step_timeout=300.0, drain_grace=30.0)
    else:
        import jax
        import jax.numpy as jnp

        from repro.core.engine import EngineConfig, PrefillOnlyEngine
        from repro.models.model import build
        from repro.runtime.fault_tolerance import InstancePool
        from repro.runtime.sharding import materialize

        api = build(cfg)
        params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)

        def make_engine(name: str) -> PrefillOnlyEngine:
            return PrefillOnlyEngine(cfg, params, EngineConfig(**tier_ecfg))

        pool = InstancePool(make_engine)
        pool.scale_to(["inst0"])
    tracer = SpanTracer()
    server = AsyncServer(pool, tracer=tracer).start()
    if sup is not None:
        import os as _os
        wire_supervisor(sup, server)
        sup.start()
        pids = {h.pid for h in sup.handles.values()}
        assert _os.getpid() not in pids, \
            f"worker pids overlap the frontend: {pids}"
        print(f"process mode: {len(pids)} worker processes "
              f"{sorted(pids)} (frontend pid {_os.getpid()})")
    exporter = start_metrics_server(server.metrics, 0, tracer=tracer)
    host, port = exporter.server_address
    base = f"http://{host}:{port}"
    try:
        rng = np.random.default_rng(0)
        token_lists = [rng.integers(0, cfg.vocab_size, 40).tolist()
                       for _ in range(n_requests)]
        # round 1: distinct 40-token requests overflow the 4-block device
        # cache -> evictions demote kept KV into the host tier
        futs = [server.submit(f"u{i}", toks, allowed_tokens=(5, 9))
                for i, toks in enumerate(token_lists)]
        assert server.drain(timeout=600.0 if workers else 120.0), \
            "drain timed out"
        # round 2: the SAME token lists — their prefixes now live host-side,
        # so submits trigger router-time prefetch and executes restore
        futs += [server.submit(f"u{i}", toks, allowed_tokens=(5, 9))
                 for i, toks in enumerate(token_lists)]
        assert server.drain(timeout=600.0 if workers else 120.0), \
            "drain timed out (round 2)"
        results = [f.result() for f in futs]
        delivered = [r for r in results if isinstance(r, dict)]
        assert delivered, f"nothing delivered: {results}"
        if sup is not None:
            # worker-side JCT series arrive on the NEXT heartbeat after the
            # final warm step; wait out one beat cycle before scraping
            import time as _time
            _time.sleep(3 * sup.heartbeat_interval)

        prom = _fetch(base + "/metrics")
        series = parse_prometheus(prom)
        fams = validate_histograms(series)
        for needed in ("prefillonly_jct_coef_a", "prefillonly_jct_coef_b",
                       "prefillonly_jct_pearson_r"):
            assert needed in series, f"missing gauge {needed}"
        assert "prefillonly_jct_residual_seconds" in fams, \
            f"jct_residual_seconds histogram absent (families: {fams})"
        print(f"metrics ok: {len(series)} series, "
              f"{len(fams)} histogram families")

        # hierarchical KV memory: the 4-block device cache must have
        # demoted blocks host-side in round 1, and round 2 must have
        # brought some back (execute-path restore and/or router prefetch)
        def _total(name: str) -> float:
            return sum(s["value"] for s in series.get(name, []))
        offloaded = _total("prefillonly_kv_offload_blocks")
        restored = _total("prefillonly_kv_restore_blocks")
        prefetched = _total("prefillonly_kv_prefetch_blocks")
        assert offloaded > 0, "no KV blocks demoted to the host tier"
        assert restored + prefetched > 0, \
            "no KV blocks came back from the host tier"
        assert "prefillonly_host_kv_used_bytes" in series, \
            "host tier occupancy gauge absent"
        triggers = _total("prefillonly_prefetches_triggered")
        print(f"offload tier ok: {offloaded:.0f} blocks demoted, "
              f"{restored:.0f} restored + {prefetched:.0f} prefetched "
              f"({triggers:.0f} router-time prefetch triggers)")

        timeline = validate_trace_jsonl(_fetch(base + "/trace"))
        print(f"trace ok: complete submit→deliver timeline for req "
              f"{timeline['req_id']} ({len(timeline['events'])} events, "
              f"{len(timeline['spans'])} spans)")

        nested = validate_chrome(
            json.loads(_fetch(base + "/trace.chrome.json")))
        print(f"chrome trace ok: {nested} phase spans nested")
    finally:
        server.shutdown(drain=False)
        if sup is not None:
            sup.stop(graceful=True)
        exporter.shutdown()
        exporter.server_close()


def validate_dump_files(jsonl_path: str) -> None:
    p = Path(jsonl_path)
    timeline = validate_trace_jsonl(p.read_text())
    print(f"trace dump ok: complete timeline for req "
          f"{timeline['req_id']}")
    cp = p.with_suffix(".chrome.json")
    nested = validate_chrome(json.loads(cp.read_text()))
    print(f"chrome dump ok ({cp}): {nested} phase spans nested")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--workers", type=int, default=0, metavar="N",
                    help="process mode: validate against N supervised "
                         "engine worker processes (0 = in-process pool)")
    ap.add_argument("--jsonl", default=None, metavar="FILE",
                    help="validate an existing --trace-dump file pair "
                         "instead of running the live smoke")
    args = ap.parse_args()
    try:
        if args.jsonl:
            validate_dump_files(args.jsonl)
        else:
            run_live_smoke(args.requests, args.arch, workers=args.workers)
    except (AssertionError, ValueError, KeyError) as e:
        print(f"SMOKE FAILED: {e}", file=sys.stderr)
        sys.exit(1)
    print("serve smoke: OK")


if __name__ == "__main__":
    main()
