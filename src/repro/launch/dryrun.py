import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import (ASSIGNED, SHAPES, cell_is_runnable, get_config,
                           shape as get_shape)
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step, lower_step, rules_for
from repro.runtime.hlo_analysis import parse_hlo
from repro.runtime.hw import TPU_V5E

RESULTS_DIR = Path(os.environ.get("DRYRUN_DIR", "results/dryrun"))


def model_flops(cfg: ModelConfig, shp: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = cfg.active_param_count()
    if shp.kind == "train":
        return 6.0 * n * shp.tokens
    if shp.kind == "prefill":
        return 2.0 * n * shp.tokens
    return 2.0 * n * shp.global_batch          # decode: one token per row


def _suggestion(dominant: str, cell: dict) -> str:
    if dominant == "compute":
        if cell["useful_ratio"] < 0.5:
            return ("compute-bound with <50% useful FLOPs: cut masked "
                    "attention waste (tile-skip / smaller kv blocks) or remat")
        return "compute-bound near peak: only lower-precision or fewer FLOPs help"
    if dominant == "memory":
        return ("HBM-bound: fuse elementwise chains, keep intermediates "
                "chunk-resident (hybrid chunk down), widen arithmetic intensity")
    return ("collective-bound: reshard to cut all-gathers (EP vs TP), "
            "overlap collectives with compute, or compress payloads")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rule_overrides=None, tag: str = "baseline",
             preset: str = "", fp8: bool = False,
             grad_compression: str = "none", packed: bool = False,
             no_remat: bool = False) -> dict:
    import dataclasses
    from repro.launch.steps import PRESETS
    from repro.optim import adamw
    cfg = get_config(arch)
    if packed:
        cfg = dataclasses.replace(cfg, packed_attention=True)
    if no_remat:
        cfg = dataclasses.replace(cfg, remat=False)
    if fp8:
        # the paper's quantized serving setup (FP8 weights, bf16 compute)
        cfg = dataclasses.replace(cfg, param_dtype="float8_e4m3fn")
    shp = get_shape(shape_name)
    if preset:
        merged = dict(PRESETS[preset])
        merged.update(rule_overrides or {})
        rule_overrides = merged
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag}

    ok, reason = cell_is_runnable(cfg, shp)
    if not ok:
        cell.update({"status": "skip", "reason": reason})
        return cell

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rules = rules_for(cfg, shp, mesh, overrides=rule_overrides)
    opt_cfg = adamw.AdamWConfig(grad_compression=grad_compression)
    bundle = build_step(cfg, shp, mesh, rules, opt_cfg)
    with mesh:
        lowered = lower_step(bundle, mesh, rules)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        print(mem)                           # proves it fits (per spec)
        cost = compiled.cost_analysis()
        print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})
    hlo = parse_hlo(compiled.as_text(), total_devices=n_dev)

    chip = TPU_V5E
    per_dev_flops = hlo.flops
    # Memory term from compiled memory stats, not the HLO text: XLA-CPU
    # materializes mask/scatter loops that fuse away on TPU, so text-derived
    # traffic overestimates wildly (kept as a diagnostic in hlo.hbm_bytes).
    # argument+output = one sweep of weights/inputs/results; 2x temp = each
    # live intermediate written then read once.
    per_dev_hbm = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                   - mem.alias_size_in_bytes + 2.0 * mem.temp_size_in_bytes)
    per_dev_coll = hlo.collective_bytes
    compute_s = per_dev_flops / chip.peak_flops_bf16
    memory_s = per_dev_hbm / chip.hbm_bw
    collective_s = per_dev_coll / chip.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shp)
    total_hlo_flops = per_dev_flops * n_dev
    step_time = max(terms.values())
    ideal = mf / (n_dev * chip.peak_flops_bf16)

    cell.update({
        "status": "ok",
        "devices": n_dev,
        "compile_seconds": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": (mem.argument_size_in_bytes
                                + mem.temp_size_in_bytes
                                + mem.output_size_in_bytes
                                - mem.alias_size_in_bytes),
            "hbm_per_device": chip.hbm_bytes,
            "fits": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
                    < chip.hbm_bytes,
        },
        "hlo": hlo.asdict(),
        "xla_cost_analysis": {"flops_once": cost.get("flops", 0.0),
                              "bytes_once": cost.get("bytes accessed", 0.0)},
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "model_flops": mf,
            "hlo_flops_total": total_hlo_flops,
            "useful_ratio": mf / total_hlo_flops if total_hlo_flops else 0.0,
            "roofline_fraction": ideal / step_time if step_time else 0.0,
            "step_time_bound_s": step_time,
        },
        "meta": bundle.meta,
    })
    cell["roofline"]["suggestion"] = _suggestion(dominant, cell["roofline"])
    return cell


def cell_path(arch, shape_name, mesh_name, tag):
    return RESULTS_DIR / f"{arch}__{shape_name}__{mesh_name}__{tag}.json"


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (assigned 10)")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--set", action="append", default=[],
                    help="rule override logical=mesh_axis (hillclimbing); "
                         "comma-separate for axis tuples")
    ap.add_argument("--preset", default="",
                    help="named rule preset from launch.steps.PRESETS")
    ap.add_argument("--fp8", action="store_true",
                    help="FP8 serving weights (paper's quantized setup)")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--packed", action="store_true",
                    help="exact-causal packed attention schedule")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    archs = sorted(ASSIGNED) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    overrides = {}
    for kv in args.set:
        k, _, v = kv.partition("=")
        if v in ("none", "None", ""):
            overrides[k] = None
        elif "," in v:
            overrides[k] = tuple(v.split(","))
        else:
            overrides[k] = v

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                out = cell_path(arch, shape_name, mesh_name, args.tag)
                if out.exists() and not args.force:
                    print(f"[cached] {out.name}")
                    continue
                print(f"=== {arch} x {shape_name} x {mesh_name} ===",
                      flush=True)
                try:
                    cell = run_cell(arch, shape_name, multi,
                                    rule_overrides=overrides or None,
                                    tag=args.tag, preset=args.preset,
                                    fp8=args.fp8,
                                    grad_compression=args.grad_compression,
                                    packed=args.packed,
                                    no_remat=args.no_remat)
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    cell = {"arch": arch, "shape": shape_name,
                            "mesh": mesh_name, "tag": args.tag,
                            "status": "error", "error": repr(e)}
                    failures += 1
                out.write_text(json.dumps(cell, indent=2))
                status = cell["status"]
                extra = ""
                if status == "ok":
                    r = cell["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" frac={r['roofline_fraction']:.3f}"
                             f" fits={cell['memory']['fits']}"
                             f" ({cell['compile_seconds']}s)")
                print(f"[{status}] {out.name}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
