"""Serving telemetry: counters, gauges, fixed-bucket latency histograms.

Metrics carry one optional ``instance`` label so the registry can report both
per-instance and globally aggregated views (global = sum of counters, merge
of histogram buckets — exact, since every histogram of a given name shares
one fixed bucket table). Percentiles come from linear interpolation inside
the bucket that crosses the target rank, clamped to the observed min/max so
tiny samples don't report a bucket edge nobody hit.

Everything is thread-safe: the serving worker threads, the submitting
thread(s), and a stats reader may all touch the registry concurrently.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

# 1e-4s .. ~178s upper bounds, geometric x ~1.78 (10^(1/4)) — 26 buckets
# + overflow. Wide enough for CPU-scale JCTs and TPU-scale latencies alike.
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    1e-4 * 10 ** (i / 4) for i in range(26))


class Counter:
    def __init__(self):
        self._v = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    def load(self, v: float) -> None:
        """Overwrite with an authoritative remote value (telemetry merge —
        the worker process owns the truth for its own series)."""
        with self._lock:
            self._v = float(v)

    @property
    def value(self) -> float:
        return self._v


class Gauge:
    """Settable value. Locked like ``Counter``: maintenance and worker
    threads both write gauges (queue depth, brownout level), and ``add()``
    is a read-modify-write that would tear without it."""

    def __init__(self):
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)

    def add(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._v


class StateGauge:
    """Typed-state gauge over a fixed set of named states (e.g. the brownout
    ladder). Holds the state INDEX; renders the name alongside it, and in
    Prometheus exposition emits one 0/1 series per state (label
    ``state="..."``) so dashboards alert on a name, not a magic integer."""

    def __init__(self, states: Sequence[str]):
        self.states = tuple(states)
        self._i = 0
        self._lock = threading.Lock()

    def set(self, index: int) -> None:
        with self._lock:
            self._i = int(index)

    @property
    def value(self) -> float:
        return float(self._i)

    @property
    def state(self) -> str:
        if 0 <= self._i < len(self.states):
            return self.states[self._i]
        return str(self._i)


class Histogram:
    """Fixed-bucket histogram; ``bounds[i]`` is the inclusive upper edge of
    bucket i, with one implicit overflow bucket past the last edge."""

    def __init__(self, bounds: Sequence[float] = DEFAULT_BUCKETS):
        self.bounds = tuple(bounds)
        assert self.bounds == tuple(sorted(self.bounds)) and self.bounds
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            i = 0
            while i < len(self.bounds) and v > self.bounds[i]:
                i += 1
            self.counts[i] += 1
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    def _snapshot(self):
        with self._lock:
            return (list(self.counts), self.count, self.sum, self.min,
                    self.max)

    def load(self, counts: Sequence[int], count: int, total: float,
             mn: float, mx: float) -> None:
        """Overwrite with an authoritative remote snapshot (same bucket
        table on both sides — DEFAULT_BUCKETS everywhere)."""
        assert len(counts) == len(self.counts), "bucket tables differ"
        with self._lock:
            self.counts = list(counts)
            self.count = count
            self.sum = total
            self.min = mn
            self.max = mx

    def merge(self, other: "Histogram") -> "Histogram":
        assert self.bounds == other.bounds, "histograms must share buckets"
        # snapshot under other's lock, apply under ours — never hold both
        # (a worker may be observe()-ing other concurrently; reading its
        # fields piecemeal could tear count vs counts)
        counts, count, total, mn, mx = other._snapshot()
        with self._lock:
            for i, c in enumerate(counts):
                self.counts[i] += c
            self.count += count
            self.sum += total
            self.min = min(self.min, mn)
            self.max = max(self.max, mx)
        return self

    def percentile(self, p: float) -> float:
        """p in [0, 1]; linear interpolation within the crossing bucket."""
        counts, count, _, mn, mx = self._snapshot()
        if count == 0:
            return float("nan")
        target = p * count
        cum = 0.0
        for i, c in enumerate(counts):
            if c and cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else mx
                lo, hi = max(lo, mn if cum == 0 else lo), min(hi, mx)
                frac = max(0.0, min(1.0, (target - cum) / c))
                return lo + frac * max(hi - lo, 0.0)
            cum += c
        return mx

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def summary(self) -> Dict[str, float]:
        _, count, total, _, mx = self._snapshot()
        return {"count": count,
                "mean": total / count if count else float("nan"),
                "p50": self.percentile(0.50), "p95": self.percentile(0.95),
                "p99": self.percentile(0.99),
                "max": mx}


class MetricsRegistry:
    """Get-or-create metric store keyed by (kind, name, instance)."""

    GLOBAL = ""   # instance label of the aggregate view

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS):
        self._buckets = tuple(buckets)
        self._m: Dict[Tuple[str, str, str], object] = {}
        self._help: Dict[str, str] = {}
        self._lock = threading.Lock()

    def _get(self, kind: str, name: str, instance: str, factory,
             help: Optional[str] = None):
        key = (kind, name, instance)
        with self._lock:
            if help and name not in self._help:
                self._help[name] = help
            if key not in self._m:
                self._m[key] = factory()
            return self._m[key]

    def describe(self, name: str, help: str) -> None:
        """Attach a HELP string to a metric name (first writer wins)."""
        with self._lock:
            self._help.setdefault(name, help)

    def counter(self, name: str, instance: str = GLOBAL,
                help: Optional[str] = None) -> Counter:
        return self._get("counter", name, instance, Counter, help)

    def gauge(self, name: str, instance: str = GLOBAL,
              help: Optional[str] = None) -> Gauge:
        return self._get("gauge", name, instance, Gauge, help)

    def histogram(self, name: str, instance: str = GLOBAL,
                  help: Optional[str] = None) -> Histogram:
        return self._get("hist", name, instance,
                         lambda: Histogram(self._buckets), help)

    def state_gauge(self, name: str, states: Sequence[str],
                    instance: str = GLOBAL,
                    help: Optional[str] = None) -> StateGauge:
        return self._get("state", name, instance,
                         lambda: StateGauge(states), help)

    # ---- aggregation -----------------------------------------------------
    def _named(self, kind: str, name: str) -> List[Tuple[str, object]]:
        with self._lock:
            return [(k[2], v) for k, v in self._m.items()
                    if k[0] == kind and k[1] == name]

    def total(self, name: str) -> float:
        """Global value of a counter: sum across every instance label."""
        return sum(c.value for _, c in self._named("counter", name))

    def merged_histogram(self, name: str) -> Histogram:
        out = Histogram(self._buckets)
        for _, h in self._named("hist", name):
            out.merge(h)
        return out

    # ---- cross-process state transfer ------------------------------------
    def dump_state(self) -> List[Dict]:
        """JSON-able snapshot of every series — the worker side of the
        telemetry bridge (heartbeat responses carry this)."""
        with self._lock:
            items = list(self._m.items())
            help_texts = dict(self._help)
        rows: List[Dict] = []
        for (kind, name, inst), m in items:
            row: Dict = {"kind": kind, "name": name, "instance": inst,
                         "help": help_texts.get(name)}
            if kind in ("counter", "gauge"):
                row["value"] = m.value
            elif kind == "state":
                row["value"] = m.value
                row["states"] = list(m.states)
            else:
                counts, count, total, mn, mx = m._snapshot()
                row.update(counts=counts, count=count, sum=total,
                           min=(None if math.isinf(mn) else mn),
                           max=(None if math.isinf(mx) else mx))
            rows.append(row)
        return rows

    def merge_state(self, rows: Sequence[Dict],
                    instance: Optional[str] = None) -> None:
        """Load a worker's ``dump_state`` into this registry, overwriting
        per-series (the worker owns the truth for its own series; frontend-
        and worker-authored series are disjoint by name, so a blind
        overwrite never clobbers frontend counts). ``instance`` forces the
        instance label (a worker always reports as itself)."""
        for row in rows:
            kind = row["kind"]
            inst = instance if instance is not None else row["instance"]
            name = row["name"]
            if row.get("help"):
                self.describe(name, row["help"])
            if kind == "counter":
                self.counter(name, inst).load(row["value"])
            elif kind == "gauge":
                self.gauge(name, inst).set(row["value"])
            elif kind == "state":
                self.state_gauge(name, row["states"], inst).set(
                    int(row["value"]))
            else:
                self.histogram(name, inst).load(
                    row["counts"], row["count"], row["sum"],
                    math.inf if row["min"] is None else row["min"],
                    -math.inf if row["max"] is None else row["max"])

    # ---- text dump (benchmark output) ------------------------------------
    def render(self) -> str:
        with self._lock:
            items = sorted(self._m.items())
        lines = []
        hist_names = sorted({k[1] for k, _ in items if k[0] == "hist"})
        for (kind, name, inst), m in items:
            label = f"{name}{{{inst}}}" if inst else name
            if kind == "counter":
                lines.append(f"counter {label} {m.value:g}")
            elif kind == "gauge":
                lines.append(f"gauge {label} {m.value:g}")
            elif kind == "state":
                lines.append(f"state {label} {m.value:g} ({m.state})")
            else:
                s = m.summary()
                lines.append(
                    f"hist {label} count={s['count']} mean={s['mean']:.4f} "
                    f"p50={s['p50']:.4f} p95={s['p95']:.4f} "
                    f"p99={s['p99']:.4f} max={s['max']:.4f}")
        for name in hist_names:
            merged = self.merged_histogram(name)
            if merged.count:
                s = merged.summary()
                lines.append(
                    f"hist {name}{{ALL}} count={s['count']} "
                    f"mean={s['mean']:.4f} p50={s['p50']:.4f} "
                    f"p95={s['p95']:.4f} p99={s['p99']:.4f} "
                    f"max={s['max']:.4f}")
        return "\n".join(lines)

    # ---- Prometheus text exposition (0.0.4) -------------------------------
    @staticmethod
    def _escape_label(v: str) -> str:
        """Label-value escaping per the exposition spec: backslash, double
        quote, and newline (instance names are caller-supplied strings)."""
        return (v.replace("\\", r"\\").replace('"', r'\"')
                .replace("\n", r"\n"))

    @staticmethod
    def _escape_help(v: str) -> str:
        """HELP-text escaping per the spec: backslash and newline only."""
        return v.replace("\\", r"\\").replace("\n", r"\n")

    def render_prometheus(self, namespace: str = "prefillonly") -> str:
        """Prometheus text exposition format, scrape-ready.

        Counters/gauges become ``<ns>_<name>{instance="..."}``; histograms
        become the conventional cumulative ``_bucket{le=...}`` series plus
        ``_sum``/``_count`` — exact, because every histogram of a name
        shares one fixed bucket table. The empty (aggregate) instance label
        is omitted so global metrics scrape as unlabelled series.
        """
        with self._lock:
            items = sorted(self._m.items())
            help_texts = dict(self._help)
        by_name: Dict[Tuple[str, str], List[Tuple[str, object]]] = {}
        for (kind, name, inst), m in items:
            by_name.setdefault((kind, name), []).append((inst, m))
        out: List[str] = []
        for (kind, name), series in sorted(by_name.items()):
            full = f"{namespace}_{name}"
            ptype = {"counter": "counter", "gauge": "gauge",
                     "state": "gauge", "hist": "histogram"}[kind]
            htext = help_texts.get(name)
            if htext:
                out.append(f"# HELP {full} {self._escape_help(htext)}")
            out.append(f"# TYPE {full} {ptype}")
            for inst, m in series:
                esc = self._escape_label(inst)
                lbl = f'{{instance="{esc}"}}' if inst else ""
                if kind in ("counter", "gauge"):
                    out.append(f"{full}{lbl} {m.value:g}")
                    continue
                if kind == "state":
                    for i, st in enumerate(m.states):
                        stl = self._escape_label(st)
                        sep = (f'{{instance="{esc}",state="{stl}"}}'
                               if inst else f'{{state="{stl}"}}')
                        out.append(f"{full}{sep} "
                                   f"{1 if i == int(m.value) else 0}")
                    continue
                counts, count, total, _, _ = m._snapshot()
                cum = 0
                for i, bound in enumerate(m.bounds):
                    cum += counts[i]
                    le = f'le="{bound:g}"'
                    sep = f'{{instance="{esc}",{le}}}' if inst \
                        else f"{{{le}}}"
                    out.append(f"{full}_bucket{sep} {cum}")
                sep = (f'{{instance="{esc}",le="+Inf"}}' if inst
                       else '{le="+Inf"}')
                out.append(f"{full}_bucket{sep} {count}")
                out.append(f"{full}_sum{lbl} {total:g}")
                out.append(f"{full}_count{lbl} {count}")
        return "\n".join(out) + ("\n" if out else "")
