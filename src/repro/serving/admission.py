"""Admission control — reject infeasible work up front, shed doomed work early.

Prefill-only JCT is precisely predictable (paper §6.3), which turns admission
control from a heuristic into arithmetic:

  * MIL check: a request longer than the engine's max input length (closed
    form from ``kv_policy.MemoryModel``) can NEVER be served — reject at the
    door instead of OOMing an instance.
  * Deadline check: predicted queue delay + predicted JCT past the deadline
    means the request is already doomed — reject it now (a typed ``Rejected``
    result) instead of letting it queue, blow out its own latency, and drag
    every request behind it into the tail.

The in-queue half of the same policy lives in
``PrefillOnlyEngine.shed_expired``: requests whose deadline becomes
unreachable AFTER admission (backlog grew, cache churned) are popped before
the next scheduling step.

Feedback loop: every admitted-with-deadline request eventually reports back
(``record_outcome``) whether it was served or shed in-queue. A shed request
is a request the admission predictor UNDER-estimated — it said feasible, the
queue said otherwise. When the shed rate over a sliding window exceeds
``shed_target``, ``deadline_slack`` is tightened (multiplied up, so the
deadline check turns pessimistic and rejects earlier); sustained zero-shed
windows relax it back toward the configured floor. Adjustments land in the
metrics registry so operators can see the controller hunting.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Optional

from repro.core.kv_policy import MemoryModel


@dataclasses.dataclass
class Rejected:
    """Typed rejection — the resolve value of a request that was not served."""
    reason: str                 # infeasible | deadline | shed | cancelled |
                                # shutdown | no_instances
    detail: str = ""
    req_id: Optional[int] = None
    user_id: Optional[str] = None
    predicted_wait: float = 0.0
    predicted_jct: float = 0.0


class AdmissionController:
    """Submit-time feasibility gate.

    ``max_input_tokens`` defaults to the MIL of the paper's hybrid-prefill
    technique computed from ``memory_model`` — the same closed form the
    profile run uses to size the prefix-KV budget. ``deadline_slack``
    multiplies the predicted completion time before comparing against the
    deadline: >1 sheds earlier (conservative), <1 gambles on the predictor
    overestimating.

    ``adapt=True`` turns on the shed-rate feedback loop: callers report each
    admitted-with-deadline request's fate via ``record_outcome(shed=...)``;
    when the shed fraction over the last ``adapt_window`` outcomes exceeds
    ``shed_target``, ``deadline_slack`` is multiplied by ``adapt_rate`` (up
    to ``max_slack``), and a full window with zero sheds relaxes it by the
    same factor (down to the configured starting slack). The window resets
    after every adjustment so one burst is not counted twice.
    """

    def __init__(self, max_input_tokens: Optional[int] = None,
                 memory_model: Optional[MemoryModel] = None,
                 chunk: int = 2048, deadline_slack: float = 1.0,
                 adapt: bool = True, adapt_window: int = 64,
                 shed_target: float = 0.05, adapt_rate: float = 1.25,
                 max_slack: float = 4.0, metrics=None):
        if max_input_tokens is None and memory_model is not None:
            max_input_tokens = memory_model.max_input_length("hybrid", chunk)
        self.max_input_tokens = max_input_tokens
        self.deadline_slack = deadline_slack
        self.rejected_infeasible = 0
        self.rejected_deadline = 0
        self.adapt = adapt
        self.adapt_window = adapt_window
        self.shed_target = shed_target
        self.adapt_rate = adapt_rate
        self.max_slack = max_slack
        self.min_slack = deadline_slack    # relax floor = configured slack
        self.slack_adjustments = 0
        self.metrics = metrics
        self._outcomes: deque = deque(maxlen=adapt_window)
        self._outcome_lock = threading.Lock()

    # ---- shed-rate feedback ----------------------------------------------
    def record_outcome(self, shed: bool) -> None:
        """Report the fate of one admitted-with-deadline request: served
        (``shed=False``) or shed in-queue after admission (``shed=True`` —
        the admission prediction under-estimated). Thread-safe: every
        serving worker reports here."""
        if not self.adapt:
            return
        with self._outcome_lock:
            self._outcomes.append(bool(shed))
            if len(self._outcomes) < self.adapt_window:
                return
            rate = sum(self._outcomes) / len(self._outcomes)
            if rate > self.shed_target and self.deadline_slack < self.max_slack:
                self.deadline_slack = min(
                    self.max_slack, self.deadline_slack * self.adapt_rate)
                self._note_adjustment("admission_slack_tightened")
            elif rate == 0.0 and self.deadline_slack > self.min_slack:
                self.deadline_slack = max(
                    self.min_slack, self.deadline_slack / self.adapt_rate)
                self._note_adjustment("admission_slack_relaxed")

    def _note_adjustment(self, counter: str) -> None:
        self.slack_adjustments += 1
        self._outcomes.clear()     # don't react to the same burst twice
        if self.metrics is not None:
            self.metrics.counter(counter).inc()
            self.metrics.gauge("admission_deadline_slack").set(
                self.deadline_slack)

    def check(self, n_input: int, deadline: Optional[float], now: float,
              predicted_wait: float, predicted_jct: float,
              user_id: Optional[str] = None) -> Optional[Rejected]:
        """None = admit; a ``Rejected`` explains why not."""
        if (self.max_input_tokens is not None
                and n_input > self.max_input_tokens):
            self.rejected_infeasible += 1
            return Rejected(
                "infeasible",
                f"n_input={n_input} exceeds MIL={self.max_input_tokens}",
                user_id=user_id, predicted_jct=predicted_jct)
        if deadline is not None:
            eta = now + self.deadline_slack * (predicted_wait + predicted_jct)
            if eta > deadline:
                self.rejected_deadline += 1
                return Rejected(
                    "deadline",
                    f"predicted finish {eta - now:.3f}s out, deadline in "
                    f"{deadline - now:.3f}s",
                    user_id=user_id, predicted_wait=predicted_wait,
                    predicted_jct=predicted_jct)
        return None
