"""Admission control — reject infeasible work up front, shed doomed work early.

Prefill-only JCT is precisely predictable (paper §6.3), which turns admission
control from a heuristic into arithmetic:

  * MIL check: a request longer than the engine's max input length (closed
    form from ``kv_policy.MemoryModel``) can NEVER be served — reject at the
    door instead of OOMing an instance.
  * Deadline check: predicted queue delay + predicted JCT past the deadline
    means the request is already doomed — reject it now (a typed ``Rejected``
    result) instead of letting it queue, blow out its own latency, and drag
    every request behind it into the tail.

The in-queue half of the same policy lives in
``PrefillOnlyEngine.shed_expired``: requests whose deadline becomes
unreachable AFTER admission (backlog grew, cache churned) are popped before
the next scheduling step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.kv_policy import MemoryModel


@dataclasses.dataclass
class Rejected:
    """Typed rejection — the resolve value of a request that was not served."""
    reason: str                 # infeasible | deadline | shed | cancelled |
                                # shutdown | no_instances
    detail: str = ""
    req_id: Optional[int] = None
    user_id: Optional[str] = None
    predicted_wait: float = 0.0
    predicted_jct: float = 0.0


class AdmissionController:
    """Submit-time feasibility gate.

    ``max_input_tokens`` defaults to the MIL of the paper's hybrid-prefill
    technique computed from ``memory_model`` — the same closed form the
    profile run uses to size the prefix-KV budget. ``deadline_slack``
    multiplies the predicted completion time before comparing against the
    deadline: >1 sheds earlier (conservative), <1 gambles on the predictor
    overestimating.
    """

    def __init__(self, max_input_tokens: Optional[int] = None,
                 memory_model: Optional[MemoryModel] = None,
                 chunk: int = 2048, deadline_slack: float = 1.0):
        if max_input_tokens is None and memory_model is not None:
            max_input_tokens = memory_model.max_input_length("hybrid", chunk)
        self.max_input_tokens = max_input_tokens
        self.deadline_slack = deadline_slack
        self.rejected_infeasible = 0
        self.rejected_deadline = 0

    def check(self, n_input: int, deadline: Optional[float], now: float,
              predicted_wait: float, predicted_jct: float,
              user_id: Optional[str] = None) -> Optional[Rejected]:
        """None = admit; a ``Rejected`` explains why not."""
        if (self.max_input_tokens is not None
                and n_input > self.max_input_tokens):
            self.rejected_infeasible += 1
            return Rejected(
                "infeasible",
                f"n_input={n_input} exceeds MIL={self.max_input_tokens}",
                user_id=user_id, predicted_jct=predicted_jct)
        if deadline is not None:
            eta = now + self.deadline_slack * (predicted_wait + predicted_jct)
            if eta > deadline:
                self.rejected_deadline += 1
                return Rejected(
                    "deadline",
                    f"predicted finish {eta - now:.3f}s out, deadline in "
                    f"{deadline - now:.3f}s",
                    user_id=user_id, predicted_wait=predicted_wait,
                    predicted_jct=predicted_jct)
        return None
