"""Admission control — reject infeasible work up front, shed doomed work early.

Prefill-only JCT is precisely predictable (paper §6.3), which turns admission
control from a heuristic into arithmetic:

  * MIL check: a request longer than the engine's max input length (closed
    form from ``kv_policy.MemoryModel``) can NEVER be served — reject at the
    door instead of OOMing an instance.
  * Deadline check: predicted queue delay + predicted JCT past the deadline
    means the request is already doomed — reject it now (a typed ``Rejected``
    result) instead of letting it queue, blow out its own latency, and drag
    every request behind it into the tail.

The in-queue half of the same policy lives in
``PrefillOnlyEngine.shed_expired``: requests whose deadline becomes
unreachable AFTER admission (backlog grew, cache churned) are popped before
the next scheduling step.

Feedback loop: every admitted-with-deadline request eventually reports back
(``record_outcome``) whether it was served or shed in-queue. A shed request
is a request the admission predictor UNDER-estimated — it said feasible, the
queue said otherwise. When the shed rate over a sliding window exceeds
``shed_target``, ``deadline_slack`` is tightened (multiplied up, so the
deadline check turns pessimistic and rejects earlier); sustained zero-shed
windows relax it back toward the configured floor. Adjustments land in the
metrics registry so operators can see the controller hunting.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Optional

from repro.core.kv_policy import MemoryModel


@dataclasses.dataclass
class Rejected:
    """Typed rejection — the resolve value of a request that was not served."""
    reason: str                 # infeasible | deadline | shed | cancelled |
                                # shutdown | no_instances | error | brownout
    detail: str = ""
    req_id: Optional[int] = None
    user_id: Optional[str] = None
    predicted_wait: float = 0.0
    predicted_jct: float = 0.0


class AdmissionController:
    """Submit-time feasibility gate.

    ``max_input_tokens`` defaults to the MIL of the paper's hybrid-prefill
    technique computed from ``memory_model`` — the same closed form the
    profile run uses to size the prefix-KV budget. ``deadline_slack``
    multiplies the predicted completion time before comparing against the
    deadline: >1 sheds earlier (conservative), <1 gambles on the predictor
    overestimating.

    ``adapt=True`` turns on the shed-rate feedback loop: callers report each
    admitted-with-deadline request's fate via ``record_outcome(shed=...)``;
    when the shed fraction over the last ``adapt_window`` outcomes exceeds
    ``shed_target``, ``deadline_slack`` is multiplied by ``adapt_rate`` (up
    to ``max_slack``), and a full window with zero sheds relaxes it by the
    same factor (down to the configured starting slack). The window resets
    after every adjustment so one burst is not counted twice.
    """

    def __init__(self, max_input_tokens: Optional[int] = None,
                 memory_model: Optional[MemoryModel] = None,
                 chunk: int = 2048, deadline_slack: float = 1.0,
                 adapt: bool = True, adapt_window: int = 64,
                 shed_target: float = 0.05, adapt_rate: float = 1.25,
                 max_slack: float = 4.0, metrics=None,
                 kv_keep: Optional[int] = None):
        if max_input_tokens is None and memory_model is not None:
            # kv_keep: price the engines' layer-wise discard (peak-layer
            # suffix KV + bounded kept slice, see MemoryModel.peak_bytes)
            # instead of the all-layers footprint — the MIL the gate
            # enforces matches what the engines can actually serve
            max_input_tokens = memory_model.max_input_length(
                "hybrid", chunk, kv_keep=kv_keep)
        self.max_input_tokens = max_input_tokens
        self.deadline_slack = deadline_slack
        self.rejected_infeasible = 0
        self.rejected_deadline = 0
        self.adapt = adapt
        self.adapt_window = adapt_window
        self.shed_target = shed_target
        self.adapt_rate = adapt_rate
        self.max_slack = max_slack
        self.min_slack = deadline_slack    # relax floor = configured slack
        self.slack_adjustments = 0
        self.metrics = metrics
        # brownout hook: a multiplier >1 applied ON TOP of deadline_slack,
        # so overload pressure tightens the gate without fighting the
        # shed-rate feedback loop's own slack hunting
        self.pressure = 1.0
        self._outcomes: deque = deque(maxlen=adapt_window)
        self._outcome_lock = threading.Lock()

    def set_pressure(self, pressure: float) -> None:
        """Brownout ladder hook: scale the effective deadline slack by
        ``pressure`` (1.0 = normal). Recorded as a gauge when metrics are
        attached, so operators can tell brownout tightening from the
        feedback loop's own adjustments."""
        self.pressure = max(1.0, float(pressure))
        if self.metrics is not None:
            self.metrics.gauge("admission_pressure").set(self.pressure)

    def shed_rate(self) -> float:
        """Shed fraction over the current outcome window (0.0 when empty) —
        one of the brownout controller's escalation signals."""
        with self._outcome_lock:
            if not self._outcomes:
                return 0.0
            return sum(self._outcomes) / len(self._outcomes)

    # ---- shed-rate feedback ----------------------------------------------
    def record_outcome(self, shed: bool) -> None:
        """Report the fate of one admitted-with-deadline request: served
        (``shed=False``) or shed in-queue after admission (``shed=True`` —
        the admission prediction under-estimated). Thread-safe: every
        serving worker reports here."""
        if not self.adapt:
            return
        with self._outcome_lock:
            self._outcomes.append(bool(shed))
            if len(self._outcomes) < self.adapt_window:
                return
            rate = sum(self._outcomes) / len(self._outcomes)
            if rate > self.shed_target and self.deadline_slack < self.max_slack:
                self.deadline_slack = min(
                    self.max_slack, self.deadline_slack * self.adapt_rate)
                self._note_adjustment("admission_slack_tightened")
            elif rate == 0.0 and self.deadline_slack > self.min_slack:
                self.deadline_slack = max(
                    self.min_slack, self.deadline_slack / self.adapt_rate)
                self._note_adjustment("admission_slack_relaxed")

    def _note_adjustment(self, counter: str) -> None:
        self.slack_adjustments += 1
        self._outcomes.clear()     # don't react to the same burst twice
        if self.metrics is not None:
            self.metrics.counter(counter).inc()
            self.metrics.gauge("admission_deadline_slack").set(
                self.deadline_slack)

    def check(self, n_input: int, deadline: Optional[float], now: float,
              predicted_wait: float, predicted_jct: float,
              user_id: Optional[str] = None) -> Optional[Rejected]:
        """None = admit; a ``Rejected`` explains why not."""
        if (self.max_input_tokens is not None
                and n_input > self.max_input_tokens):
            self.rejected_infeasible += 1
            return Rejected(
                "infeasible",
                f"n_input={n_input} exceeds MIL={self.max_input_tokens}",
                user_id=user_id, predicted_jct=predicted_jct)
        if deadline is not None:
            eta = now + (self.deadline_slack * self.pressure
                         * (predicted_wait + predicted_jct))
            if eta > deadline:
                self.rejected_deadline += 1
                return Rejected(
                    "deadline",
                    f"predicted finish {eta - now:.3f}s out, deadline in "
                    f"{deadline - now:.3f}s",
                    user_id=user_id, predicted_wait=predicted_wait,
                    predicted_jct=predicted_jct)
        return None


class BrownoutController:
    """Graceful-degradation ladder: overload trades quality for survival.

    Levels (typed, exported as the ``brownout_level`` gauge):

      0  normal    everything on
      1  tighten   admission deadline slack scaled by ``slack_factor`` —
                   doomed-looking work is rejected earlier, the queue stops
                   growing at the tail
      2  degrade   hit co-packing's expensive gather paths disabled on every
                   engine (``engine.set_degraded``) — cache hits run the
                   cheap solo-suffix path, misses still co-pack; per-step
                   cost variance collapses, shedding compute for latency
                   headroom
      3  shed      new work rejected at the door (``Rejected("brownout")``)
                   — existing backlog drains, the pool never collapses

    Signals: the pool's worst per-instance backlog in predicted-JCT seconds
    (trustworthy *because* prefill-only JCT is predictable) and the
    admission controller's shed rate (fraction of admitted-with-deadline
    requests later shed in-queue — admission under-estimating means the
    door is effectively open too wide). The shed rate maps onto the backlog
    axis via ``shed_to_seconds`` and the max of both drives the ladder.

    Hysteresis: escalation is immediate (overload hurts NOW); de-escalation
    requires the signal below the level's *exit* threshold (strictly less
    than its enter threshold) for ``hold`` consecutive evaluations, so the
    ladder doesn't flap across a noisy boundary.
    """

    LEVELS = ("normal", "tighten", "degrade", "shed")

    def __init__(self, enter=(2.0, 6.0, 12.0), exit=(1.0, 3.0, 6.0),
                 hold: int = 3, slack_factor: float = 1.5,
                 shed_to_seconds: float = 20.0):
        assert len(enter) == len(exit) == len(self.LEVELS) - 1
        assert all(x < e for x, e in zip(exit, enter)), \
            "exit thresholds must sit strictly below enter thresholds"
        self.enter = tuple(enter)
        self.exit = tuple(exit)
        self.hold = hold
        self.slack_factor = slack_factor
        self.shed_to_seconds = shed_to_seconds
        self.level = 0
        self.escalations = 0
        self.deescalations = 0
        self._calm = 0          # consecutive below-exit evaluations
        self._lock = threading.Lock()

    def signal(self, backlog_seconds: float, shed_rate: float) -> float:
        return max(backlog_seconds, shed_rate * self.shed_to_seconds)

    def evaluate(self, backlog_seconds: float,
                 shed_rate: float = 0.0) -> int:
        """Feed one observation; returns the (possibly new) level."""
        s = self.signal(backlog_seconds, shed_rate)
        with self._lock:
            target = 0
            for i, e in enumerate(self.enter):
                if s >= e:
                    target = i + 1
            if target > self.level:
                self.level = target
                self.escalations += 1
                self._calm = 0
            elif self.level > 0 and s < self.exit[self.level - 1]:
                self._calm += 1
                if self._calm >= self.hold:
                    self.level -= 1
                    self.deescalations += 1
                    self._calm = 0
            else:
                self._calm = 0
            return self.level

    def pressure(self) -> float:
        """Admission slack multiplier for the current level."""
        return self.slack_factor if self.level >= 1 else 1.0

    def state(self) -> str:
        return self.LEVELS[self.level]
