"""Chaos harness — seeded, deterministic fault injection for the serving stack.

Prefill-only serving is uniquely testable under faults: every request is one
stateless, side-effect-free forward producing one token, so a lost request
can be re-run anywhere with no duplicate-output hazard, and "did every future
resolve exactly once" is a crisp invariant a chaos soak can assert. This
module provides the faults; ``AsyncServer`` (watchdog + retry + brownout)
provides the recovery the soak proves out.

``ChaosConfig`` declares per-operation fault *rates* plus an optional exact
``schedule``; ``FaultPlan`` turns that into deterministic per-instance draws
(seeded ``Philox``-free: one ``numpy`` generator per instance, seeded from
``(seed, instance name)``, so a run replays bit-identically given the same
request interleaving); ``ChaosEngine`` wraps a pool engine and injects:

  step_error   step() raises ``InjectedFault`` AFTER the forward completed,
               with the batch's results destroyed — the worst mid-step crash:
               work was in flight and is gone. The server's worker must
               retry the lost batch on a peer and fail the instance.
  hang         step() completes, then blocks for ``hang_seconds`` while
               still REPORTING the batch as in-flight — a wedged step from
               the outside. The JCT watchdog must trip, confiscate the
               batch onto a peer, and the late results must be dropped
               (exactly-once), not double-delivered.
  straggler    step() completes, then dawdles ``straggler_seconds`` with the
               batch still reported in-flight — slow, not dead. Below the
               watchdog deadline this must NOT trip; results deliver late.
  nan_score    the step's results are corrupted to non-finite scores (the
               NaN-logits failure PR 3's benchmark hit silently) — the
               server must quarantine and retry them, never deliver NaN.
  submit_error submit() raises ``InjectedFault`` — a transient enqueue
               failure; the server must fall back to a peer.

Process-mode faults (the cross-process serving plane of
``serving.supervisor``) go further — the fault hits a real worker PROCESS,
not a proxy:

  kill         SIGKILL the worker a beat after its step was driven — the
               kernel guarantees mid-batch death, no Python cleanup runs.
               Detection: TCP reset on the in-flight step RPC + missed
               heartbeats; recovery: shadow-queue re-home + idempotent
               retry + supervised restart.
  freeze       SIGSTOP the worker mid-batch (SIGCONT after
               ``freeze_seconds``) — the process is alive but silent: no
               heartbeats, no RPC responses, no TCP reset. The supervisor
               must declare it dead on lease expiry and SIGKILL it to
               unblock the frontend.
  rpc_drop     the worker processed the call; the response is dropped at
               the client edge (``RpcClient.fault_hook``) — the classic
               "did it happen?" network fault. Exactly-once must hold.
  rpc_delay    the response is delayed ``rpc_delay_seconds`` — tests
               timeout discipline without killing anything.

Wrap a whole pool with ``wrap_pool(pool, plan)`` — live engines are wrapped
in place and ``pool.make_engine`` is chained so instances born later (scale-
up, resurrection) inherit the same plan. For process pools use
``wrap_pool_processes(pool, plan, sup)`` (kill/freeze) plus
``plan.rpc_fault`` as the supervisor's ``rpc_fault_hook`` (drop/delay).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

STEP_FAULTS = ("step_error", "hang", "straggler", "nan_score")
SUBMIT_FAULTS = ("submit_error",)
PROCESS_FAULTS = ("kill", "freeze")
RPC_FAULTS = ("rpc_drop", "rpc_delay")
FAULT_KINDS = STEP_FAULTS + SUBMIT_FAULTS + PROCESS_FAULTS + RPC_FAULTS

# which operation stream each fault kind draws from (see FaultPlan.draw)
_OP_OF = {**{k: "step" for k in STEP_FAULTS},
          **{k: "submit" for k in SUBMIT_FAULTS},
          **{k: "pstep" for k in PROCESS_FAULTS},
          **{k: "rpc" for k in RPC_FAULTS}}


class InjectedFault(RuntimeError):
    """Raised by injected step/submit failures (never by real code paths)."""


@dataclasses.dataclass
class ChaosConfig:
    """Per-operation fault rates + optional exact schedule, one seed.

    Rates are per *eligible* operation: step faults draw once per step that
    has work queued (an idle poll can't lose anything), submit faults once
    per submit. ``schedule`` entries ``(instance, op_index, kind)`` fire
    deterministically at that instance's ``op_index``-th eligible operation
    (steps and submits indexed separately) and override the rate draw.
    ``max_faults`` bounds TOTAL injected faults across the run so a chaos
    soak converges instead of grinding the pool to zero instances.
    """
    seed: int = 0
    step_error: float = 0.0
    hang: float = 0.0
    hang_seconds: float = 1.0
    straggler: float = 0.0
    straggler_seconds: float = 0.1
    nan_score: float = 0.0
    submit_error: float = 0.0
    kill: float = 0.0
    freeze: float = 0.0
    freeze_seconds: float = 1.0
    rpc_drop: float = 0.0
    rpc_delay: float = 0.0
    rpc_delay_seconds: float = 0.05
    schedule: Sequence[Tuple[str, int, str]] = ()
    max_faults: Optional[int] = None

    def __post_init__(self):
        for _, _, kind in self.schedule:
            assert kind in FAULT_KINDS, kind


class FaultPlan:
    """Deterministic fault oracle shared by every ChaosEngine of one run.

    Thread-safe: each serving worker draws for its own instance, and the
    global ``max_faults`` budget is decremented under one lock. Draws are a
    pure function of (seed, instance, operation index), so two runs with the
    same config and request interleaving inject identically.
    """

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self.injected: List[Tuple[str, int, str]] = []   # audit trail
        self._lock = threading.Lock()
        self._rngs: Dict[str, np.random.Generator] = {}
        self._ops: Dict[Tuple[str, str], int] = {}       # (instance, op) -> n
        self._sched = {(i, n, _OP_OF[k]): k for i, n, k in cfg.schedule}

    def _rng(self, instance: str) -> np.random.Generator:
        if instance not in self._rngs:
            # stable across processes (str hash() is salted per interpreter)
            import hashlib
            h = int.from_bytes(hashlib.blake2b(
                instance.encode(), digest_size=4).digest(), "big")
            self._rngs[instance] = np.random.default_rng([self.cfg.seed, h])
        return self._rngs[instance]

    def draw(self, instance: str, op: str) -> Optional[str]:
        """The fault to inject for this instance's next ``op`` — or None.

        ``op`` is "step", "submit", "pstep" (process-level step fault), or
        "rpc" (response fault). Consumes one operation index either way
        (rates stay per-operation, not per-call-that-faulted).
        """
        ladders = {"step": STEP_FAULTS, "submit": SUBMIT_FAULTS,
                   "pstep": PROCESS_FAULTS, "rpc": RPC_FAULTS}
        cfg = self.cfg
        with self._lock:
            n = self._ops.get((instance, op), 0)
            self._ops[(instance, op)] = n + 1
            kind = self._sched.get((instance, n, op))
            if kind is None:
                rates = [(k, getattr(cfg, k)) for k in ladders[op]]
                # one uniform draw walks the rate ladder: stable under
                # adding kinds, and each op costs exactly one rng call
                u = float(self._rng(instance).uniform())
                acc = 0.0
                for k, rate in rates:
                    acc += rate
                    if u < acc:
                        kind = k
                        break
            if kind is None:
                return None
            if (cfg.max_faults is not None
                    and len(self.injected) >= cfg.max_faults):
                return None
            self.injected.append((instance, n, kind))
            return kind

    def counts(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for _, _, k in self.injected:
                out[k] = out.get(k, 0) + 1
            return out

    def rpc_fault(self, instance: str,
                  op: str) -> Optional[Tuple[str, float]]:
        """``RpcClient.fault_hook`` adapter: drop/delay the RESPONSE of a
        submit or step call (the worker already processed it — exactly the
        fault window where exactly-once is hardest). Other ops (heartbeat,
        probe, requeue) are left alone: randomly failing the failure
        DETECTOR itself would make every soak assertion about detection
        latency vacuous."""
        if op not in ("submit", "step"):
            return None
        kind = self.draw(instance, "rpc")
        if kind == "rpc_drop":
            return ("rpc_drop", 0.0)
        if kind == "rpc_delay":
            return ("rpc_delay", self.cfg.rpc_delay_seconds)
        return None


class ChaosEngine:
    """Transparent engine proxy that injects the plan's faults.

    Every attribute not intercepted here proxies to the wrapped engine
    (lock, queue, results, probes, stats, ...), so the server, routers, and
    ``InstancePool`` drive a ChaosEngine exactly like the real thing.

    Hang/straggler injection happens AFTER the inner step completed, while
    ``inflight_snapshot`` keeps reporting the served batch as in-flight —
    from the server's side the step simply hasn't returned, which is
    exactly what a wedged forward looks like, without reaching into the
    engine's internals (real engines wrap as cleanly as test fakes).
    """

    def __init__(self, inner, name: str, plan: FaultPlan):
        # object.__setattr__-free: plain attrs, __getattr__ only fires for
        # names NOT set here
        self._inner = inner
        self._name = name
        self._plan = plan
        self._shadow_lock = threading.Lock()
        self._shadow_ids: List[int] = []
        self._shadow_t0 = 0.0

    def __getattr__(self, attr):
        return getattr(self._inner, attr)

    # ---- intercepted surface ---------------------------------------------
    def submit(self, *args, **kw) -> int:
        if self._plan.draw(self._name, "submit") == "submit_error":
            raise InjectedFault(f"injected submit failure on {self._name}")
        return self._inner.submit(*args, **kw)

    def inflight_snapshot(self) -> Tuple[List[int], float, float]:
        with self._shadow_lock:
            if self._shadow_ids:
                # predicted JCT 0.0: the step already finished, there is no
                # honest prediction left — the watchdog's min_deadline /
                # p95-history floor governs when a shadowed hang trips
                return list(self._shadow_ids), 0.0, self._shadow_t0
        snap = getattr(self._inner, "inflight_snapshot", None)
        return snap() if snap is not None else ([], 0.0, 0.0)

    @property
    def _inflight(self) -> List[int]:
        """Crash accounting the server's worker reads after a step raised:
        a post-step injected crash lost the whole served batch."""
        with self._shadow_lock:
            if self._shadow_ids:
                return list(self._shadow_ids)
        return list(getattr(self._inner, "_inflight", []))

    def step(self) -> Optional[int]:
        if not getattr(self._inner, "queue", None):
            return self._inner.step()        # idle poll: nothing to lose
        kind = self._plan.draw(self._name, "step")
        t0 = time.perf_counter()
        rid = self._inner.step()
        if rid is None or kind is None:
            return rid
        served = list(self._inner.last_step_ids)
        if kind == "nan_score":
            with _lock_of(self._inner):
                for i in served:
                    res = self._inner.results.get(i)
                    if res is None:
                        continue
                    res["corrupt"] = "injected_nan"
                    if res.get("scores"):
                        res["scores"] = {t: float("nan")
                                         for t in res["scores"]}
            return rid
        if kind in ("hang", "straggler"):
            cfg = self._plan.cfg
            dwell = (cfg.hang_seconds if kind == "hang"
                     else cfg.straggler_seconds)
            with self._shadow_lock:
                self._shadow_ids = served
                # dwell start, NOT the real step's t0: whether an injected
                # dwell trips the watchdog must depend only on (dwell,
                # deadline), never on how long the honest forward happened
                # to take — otherwise a large packed batch plus a small
                # straggler crosses min_deadline and kills a healthy
                # instance nondeterministically
                self._shadow_t0 = time.perf_counter()
            try:
                time.sleep(dwell)
            finally:
                with self._shadow_lock:
                    self._shadow_ids = []
            return rid
        # step_error: the crash landed after the forward — results are gone,
        # the batch reads as in-flight, and step() dies like the chip did
        with _lock_of(self._inner):
            for i in served:
                self._inner.results.pop(i, None)
        with self._shadow_lock:
            self._shadow_ids = served        # never cleared: instance dies
            self._shadow_t0 = t0
        raise InjectedFault(f"injected step crash on {self._name}")


def _lock_of(eng):
    lock = getattr(eng, "lock", None)
    if lock is not None:
        return lock
    import contextlib
    return contextlib.nullcontext()


class ProcessChaosEngine:
    """Process-level fault injector for a ``RemoteEngine``.

    Wraps the pool entry; every driven step with believed-queued work draws
    from the ``pstep`` stream. ``kill``/``freeze`` fire a timer that
    signals the worker PROCESS ``delay`` seconds into the step — i.e. mid-
    batch, while the RPC is in flight — so the fault lands exactly where a
    real chip lockup or OOM-kill would. Everything else proxies through:
    the server, router, watchdog, and pool drive the remote engine
    unchanged.
    """

    def __init__(self, inner, name: str, plan: FaultPlan, pid_of,
                 delay: float = 0.02):
        self._inner = inner
        self._name = name
        self._plan = plan
        self._pid_of = pid_of     # supervisor.pid_of — tracks restarts
        self._delay = delay

    def __getattr__(self, attr):
        return getattr(self._inner, attr)

    def step(self) -> Optional[int]:
        if getattr(self._inner, "queue", None):
            kind = self._plan.draw(self._name, "pstep")
            if kind in PROCESS_FAULTS:
                pid = self._pid_of(self._name)
                if pid is not None:
                    t = threading.Timer(self._delay, self._fire,
                                        args=(kind, pid))
                    t.daemon = True
                    t.start()
        return self._inner.step()

    def _fire(self, kind: str, pid: int) -> None:
        try:
            if kind == "kill":
                os.kill(pid, signal.SIGKILL)
            else:
                os.kill(pid, signal.SIGSTOP)
                t = threading.Timer(self._plan.cfg.freeze_seconds,
                                    self._thaw, args=(pid,))
                t.daemon = True
                t.start()
        except (ProcessLookupError, PermissionError):
            pass      # already dead/restarted: the fault found a corpse

    def _thaw(self, pid: int) -> None:
        try:
            os.kill(pid, signal.SIGCONT)
        except (ProcessLookupError, PermissionError):
            pass


def wrap_pool_processes(pool, plan: FaultPlan, sup, delay: float = 0.02):
    """Wrap every RemoteEngine of a process pool in a ProcessChaosEngine
    (kill/freeze). Pair with ``rpc_fault_hook=plan.rpc_fault`` on the
    supervisor for response drop/delay faults. Returns ``pool``."""
    for name in list(pool.engines):
        eng = pool.engines[name]
        if not isinstance(eng, ProcessChaosEngine):
            pool.engines[name] = ProcessChaosEngine(eng, name, plan,
                                                    sup.pid_of, delay)
    return pool


def wrap_pool(pool, plan: FaultPlan):
    """Wrap every live engine of ``pool`` in a ChaosEngine and chain
    ``pool.make_engine`` so later instances (scale-up, resurrection after a
    chaos kill) are wrapped under the same plan. Returns ``pool``."""
    inner_make = pool.make_engine

    def make(name: str):
        return ChaosEngine(inner_make(name), name, plan)

    pool.make_engine = make
    for name in list(pool.engines):
        eng = pool.engines[name]
        if not isinstance(eng, ChaosEngine):
            pool.engines[name] = ChaosEngine(eng, name, plan)
    return pool
