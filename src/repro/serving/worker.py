"""Engine worker process — one engine, one RPC listener, one lease.

``python -m repro.serving.worker --name inst0 --port-file /tmp/p.json
--spec '{"kind": "fake", ...}'`` owns ONE engine instance and serves the
cross-process plane's ops over the length-prefixed protocol in
``serving.rpc``. The frontend (``serving.supervisor.RemoteEngine``) drives
it exactly like an in-process engine: the AsyncServer worker thread calls
``step`` over the wire, the router probes over the wire, the supervisor
heartbeats over the wire. The worker is PASSIVE — it never steps itself —
so a worker that is never stepped again (marked failed after a dropped
response) can never double-deliver: exactly-once is structural, not
cooperative.

Crash-safety contract:
  * req_ids are CLIENT-assigned (one counter per frontend process), carried
    in the submit payload. ``submit`` dedupes by rid, so the client may
    blindly re-send on connection errors — prefill-only idempotence end to
    end (paper §2: one stateless forward, one token).
  * deadlines cross the boundary as DELTAS (seconds-from-now), because
    ``time.perf_counter`` origins differ per process; the worker re-anchors
    them on its own clock. Transit time only shrinks the remaining budget —
    the conservative direction.
  * every response that carries timestamps also carries ``now`` (the
    worker's clock at response build), so the client can map worker times
    onto its own clock with a one-way-transit error bound.
  * SIGTERM = graceful drain: stop accepting submits, keep serving step/
    harvest RPCs until the queue and in-flight work are empty (bounded by
    ``--drain-grace``), exit 0.
  * lease: if no supervisor heartbeat arrives for ``--lease`` seconds the
    worker self-exits — an orphaned worker (supervisor SIGKILLed) must not
    linger and serve stale state to a restarted plane.

Telemetry crosses the boundary in two export queues: the worker-side
``SpanTracer`` never binds a request (the frontend owns the timelines), so
every engine span/event lands in its orphan buffer, which ``step`` drains
into the response for frontend replay; the worker-side ``MetricsRegistry``
rides the heartbeat as a ``dump_state`` snapshot the frontend merges.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import sys
import threading
import time
from typing import Dict, List, Optional

from repro.serving.rpc import recv_msg, send_msg


# ---- engines ----------------------------------------------------------------

class FakeWorkerEngine:
    """Deterministic protocol double (no jax import): step() sleeps
    ``sec_per_token`` per queued token. Mirrors the serving tests' fake so
    process-plane chaos tests measure the PLANE, not model compute."""

    class _ECfg:
        def __init__(self, block_size: int = 16):
            self.block_size = block_size

    def __init__(self, name: str, sec_per_token: float = 2e-4,
                 block_size: int = 16):
        self.name = name
        self.ecfg = self._ECfg(block_size)
        self.lock = threading.RLock()
        self.queue: List = []
        self.results: Dict[int, Dict] = {}
        self._last: List[int] = []
        self.a = sec_per_token
        self.steps = 0
        self._inflight: List[int] = []
        self._inflight_pred = 0.0
        self._inflight_t0 = 0.0
        self._step_compiled = False
        self.degraded = False

    def cancel(self, rid: int):
        with self.lock:
            for i, r in enumerate(self.queue):
                if r.req_id == rid:
                    return self.queue.pop(i)
        return None

    def shed_expired(self, now: Optional[float] = None) -> List:
        now = time.perf_counter() if now is None else now
        shed: List = []
        with self.lock:
            keep = []
            for r in self.queue:
                doomed = (r.deadline is not None
                          and now + self.a * r.n_input > r.deadline)
                (shed if doomed else keep).append(r)
            self.queue[:] = keep
        return shed

    def pending_jct(self, now: Optional[float] = None) -> float:
        with self.lock:
            queued = sum(self.a * r.n_input for r in self.queue)
            running = 0.0
            if self._inflight:
                running = max(0.0, self._inflight_pred - (
                    time.perf_counter() - self._inflight_t0))
            return queued + running

    def predict_jct(self, n: int, chain=()) -> float:
        return self.a * n

    def cached_prefix_len(self, chain) -> int:
        return 0

    def probe(self, n_input: int, chain=()):
        return self.pending_jct(), self.predict_jct(n_input, chain), 0

    def inflight_snapshot(self):
        with self.lock:
            return (list(self._inflight), self._inflight_pred,
                    self._inflight_t0)

    def set_degraded(self, flag: bool) -> None:
        self.degraded = bool(flag)

    def step(self) -> Optional[int]:
        with self.lock:
            if not self.queue:
                return None
            r = self.queue.pop(0)
            self._inflight = [r.req_id]
            self._inflight_pred = self.a * r.n_input
            self._inflight_t0 = time.perf_counter()
        time.sleep(self.a * r.n_input)
        r.finish_time = time.perf_counter()
        with self.lock:
            res = {"req_id": r.req_id, "latency": r.latency, "n_cached": 0,
                   "n_input": r.n_input, "deadline": r.deadline, "token": 5}
            if r.allowed_tokens:
                res["scores"] = {int(t): 1.0 / len(r.allowed_tokens)
                                 for t in r.allowed_tokens}
            self.results[r.req_id] = res
            self._last = [r.req_id]
            self._inflight = []
            self._inflight_pred = 0.0
            self.steps += 1
        return r.req_id

    @property
    def last_step_ids(self) -> List[int]:
        return list(self._last)

    def stats(self) -> Dict:
        return {"steps": self.steps}


def build_engine(name: str, spec: Dict):
    """Engine from a JSON spec. ``fake`` is import-light (tests of the
    plane itself); ``engine`` builds the real PrefillOnly engine the way
    ``launch.serve.make_pool`` does (jax imported lazily here so fake
    workers start in milliseconds)."""
    kind = spec.get("kind", "fake")
    if kind == "fake":
        return FakeWorkerEngine(
            name, sec_per_token=float(spec.get("sec_per_token", 2e-4)),
            block_size=int(spec.get("block_size", 16)))
    assert kind == "engine", kind
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.core.engine import EngineConfig, PrefillOnlyEngine
    from repro.models.model import build
    from repro.runtime.sharding import materialize

    cfg = get_config(spec.get("arch", "qwen1.5-0.5b"))
    if spec.get("reduced", True):
        cfg = reduce_config(cfg, hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(int(spec.get("seed", 0))),
                         api.defs(), jnp.float32)
    overrides = dict(spec.get("ecfg") or {})
    for k, v in overrides.items():       # JSON has no tuples
        if isinstance(v, list):
            overrides[k] = tuple(v)
    kw = {"policy": spec.get("policy", "srjf_calibrated"),
          "lam": float(spec.get("lam", 0.05)),
          "cache_capacity_tokens": int(spec.get("cache_tokens", 4096))}
    kw.update(overrides)                 # spec["ecfg"] wins over shorthands
    eng = PrefillOnlyEngine(cfg, params, EngineConfig(**kw))
    if spec.get("profile"):
        eng.profile(tuple(spec.get("profile_lengths", (32, 64, 128))))
    return eng


# ---- the worker -------------------------------------------------------------

class EngineWorker:
    """One engine behind one listener; see the module docstring."""

    def __init__(self, name: str, engine, *, lease: float = 30.0,
                 drain_grace: float = 5.0, host: str = "127.0.0.1"):
        self.name = name
        self.engine = engine
        self.lease = lease
        self.drain_grace = drain_grace
        self._draining = False
        self._drain_t0 = 0.0
        self._last_beat = time.perf_counter()
        self._exit = threading.Event()
        self._seen_rids: set = set()
        self._seen_order: List[int] = []       # FIFO bound on the dedupe set
        self._sub_lock = threading.Lock()
        # telemetry export queues (worker side of the bridge)
        from repro.serving.metrics import MetricsRegistry
        from repro.serving.tracing import SpanTracer
        self.registry = MetricsRegistry()
        self.tracer = SpanTracer(capacity=16, batch_capacity=1024,
                                 orphan_capacity=8192)
        bind = getattr(engine, "bind_telemetry", None)
        if bind is not None:
            bind(metrics=self.registry, instance=name, tracer=self.tracer)
        self.srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.srv.bind((host, 0))
        self.srv.listen(64)
        self.port = self.srv.getsockname()[1]

    # ---- ops -------------------------------------------------------------
    def _mk_request(self, p: Dict, now: float):
        """A Request mirroring the client's, re-anchored on this clock:
        rid comes FROM the payload (client-assigned — never the shared
        counter, which would collide across worker processes), the deadline
        from its delta, the arrival from its age (so the scheduler's
        starvation offset keeps crediting time queued elsewhere)."""
        from repro.core.prefix_cache import token_chain
        from repro.core.scheduler import Request
        tokens = list(p["tokens"])
        bs = self.engine.ecfg.block_size
        chain = (tuple(token_chain(tokens, bs))
                 if getattr(self.engine, "cache", None) is not None else ())
        allowed = p.get("allowed_tokens")
        deadline = (None if p.get("deadline_delta") is None
                    else now + float(p["deadline_delta"]))
        return Request(
            n_input=len(tokens),
            arrival=now - float(p.get("arrival_age", 0.0) or 0.0),
            chain=chain, tokens=tokens, req_id=int(p["rid"]),
            user_id=p.get("user_id"),
            allowed_tokens=tuple(allowed) if allowed else None,
            deadline=deadline)

    def _enqueue_one(self, p: Dict, now: float) -> bool:
        """Dedupe + enqueue. False = duplicate rid (idempotent replay)."""
        rid = int(p["rid"])
        with self._sub_lock:
            if rid in self._seen_rids:
                return False
            self._seen_rids.add(rid)
            self._seen_order.append(rid)
            if len(self._seen_order) > 65536:
                self._seen_rids.discard(self._seen_order.pop(0))
        r = self._mk_request(p, now)
        eng = self.engine
        with eng.lock:
            cache = getattr(eng, "cache", None)
            if cache is not None:
                # probe, don't match: on a tiered cache an eager match here
                # would restore host blocks inside the submit RPC
                r.n_cached_at_arrival = (
                    cache.probe_len(r.chain)
                    if hasattr(cache, "probe_len")
                    else cache.match_len(r.chain))
            eng.queue.append(r)
        return True

    def _op_submit(self, p: Dict) -> Dict:
        if self._draining:
            raise RuntimeError("draining: worker refuses new work")
        now = time.perf_counter()
        fresh = self._enqueue_one(p, now)
        return {"rid": int(p["rid"]), "dup": not fresh, "now": now}

    def _op_requeue(self, p: Dict) -> Dict:
        """Batch re-home from a dead peer's shadow queue. Same dedupe as
        submit (re-homing is a re-send of work this worker may have seen)."""
        if self._draining:
            raise RuntimeError("draining: worker refuses new work")
        now = time.perf_counter()
        accepted = [int(q["rid"]) for q in p["requests"]
                    if self._enqueue_one(q, now)]
        return {"accepted": accepted, "now": now}

    def _op_cancel(self, p: Dict) -> Dict:
        r = self.engine.cancel(int(p["rid"]))
        return {"found": r is not None,
                "user_id": getattr(r, "user_id", None)}

    def _op_shed_expired(self, p: Dict) -> Dict:
        shed = self.engine.shed_expired()
        return {"shed": [{"rid": r.req_id, "user_id": r.user_id}
                         for r in shed]}

    def _op_step(self, p: Dict) -> Dict:
        eng = self.engine
        t0 = time.perf_counter()
        try:
            rid = eng.step()
        except Exception as e:      # engine crash != protocol crash: report
            return {"crashed": f"{type(e).__name__}: {e}",
                    "inflight": list(getattr(eng, "_inflight", [])),
                    "now": time.perf_counter()}
        out: Dict = {"rid": rid,
                     "step_seconds": time.perf_counter() - t0,
                     "compiled": bool(getattr(eng, "_step_compiled", False))}
        served = []
        if rid is not None:
            with eng.lock:
                served = [[i, eng.results.pop(i, None)]
                          for i in eng.last_step_ids]
                out["depth"] = len(eng.queue)
        else:
            with eng.lock:
                out["depth"] = len(eng.queue)
        out["served"] = served
        out["pending_jct"] = eng.pending_jct()
        out["orphans"] = [[r, t, n, a]
                          for r, t, n, a in self.tracer.drain_orphans()]
        out["batches"] = [b.to_dict() for b in self.tracer.drain_batches()]
        out["now"] = time.perf_counter()
        return out

    def _op_probe(self, p: Dict) -> Dict:
        eng = self.engine
        n_input = int(p.get("n_input", 0))
        # chains are hash chains over int tuples — Python int/tuple hashing
        # is NOT seed-salted, so a chain cut in the frontend process is
        # valid here as long as the block sizes agree (hello reports ours)
        chain = tuple(p.get("chain") or ())
        if not chain and p.get("tokens") \
                and getattr(eng, "cache", None) is not None:
            from repro.core.prefix_cache import token_chain
            chain = tuple(token_chain(list(p["tokens"]),
                                      eng.ecfg.block_size))
        probe = getattr(eng, "probe", None)
        if probe is not None:
            pending, predict, cached = probe(n_input, chain)
        else:
            pending = eng.pending_jct()
            predict = eng.predict_jct(n_input, chain)
            cached = eng.cached_prefix_len(chain)
        return {"pending_jct": pending, "predict_jct": predict,
                "cached_prefix_len": cached, "now": time.perf_counter()}

    def _op_heartbeat(self, p: Dict) -> Dict:
        self._last_beat = time.perf_counter()
        if p.get("lease") is not None:
            self.lease = float(p["lease"])
        eng = self.engine
        snap = getattr(eng, "inflight_snapshot", None)
        ids, pred, t0 = snap() if snap is not None else ([], 0.0, 0.0)
        now = time.perf_counter()
        out = {"pid": os.getpid(), "now": now, "name": self.name,
               "inflight": list(ids), "inflight_pred": pred,
               "inflight_elapsed": (now - t0) if ids else 0.0,
               "pending_jct": eng.pending_jct(),
               "draining": self._draining}
        with eng.lock:
            out["depth"] = len(eng.queue)
        host = getattr(getattr(eng, "cache", None), "host", None)
        if host is not None:     # tier occupancy rides every heartbeat
            out["host_kv"] = host.stats()
        if p.get("want_metrics", True):
            out["metrics"] = self.registry.dump_state()
        if p.get("want_stats"):
            try:
                out["stats"] = eng.stats()
            except Exception:
                out["stats"] = None
        return out

    def _op_prefetch(self, p: Dict) -> Dict:
        """Router-time offload-tier ops: ``estimate`` prices the restorable
        host prefix (admission), otherwise kick the async host->device
        prefetch. No-ops (zeros) on engines without a tier."""
        eng = self.engine
        chain = tuple(p.get("chain") or ())
        if p.get("estimate"):
            est_fn = getattr(eng, "restore_estimate", None)
            est = (est_fn(chain) if est_fn is not None
                   else {"device_blocks": 0, "blocks": 0, "bytes": 0,
                         "restore_s": 0.0})
            est["now"] = time.perf_counter()
            return est
        pf = getattr(eng, "prefetch_prefix", None)
        rid = p.get("rid")
        blocks = pf(chain, rid=int(rid) if rid is not None else None) \
            if pf is not None else 0
        return {"blocks": int(blocks), "now": time.perf_counter()}

    def _op_set_degraded(self, p: Dict) -> Dict:
        set_deg = getattr(self.engine, "set_degraded", None)
        if set_deg is not None:
            set_deg(bool(p.get("flag")))
        return {}

    def _op_stats(self, p: Dict) -> Dict:
        return {"stats": self.engine.stats(),
                "metrics": self.registry.dump_state(),
                "now": time.perf_counter()}

    def _op_hello(self, p: Dict) -> Dict:
        # offload: duck-typed (a tiered cache carries a host store) so the
        # fake engine stays import-light; the frontend uses the flag to
        # skip prefetch/estimate RPCs entirely on un-tiered workers
        return {"pid": os.getpid(), "name": self.name,
                "block_size": self.engine.ecfg.block_size,
                "offload": getattr(
                    getattr(self.engine, "cache", None), "host", None)
                is not None,
                "now": time.perf_counter()}

    def _op_shutdown(self, p: Dict) -> Dict:
        self.begin_drain()
        return {"draining": True}

    # ---- serving loop ----------------------------------------------------
    _OPS = {"hello": _op_hello, "submit": _op_submit,
            "requeue": _op_requeue, "cancel": _op_cancel,
            "shed_expired": _op_shed_expired, "step": _op_step,
            "probe": _op_probe, "heartbeat": _op_heartbeat,
            "prefetch": _op_prefetch,
            "set_degraded": _op_set_degraded, "stats": _op_stats,
            "shutdown": _op_shutdown}

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while True:
                msg = recv_msg(conn)
                op = msg.get("op", "")
                fn = self._OPS.get(op)
                if fn is None:
                    send_msg(conn, {"ok": False,
                                    "error": f"unknown op {op!r}"})
                    continue
                try:
                    out = fn(self, msg)
                except Exception as e:
                    send_msg(conn, {"ok": False,
                                    "error": f"{type(e).__name__}: {e}"})
                    continue
                send_msg(conn, {"ok": True, "out": out})
        except Exception:
            pass      # peer gone / torn frame: this connection is done
        finally:
            conn.close()

    def _accept_loop(self) -> None:
        while not self._exit.is_set():
            try:
                conn, _ = self.srv.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def begin_drain(self) -> None:
        if not self._draining:
            self._draining = True
            self._drain_t0 = time.perf_counter()

    def _drained(self) -> bool:
        eng = self.engine
        with eng.lock:
            empty = not eng.queue and not getattr(eng, "_inflight", [])
        return empty

    def run(self, port_file: Optional[str] = None) -> int:
        """Serve until drained (SIGTERM) or orphaned (lease expiry)."""
        signal.signal(signal.SIGTERM, lambda *_: self.begin_drain())
        threading.Thread(target=self._accept_loop, daemon=True).start()
        if port_file:
            tmp = port_file + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"port": self.port, "pid": os.getpid(),
                           "name": self.name}, f)
            os.replace(tmp, port_file)    # atomic: readers never see a torn file
        print(f"worker {self.name}: pid={os.getpid()} port={self.port}",
              flush=True)
        while True:
            time.sleep(0.05)
            now = time.perf_counter()
            if self._draining:
                if self._drained() or (now - self._drain_t0
                                       > self.drain_grace):
                    print(f"worker {self.name}: drained, exiting",
                          flush=True)
                    return 0
            if self.lease > 0 and now - self._last_beat > self.lease:
                print(f"worker {self.name}: lease expired "
                      f"({self.lease:.1f}s without heartbeat) — orphaned, "
                      f"exiting", file=sys.stderr, flush=True)
                return 2


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--name", required=True)
    ap.add_argument("--spec", default='{"kind": "fake"}',
                    help="engine spec JSON (kind: fake | engine)")
    ap.add_argument("--port-file", default=None,
                    help="write {port, pid} JSON here once listening")
    ap.add_argument("--lease", type=float, default=30.0,
                    help="self-exit after this many heartbeat-less seconds "
                         "(0 disables)")
    ap.add_argument("--drain-grace", type=float, default=5.0,
                    help="max seconds to wait out the queue after SIGTERM")
    args = ap.parse_args()
    engine = build_engine(args.name, json.loads(args.spec))
    worker = EngineWorker(args.name, engine, lease=args.lease,
                          drain_grace=args.drain_grace)
    return worker.run(args.port_file)


if __name__ == "__main__":
    sys.exit(main())
