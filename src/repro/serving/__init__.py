"""Async serving subsystem: JCT-aware routing, admission control, telemetry.

The deployment shape of paper §7.1 — N single-copy PrefillOnly instances
behind a router — as a first-class layer:

  server.AsyncServer      worker thread per engine, submit() -> Future,
                          deadlines + cancellation, drain/shutdown, health
  router                  user-hash rendezvous | JCT-aware least-backlog
                          with cache-affinity tie-break
  admission               MIL + deadline feasibility -> typed Rejected
  metrics                 counters / gauges / fixed-bucket histograms,
                          per-instance and global, text dump
  chaos                   seeded deterministic fault injection (step crash,
                          hang, straggler, NaN corruption, submit failure;
                          process mode: SIGKILL, SIGSTOP freeze, RPC
                          response drop/delay)
  robustness              idempotent retry (RetryPolicy), JCT-deadline
                          watchdog, brownout ladder (BrownoutController)
  worker / rpc /          cross-process plane: engine worker processes
  supervisor              behind a length-prefixed localhost RPC boundary,
                          heartbeat-lease failure detection, supervised
                          restart with crash-loop budget
"""
from repro.serving.admission import (AdmissionController,          # noqa: F401
                                     BrownoutController, Rejected)
from repro.serving.chaos import (ChaosConfig, ChaosEngine,         # noqa: F401
                                 FaultPlan, InjectedFault,
                                 wrap_pool, wrap_pool_processes)
from repro.serving.metrics import (Counter, Gauge, Histogram,      # noqa: F401
                                   MetricsRegistry, StateGauge)
from repro.serving.router import (LeastBacklogRouter,              # noqa: F401
                                  UserHashRouter, get_router)
from repro.serving.rpc import (RpcClient, RpcClosed, RpcDropped,   # noqa: F401
                               RpcError, RpcRemoteError, RpcTimeout)
from repro.serving.server import AsyncServer, RetryPolicy          # noqa: F401
from repro.serving.supervisor import (RemoteEngine,                # noqa: F401
                                      WorkerSupervisor,
                                      make_process_pool,
                                      wire_supervisor)
from repro.serving.tracing import (BatchRecord,                    # noqa: F401
                                   JCTCalibrationMonitor, SpanTracer)
