"""Length-prefixed JSON RPC over localhost TCP — the worker-plane wire.

The cross-process serving plane needs exactly one transport property the
in-process thread pool never did: a call into a worker that was SIGKILLed,
SIGSTOPped, or wedged must come back as a *typed, bounded-time error* the
caller can route into the existing retry/confiscation stack, never as an
indefinite hang. Everything here serves that:

  framing      4-byte big-endian length + JSON body. One frame per message;
               a torn frame (peer died mid-write) raises ``RpcClosed``.
  RpcClient    thread-safe client with connection REUSE (a free-list of
               sockets — each call checks one out, so concurrent callers
               from the serving worker thread, the supervisor heartbeat
               thread, and router probes never share a socket mid-frame),
               per-call timeouts, and BOUNDED retries on connection errors
               for ops the worker dedupes (submit is idempotent by rid).
  fault hook   ``fault_hook(op)`` lets the chaos harness drop or delay
               responses at the client edge — the worker processed the
               request, the caller never learns — which is exactly the
               network fault a real deployment sees.

Timeout discipline: a timed-out socket is CLOSED, never returned to the
free list (its response may still arrive and would corrupt the next call's
framing). The caller decides what a timeout means — for ``step`` it means
the batch is lost (idempotent re-submission is safe); for ``heartbeat`` it
is one missed beat.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

_LEN = struct.Struct(">I")
MAX_FRAME = 64 * 1024 * 1024


class RpcError(RuntimeError):
    """Transport-level failure (connect refused, peer died, bad frame)."""


class RpcClosed(RpcError):
    """Peer closed the connection mid-frame (process death mid-call)."""


class RpcTimeout(RpcError):
    """Per-call deadline exceeded (frozen/wedged worker)."""


class RpcRemoteError(RpcError):
    """The worker handled the frame and returned an application error."""


class RpcDropped(RpcError):
    """Chaos: the response was dropped at the client edge (the worker DID
    process the request — callers must treat this as 'unknown outcome')."""


def _json_default(o):
    """Engine stats carry numpy scalars; coerce anything float-like, fall
    back to repr so a weird payload degrades to a string, never a crash."""
    try:
        return float(o)
    except Exception:
        return repr(o)


def send_msg(sock: socket.socket, obj: Dict) -> None:
    body = json.dumps(obj, separators=(",", ":"),
                      default=_json_default).encode()
    if len(body) > MAX_FRAME:
        raise RpcError(f"frame too large: {len(body)}")
    sock.sendall(_LEN.pack(len(body)) + body)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout as e:
            raise RpcTimeout(str(e) or "recv timed out")
        if not chunk:
            raise RpcClosed(f"peer closed after {len(buf)}/{n} bytes")
        buf.extend(chunk)
    return bytes(buf)


def recv_msg(sock: socket.socket) -> Dict:
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if n > MAX_FRAME:
        raise RpcError(f"frame too large: {n}")
    return json.loads(_recv_exact(sock, n))


class RpcClient:
    """Thread-safe RPC client with connection reuse and bounded retries.

    ``call(op, timeout=..., retries=...)`` retries ONLY on connection-level
    errors (refused / peer closed before a response byte arrived), never on
    ``RpcTimeout`` — a timeout means the worker may still be executing, and
    blind re-send would double work the caller is about to confiscate.
    Retries sleep ``retry_backoff * 2**k`` between attempts.
    """

    def __init__(self, host: str, port: int, *,
                 connect_timeout: float = 5.0, retry_backoff: float = 0.02,
                 fault_hook: Optional[Callable[[str], Optional[Tuple[str,
                                               float]]]] = None):
        self.host = host
        self.port = port
        self.connect_timeout = connect_timeout
        self.retry_backoff = retry_backoff
        self.fault_hook = fault_hook
        self._free: List[socket.socket] = []
        self._lock = threading.Lock()
        self._closed = False
        self.calls = 0
        self.reconnects = 0

    # ---- connection pool -------------------------------------------------
    def _checkout(self) -> socket.socket:
        with self._lock:
            if self._closed:
                raise RpcError("client closed")
            if self._free:
                return self._free.pop()
        self.reconnects += 1
        try:
            s = socket.create_connection((self.host, self.port),
                                         timeout=self.connect_timeout)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return s
        except OSError as e:
            raise RpcError(f"connect {self.host}:{self.port}: {e}")

    def _checkin(self, s: socket.socket) -> None:
        with self._lock:
            if not self._closed and len(self._free) < 8:
                self._free.append(s)
                return
        s.close()

    def retarget(self, host: str, port: int) -> None:
        """Point at a restarted worker's new address; drops pooled sockets
        (they belong to the dead process)."""
        with self._lock:
            self.host, self.port = host, port
            free, self._free = self._free, []
        for s in free:
            s.close()

    def close(self) -> None:
        with self._lock:
            self._closed = True
            free, self._free = self._free, []
        for s in free:
            s.close()

    # ---- calls -----------------------------------------------------------
    def call(self, op: str, payload: Optional[Dict] = None, *,
             timeout: float = 10.0, retries: int = 0) -> Dict:
        """One RPC; returns the worker's ``out`` dict. Raises a typed
        ``RpcError`` subclass on failure. ``retries`` bounds re-sends on
        connection errors (use only for ops the worker dedupes)."""
        attempt = 0
        while True:
            try:
                return self._call_once(op, payload, timeout)
            except (RpcTimeout, RpcRemoteError, RpcDropped):
                raise
            except RpcError:
                if attempt >= retries:
                    raise
                time.sleep(self.retry_backoff * (2 ** attempt))
                attempt += 1

    def _call_once(self, op: str, payload: Optional[Dict],
                   timeout: float) -> Dict:
        self.calls += 1
        msg = {"op": op}
        if payload:
            msg.update(payload)
        s = self._checkout()
        try:
            s.settimeout(timeout)
            send_msg(s, msg)
            resp = recv_msg(s)
        except Exception as e:
            s.close()     # never reuse a socket in an unknown frame state
            if isinstance(e, RpcError):
                raise
            if isinstance(e, socket.timeout):
                raise RpcTimeout(str(e) or f"{op} timed out")
            if isinstance(e, (OSError, ValueError)):
                # ECONNRESET from a SIGKILLed peer, torn/garbage frame:
                # connection-level, retry-eligible
                raise RpcClosed(f"{op}: {e}") from e
            raise
        fault = self.fault_hook(op) if self.fault_hook is not None else None
        if fault is not None:
            kind, arg = fault
            if kind == "rpc_drop":
                s.close()
                raise RpcDropped(f"chaos dropped {op} response")
            if kind == "rpc_delay":
                time.sleep(arg)
        self._checkin(s)
        if not resp.get("ok"):
            raise RpcRemoteError(resp.get("error", "unknown remote error"))
        return resp.get("out", {})
