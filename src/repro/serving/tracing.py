"""Request-lifecycle tracing + JCT-calibration observability plane.

Every load-bearing decision in this engine — SRJF routing, admission
feasibility, watchdog deadlines, brownout escalation — is derived from the
JCT predictor, yet until this module nothing measured how accurate those
predictions actually were, and no per-request record explained *where* a
slow request spent its time (queue vs batch-formation vs jit-compile vs
compute vs retry). Three pieces close that gap:

  ``SpanTracer``
      a thread-safe, bounded (ring-buffer), monotonic-clock span tracer.
      One ``_Trace`` per request records the full timeline: submit ->
      admission verdict -> route decision (with probe values) -> queue
      dwell -> batch formation (pack kind solo/miss/hit, co-packed peers)
      -> jit-compile (flagged separately) -> execute -> score ->
      deliver/retry/shed/quarantine. The serving layer propagates trace
      context through the retry/watchdog/brownout paths, so trips,
      re-homes, tombstone drops and brownout transitions land as events on
      the affected requests' timelines. Finished traces live in a fixed
      ring (old ones fall off), so tracing is always-on-cheap: no
      allocation growth, one small lock, optional sampling.

  ``BatchRecord``
      per-engine-step pack composition: S/N/smax/pmax/K, padding-waste
      fraction, jit key + compile hit/miss, predicted JCT vs measured wall
      time — the hidden variables behind prefill throughput (Prepacking,
      arXiv 2404.09529) made observable per batch.

  ``JCTCalibrationMonitor``
      online residual tracking of the JCT predictor per bucket class, with
      error histograms and predictor coefficients exported as Prometheus
      gauges, plus a drift detector that forces a refit when the recent
      relative error degrades — closing the loop on the paper's core
      premise that prefill-only JCT is precisely predictable.

Exports: ``dump_jsonl`` (the ``--trace-dump`` endpoint payload, one JSON
object per line, request and batch records), ``chrome_trace`` (a
Chrome-trace/Perfetto-loadable JSON object), and Prometheus series through
the bound ``MetricsRegistry``.

Clock discipline: everything is ``time.perf_counter`` (monotonic), the same
clock the engine stamps ``Request.arrival``/``start_time`` with, so spans
computed across layers never go negative on wall-clock adjustment.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class BatchRecord:
    """Composition + cost of ONE engine step (solo or packed)."""
    step: int                    # engine step index
    ts: float                    # step end, perf_counter seconds
    instance: str = ""
    kind: str = "solo"           # solo | miss | hit (pack class)
    n_requests: int = 1
    req_ids: Tuple[int, ...] = ()
    computed_tokens: int = 0     # miss/suffix tokens actually computed
    padded_tokens: int = 0       # forward slots paid (incl. padding/prefix)
    S: int = 0                   # packed/bucketed sequence length
    Nb: int = 0                  # padded batch rows (packed-hit path)
    smax: int = 0                # per-segment suffix pad (packed-hit path)
    pmax: int = 0                # per-segment prefix pad
    K: int = 0                   # gathered fresh-KV length
    jit_path: str = ""           # fresh | suffix | packed_miss | packed_hit
    jit_key: Tuple = ()
    compiled: bool = False       # this step compiled a fresh jit shape
    predicted_jct: float = 0.0   # model prediction made BEFORE execution
    wall: float = 0.0            # measured forward wall time

    @property
    def padding_waste(self) -> float:
        """Fraction of paid forward slots that were padding slack."""
        if self.padded_tokens <= 0:
            return 0.0
        return 1.0 - min(1.0, self.computed_tokens / self.padded_tokens)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["type"] = "batch"
        d["req_ids"] = list(self.req_ids)
        d["jit_key"] = list(self.jit_key)
        d["padding_waste"] = self.padding_waste
        return d


class _Trace:
    """One request's timeline. Mutated only under the owning tracer's lock."""

    __slots__ = ("tid", "rids", "user_id", "n_input", "t0", "t1", "outcome",
                 "events", "spans", "attrs")

    def __init__(self, tid: int, t0: float, user_id, n_input, attrs):
        self.tid = tid
        self.rids: List[int] = []      # engine req_ids, attempt order
        self.user_id = user_id
        self.n_input = n_input
        self.t0 = t0
        self.t1: Optional[float] = None
        self.outcome: Optional[str] = None
        self.events: List[Tuple[float, str, Dict]] = [(t0, "submit", attrs)]
        self.spans: List[Tuple[str, float, float, Dict]] = []

    def to_dict(self) -> Dict:
        return {
            "type": "request",
            "trace_id": self.tid,
            "req_id": self.rids[0] if self.rids else None,
            "rids": list(self.rids),
            "user_id": self.user_id,
            "n_input": self.n_input,
            "t0": self.t0,
            "t1": self.t1,
            "outcome": self.outcome,
            "attempts": max(1, len(self.rids)),
            "events": [{"t": t, "name": n, **a} for t, n, a in self.events],
            "spans": [{"name": n, "t0": a, "t1": b, "dur": b - a, **at}
                      for n, a, b, at in sorted(
                          self.spans, key=lambda s: (s[1], -s[2]))],
        }


class SpanTracer:
    """Bounded, thread-safe request-lifecycle tracer.

    * ``begin()`` opens a trace (optionally pre-bound to an engine req_id)
      and returns a context id; ``bind(ctx, rid)`` attaches the engine's
      req_id once the enqueue assigned one, so layers that only know the
      rid (engine, watchdog, retry) can annotate the same timeline.
    * ``rebind(old_rid, new_rid)`` moves a retried request's trace onto its
      replacement req_id while KEEPING the old mapping — a late result from
      the confiscated attempt then lands on the same timeline (as the
      tombstone-drop event) instead of vanishing.
    * events emitted against a rid the tracer has not seen yet (the worker
      can execute a request before ``submit`` finishes binding it) are held
      in a small bounded orphan buffer and merged at bind time — never
      silently lost, never unbounded.
    * finished traces move to a ring (``capacity``); ``sample`` < 1.0
      drops a deterministic fraction of traces at ``begin`` (every call
      still returns instantly — unsampled contexts are no-ops throughout).

    All public methods are safe to call from any thread and are cheap
    no-ops when the request is unsampled/unknown.
    """

    _NOSAMPLE = -1

    def __init__(self, capacity: int = 2048, sample: float = 1.0,
                 batch_capacity: int = 2048, orphan_capacity: int = 512):
        assert capacity > 0 and 0.0 < sample <= 1.0
        self.capacity = capacity
        self.sample = sample
        self.epoch = time.perf_counter()   # chrome-trace time origin
        self._lock = threading.Lock()
        self._next = 0                     # trace-id counter
        self._seq = 0                      # sampling counter
        self._period = max(1, round(1.0 / sample))
        self._active: Dict[int, _Trace] = {}
        self._by_rid: Dict[int, _Trace] = {}
        self._done: deque = deque(maxlen=capacity)
        self._batches: deque = deque(maxlen=batch_capacity)
        self._orphans: "deque[Tuple[int, float, str, Dict]]" = deque(
            maxlen=orphan_capacity)
        self.begun = 0
        self.finished = 0
        self.sampled_out = 0

    # ---- lifecycle -------------------------------------------------------
    def begin(self, rid: Optional[int] = None, user_id: Optional[str] = None,
              n_input: Optional[int] = None, **attrs) -> int:
        """Open a trace; returns a context id (or a no-op sentinel when the
        trace was sampled out). ``rid`` pre-binds an engine req_id."""
        now = time.perf_counter()
        with self._lock:
            self._seq += 1
            if self.sample < 1.0 and (self._seq % self._period):
                self.sampled_out += 1
                return self._NOSAMPLE
            tid = self._next
            self._next += 1
            tr = _Trace(tid, now, user_id, n_input, attrs)
            self._active[tid] = tr
            self.begun += 1
            if rid is not None:
                self._bind_locked(tr, rid)
            return tid

    def bind(self, ctx: int, rid: int) -> None:
        """Attach engine req_id ``rid`` to trace ``ctx``; merges any events
        the engine emitted against ``rid`` before the bind landed."""
        if ctx == self._NOSAMPLE:
            return
        with self._lock:
            tr = self._active.get(ctx)
            if tr is not None:
                self._bind_locked(tr, rid)

    def _bind_locked(self, tr: _Trace, rid: int) -> None:
        tr.rids.append(rid)
        self._by_rid[rid] = tr
        if self._orphans:
            kept = deque(maxlen=self._orphans.maxlen)
            for orid, t, name, attrs in self._orphans:
                if orid == rid:
                    if name.startswith("span:"):
                        tr.spans.append((name[5:], attrs.pop("_t0", t), t,
                                         attrs))
                    else:
                        tr.events.append((t, name, attrs))
                else:
                    kept.append((orid, t, name, attrs))
            self._orphans = kept

    def rebind(self, old_rid: int, new_rid: int) -> None:
        """Retry re-key: the replacement ``new_rid`` joins ``old_rid``'s
        timeline. The old mapping survives so the confiscated attempt's
        late events still attach to the same trace."""
        with self._lock:
            tr = self._by_rid.get(old_rid)
            if tr is not None:
                tr.rids.append(new_rid)
                self._by_rid[new_rid] = tr

    def finish(self, ctx: int, outcome: str, **attrs) -> None:
        if ctx == self._NOSAMPLE:
            return
        with self._lock:
            tr = self._active.pop(ctx, None)
            if tr is not None:
                self._finish_locked(tr, outcome, attrs)

    def finish_rid(self, rid: int, outcome: str, **attrs) -> None:
        with self._lock:
            tr = self._by_rid.get(rid)
            if tr is not None and self._active.pop(tr.tid, None) is not None:
                self._finish_locked(tr, outcome, attrs)

    def _finish_locked(self, tr: _Trace, outcome: str, attrs: Dict) -> None:
        now = time.perf_counter()
        tr.t1 = now
        tr.outcome = outcome
        tr.events.append((now, "finish", {"outcome": outcome, **attrs}))
        for rid in tr.rids:
            self._by_rid.pop(rid, None)
        self._done.append(tr)
        self.finished += 1

    # ---- annotation ------------------------------------------------------
    def event(self, ctx: int, name: str, **attrs) -> None:
        if ctx == self._NOSAMPLE:
            return
        now = time.perf_counter()
        with self._lock:
            tr = self._active.get(ctx)
            if tr is not None:
                tr.events.append((now, name, attrs))

    def event_rid(self, rid: int, name: str, **attrs) -> None:
        now = time.perf_counter()
        with self._lock:
            tr = self._by_rid.get(rid)
            if tr is not None:
                tr.events.append((now, name, attrs))
            else:
                self._orphans.append((rid, now, name, attrs))

    def postmortem_rid(self, rid: int, name: str, **attrs) -> None:
        """Attach a post-mortem event to the trace that owned ``rid`` even
        after it finished (e.g. a confiscated attempt's late result being
        tombstone-dropped minutes after the replacement delivered). Scans
        the bounded done-ring when the live mapping is gone; falls back to
        the orphan buffer once the trace has fallen off the ring."""
        now = time.perf_counter()
        with self._lock:
            tr = self._by_rid.get(rid)
            if tr is None:
                tr = next((t for t in reversed(self._done)
                           if rid in t.rids), None)
            if tr is not None:
                tr.events.append((now, name, attrs))
            else:
                self._orphans.append((rid, now, name, attrs))

    def span_rid(self, rid: int, name: str, t0: float, t1: float,
                 **attrs) -> None:
        """Record a completed [t0, t1] phase (perf_counter seconds)."""
        with self._lock:
            tr = self._by_rid.get(rid)
            if tr is not None:
                tr.spans.append((name, t0, t1, attrs))
            else:
                attrs["_t0"] = t0
                self._orphans.append((rid, t1, "span:" + name, attrs))

    def broadcast(self, name: str, **attrs) -> None:
        """Attach an event to EVERY active trace (rare transitions only —
        e.g. brownout level changes affect all in-flight requests)."""
        now = time.perf_counter()
        with self._lock:
            for tr in self._active.values():
                tr.events.append((now, name, dict(attrs)))

    def record_batch(self, record: BatchRecord) -> None:
        with self._lock:
            self._batches.append(record)

    # ---- cross-process bridging ------------------------------------------
    def drain_orphans(self) -> List[Tuple[int, float, str, Dict]]:
        """Drain the orphan buffer: ``(rid, t, name, attrs)`` rows, spans
        encoded as ``span:<name>`` with ``attrs['_t0']``. A worker-side
        tracer (no request ever binds, so EVERY engine emission lands here)
        uses this as its export queue — the frontend replays the rows onto
        the real request timelines after mapping the worker clock."""
        with self._lock:
            rows = list(self._orphans)
            self._orphans.clear()
        return rows

    def drain_batches(self) -> List[BatchRecord]:
        """Drain the batch-record ring (worker-side export queue)."""
        with self._lock:
            rows = list(self._batches)
            self._batches.clear()
        return rows

    def ingest_event(self, rid: int, t: float, name: str, **attrs) -> None:
        """``event_rid`` with a caller-supplied timestamp — replaying a
        remote worker's event at its (clock-mapped) original time instead
        of the replay time."""
        with self._lock:
            tr = self._by_rid.get(rid)
            if tr is not None:
                tr.events.append((t, name, attrs))
            else:
                self._orphans.append((rid, t, name, attrs))

    def ingest_span(self, rid: int, name: str, t0: float, t1: float,
                    **attrs) -> None:
        """Like ``span_rid`` but for REMOTE spans whose times crossed a
        clock mapping: clamps the span into the trace's own window so a
        worker/frontend clock-offset estimate off by a transit time can
        never produce a span that starts before its request's submit (which
        would break Perfetto containment)."""
        with self._lock:
            tr = self._by_rid.get(rid)
            if tr is None:
                attrs["_t0"] = t0
                self._orphans.append((rid, t1, "span:" + name, attrs))
                return
            t0 = max(t0, tr.t0)
            tr.spans.append((name, t0, max(t1, t0), attrs))

    # ---- export ----------------------------------------------------------
    def snapshot(self, include_active: bool = False) -> List[Dict]:
        with self._lock:
            out = [tr.to_dict() for tr in self._done]
            if include_active:
                out.extend(tr.to_dict() for tr in self._active.values())
        return out

    def batch_snapshot(self) -> List[Dict]:
        with self._lock:
            return [b.to_dict() for b in self._batches]

    def dump_jsonl(self, include_batches: bool = True,
                   include_active: bool = False) -> str:
        """One JSON object per line: request records, then batch records."""
        rows = self.snapshot(include_active=include_active)
        if include_batches:
            rows.extend(self.batch_snapshot())
        return "\n".join(json.dumps(r, sort_keys=True) for r in rows) + (
            "\n" if rows else "")

    def chrome_trace(self, include_active: bool = False) -> Dict:
        """Chrome-trace (Perfetto-loadable) JSON object.

        pid = serving instance (named via metadata events), tid = trace id.
        Each request contributes one umbrella "request" X-span covering
        submit->finish, nested phase X-spans (queue/execute/score, properly
        contained), and "i" instant events for everything else (retry,
        watchdog_trip, brownout, ...). Batch records land on a dedicated
        "engine-steps" thread per instance so pack composition lines up
        against the requests it served.
        """
        us = 1e6
        pids: Dict[str, int] = {}
        events: List[Dict] = []

        def pid_of(instance: str) -> int:
            if instance not in pids:
                pids[instance] = len(pids) + 1
                events.append({"ph": "M", "name": "process_name",
                               "pid": pids[instance], "tid": 0,
                               "args": {"name": instance or "pool"}})
            return pids[instance]

        def ts(t: float) -> float:
            return max(0.0, (t - self.epoch) * us)

        with self._lock:
            traces = [tr.to_dict() for tr in self._done]
            if include_active:
                traces.extend(tr.to_dict() for tr in self._active.values())
            batches = [b.to_dict() for b in self._batches]
        for tr in traces:
            inst = next((s.get("instance") for s in tr["spans"]
                         if s.get("instance")), "") or next(
                (e.get("instance") for e in tr["events"]
                 if e.get("instance")), "")
            pid = pid_of(inst or "pool")
            tid = tr["trace_id"]
            t1 = tr["t1"] if tr["t1"] is not None else max(
                [tr["t0"]] + [s["t1"] for s in tr["spans"]]
                + [e["t"] for e in tr["events"]])
            events.append({
                "ph": "X", "name": f"request {tr['outcome'] or 'open'}",
                "pid": pid, "tid": tid, "ts": ts(tr["t0"]),
                "dur": max(1.0, (t1 - tr["t0"]) * us),
                "args": {"req_id": tr["req_id"], "user_id": tr["user_id"],
                         "n_input": tr["n_input"],
                         "attempts": tr["attempts"]}})
            for s in tr["spans"]:
                args = {k: v for k, v in s.items()
                        if k not in ("name", "t0", "t1", "dur")}
                events.append({"ph": "X", "name": s["name"], "pid": pid,
                               "tid": tid, "ts": ts(s["t0"]),
                               "dur": max(1.0, s["dur"] * us),
                               "args": args})
            for e in tr["events"]:
                args = {k: v for k, v in e.items() if k not in ("name", "t")}
                events.append({"ph": "i", "s": "t", "name": e["name"],
                               "pid": pid, "tid": tid, "ts": ts(e["t"]),
                               "args": args})
        for inst in sorted({b["instance"] for b in batches}):
            pid = pid_of(inst or "pool")
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": 0, "args": {"name": "engine-steps"}})
        for b in batches:
            pid = pid_of(b["instance"] or "pool")
            events.append({
                "ph": "X", "name": f"step {b['kind']}", "pid": pid,
                "tid": 0, "ts": ts(b["ts"] - b["wall"]),
                "dur": max(1.0, b["wall"] * us),
                "args": {k: b[k] for k in
                         ("step", "n_requests", "req_ids", "computed_tokens",
                          "padded_tokens", "padding_waste", "S", "Nb",
                          "smax", "pmax", "K", "jit_path", "jit_key",
                          "compiled", "predicted_jct", "wall")}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def stats(self) -> Dict:
        with self._lock:
            return {"begun": self.begun, "finished": self.finished,
                    "active": len(self._active),
                    "retained": len(self._done),
                    "batches": len(self._batches),
                    "sampled_out": self.sampled_out,
                    "orphaned": len(self._orphans)}


class JCTCalibrationMonitor:
    """Online accuracy tracking for the JCT predictor.

    The engine reports every WARM (non-compile) step as ``observe(predicted,
    actual, tokens)``. The monitor keeps signed residuals per bucket class
    (the same suffix-bucket ladder the engine jits over, so a misfit shows
    *which* shapes mispredict), exports error histograms and the fitted
    coefficients as Prometheus series when a registry is bound, and runs a
    drift detector: when the mean relative error over the recent window
    degrades past ``drift_threshold``, the predictor is refit immediately
    from its own sliding sample window (instead of waiting out
    ``refit_every``) and the forced refit is counted — mispredictions are
    corrected within a handful of steps instead of silently steering
    routing/admission/watchdog decisions.
    """

    def __init__(self, model, buckets: Sequence[int] = (),
                 window: int = 32, per_bucket: int = 128,
                 drift_threshold: float = 0.5, drift_min: int = 8,
                 cooldown: int = 16, shape_model=None):
        self.model = model
        # optional PackedShapeJCT riding along: its residuals are tracked
        # per PACK CLASS (solo/miss/hit — the three step layouts it prices)
        # and a drift event refits it from its own shape-sample window too
        self.shape_model = shape_model
        self.buckets = tuple(sorted(buckets))
        self.window = window
        self.drift_threshold = drift_threshold
        self.drift_min = drift_min
        self.cooldown = cooldown
        self.drift_refits = 0
        self.observed = 0
        self._recent_rel: deque = deque(maxlen=window)
        self._by_bucket: Dict[int, deque] = {}
        self._by_class: Dict[str, deque] = {}
        self._per_bucket = per_bucket
        self._since_refit = 0
        self._lock = threading.Lock()
        self._metrics = None
        self._instance = ""

    def bind(self, metrics, instance: str = "") -> None:
        """Attach a MetricsRegistry; coefficient gauges are exported
        immediately (a scrape before the first warm step still sees the
        fit) and refreshed on every observation."""
        self._metrics = metrics
        self._instance = instance
        if metrics is not None:
            self._export_coefficients()

    def _bucket(self, tokens: int) -> int:
        for s in self.buckets:
            if tokens <= s:
                return s
        return self.buckets[-1] if self.buckets else tokens

    def _export_coefficients(self) -> None:
        m, inst = self._metrics, self._instance
        model = self.model
        m.gauge("jct_coef_a", inst).set(getattr(model, "a", 0.0))
        m.gauge("jct_coef_b", inst).set(getattr(model, "b", 0.0))
        m.gauge("jct_pearson_r", inst).set(getattr(model, "pearson_r", 0.0))
        m.gauge("jct_refits", inst).set(
            getattr(model, "fits", 0) + self.drift_refits)
        m.gauge("jct_fit_clamped", inst).set(
            getattr(model, "clamped_fits", 0))
        sm = self.shape_model
        if sm is not None:
            for name, c in sm.coefficients().items():
                m.gauge(f"jct_shape_{name}", inst).set(c)
            m.gauge("jct_shape_pearson_r", inst).set(sm.pearson_r)
            m.gauge("jct_shape_refits", inst).set(sm.fits)

    def observe(self, predicted: float, actual: float, tokens: int,
                kind: str = None) -> None:
        resid = actual - predicted
        rel = abs(resid) / max(abs(actual), 1e-9)
        bucket = self._bucket(tokens)
        drifted = False
        with self._lock:
            self.observed += 1
            dq = self._by_bucket.get(bucket)
            if dq is None:
                dq = self._by_bucket[bucket] = deque(maxlen=self._per_bucket)
            dq.append(resid)
            if kind is not None:
                cq = self._by_class.get(kind)
                if cq is None:
                    cq = self._by_class[kind] = deque(
                        maxlen=self._per_bucket)
                cq.append(resid)
            self._recent_rel.append(rel)
            self._since_refit += 1
            if (len(self._recent_rel) >= self.drift_min
                    and self._since_refit >= self.cooldown
                    and (sum(self._recent_rel) / len(self._recent_rel)
                         > self.drift_threshold)):
                drifted = True
                self.drift_refits += 1
                self._recent_rel.clear()
                self._since_refit = 0
        if drifted:
            # refit OUTSIDE the monitor lock (the model has its own state;
            # lstsq over <=256 samples is microseconds)
            recent = getattr(self.model, "_recent", None)
            if recent and len(recent) >= 4:
                self.model.fit(list(recent))
            if self.shape_model is not None:
                self.shape_model.refit_recent()
        m = self._metrics
        if m is not None:
            inst = self._instance
            m.histogram("jct_residual_seconds", inst).observe(abs(resid))
            m.histogram("jct_relative_error", inst).observe(rel)
            if kind is not None:
                m.histogram(f"jct_residual_{kind}_seconds", inst).observe(
                    abs(resid))
            if drifted:
                m.counter("jct_drift_refits", inst).inc()
            self._export_coefficients()

    def summary(self) -> Dict:
        """Coefficients, residual percentiles, refit counts — the JCT-fit
        block surfaced through ``engine.stats()`` and serve results."""
        import numpy as np
        with self._lock:
            all_resid = [r for dq in self._by_bucket.values() for r in dq]
            by_bucket = {
                b: {"count": len(dq),
                    "mean_abs": float(np.mean(np.abs(dq))) if dq else 0.0,
                    "p95_abs": float(np.percentile(np.abs(list(dq)), 95))
                    if dq else 0.0}
                for b, dq in sorted(self._by_bucket.items())}
            by_class = {
                k: {"count": len(dq),
                    "mean_abs": float(np.mean(np.abs(dq))) if dq else 0.0,
                    "p95_abs": float(np.percentile(np.abs(list(dq)), 95))
                    if dq else 0.0}
                for k, dq in sorted(self._by_class.items())}
            drift = self.drift_refits
            observed = self.observed
        absr = np.abs(all_resid) if all_resid else None
        model = self.model
        out = {
            "a": float(getattr(model, "a", 0.0)),
            "b": float(getattr(model, "b", 0.0)),
            "pearson_r": float(getattr(model, "pearson_r", 0.0)),
            "observed": observed,
            "refits": int(getattr(model, "fits", 0)),
            "clamped_fits": int(getattr(model, "clamped_fits", 0)),
            "drift_refits": drift,
            "residual_p50": float(np.percentile(absr, 50))
            if absr is not None else 0.0,
            "residual_p95": float(np.percentile(absr, 95))
            if absr is not None else 0.0,
            "by_bucket": by_bucket,
            "by_class": by_class,
        }
        if self.shape_model is not None:
            sm = self.shape_model
            out["shape"] = {"coef": sm.coefficients(),
                            "pearson_r": float(sm.pearson_r),
                            "refits": int(sm.fits),
                            "fitted": bool(sm.fitted)}
        return out
