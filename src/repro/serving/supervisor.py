"""Crash-safe cross-process serving plane: RemoteEngine + WorkerSupervisor.

Promotes ``InstancePool`` members from in-process engines to supervised
engine WORKER PROCESSES behind the ``serving.rpc`` boundary, without
changing ``AsyncServer`` at all: ``RemoteEngine`` implements the engine
protocol the server's worker threads, router, watchdog, and retry stack
already speak (``lock/queue/results/submit/step/shed_expired/pending_jct/
predict_jct/cached_prefix_len/inflight_snapshot/...``), so every existing
recovery path — idempotent retry, confiscation tombstones, JCT watchdog,
brownout — now exercises REAL process death (kill -9, SIGSTOP, dropped RPC
responses) instead of simulated exceptions.

Why exactly-once survives a kill -9 with no distributed log:

  * req_ids are assigned in the FRONTEND process (one shared counter), so a
    rid is globally unique across workers and restarts; workers dedupe
    submits by rid, making blind re-send on connection errors safe.
  * stepping is PULL-model: the frontend drives ``step()`` over RPC. An
    instance whose step call failed is marked failed and never stepped or
    harvested again, so results stranded in a zombie worker can never be
    delivered — a restarted worker is a fresh process with an empty queue.
  * ``RemoteEngine`` keeps a client-side SHADOW QUEUE of submitted-but-
    unserved requests. On death, ``InstancePool._drain`` re-homes the
    shadow to healthy peers (futures intact); the subset the last heartbeat
    reported IN-FLIGHT is excluded from the drain and handed to the
    server's ``_handle_lost`` instead — the two recovery paths are disjoint
    by construction, so a request is re-owned exactly once.

Failure detection is heartbeat leases: the supervisor beats every worker at
``heartbeat_interval``; ``miss_budget`` consecutive misses (or process
exit) declares death. Death means SIGKILL FIRST — a SIGSTOPped worker
gives no TCP reset until it dies, and that reset is what unblocks a
frontend thread mid-``step`` — then the death callback (``mark_failed``),
then a scheduled restart with exponential backoff under a crash-loop
budget. The lease is symmetric: a worker that stops hearing heartbeats
(orphaned by a dead supervisor) self-exits.

Heartbeats also carry the worker's ``inflight_snapshot`` (ids, predicted
JCT, elapsed-at-send), so the JCTDeadlineWatchdog scan works across the
process boundary: the frontend re-anchors ``t0 = recv - elapsed`` on its
own clock (error = one-way transit, which only makes the batch look
OLDER — the safe direction), and a frozen worker's snapshot goes stale
while its elapsed keeps growing, which is exactly what trips the scan.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import subprocess
import sys
import tempfile
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler import Request, _req_counter
from repro.runtime.fault_tolerance import InstancePool
from repro.serving.rpc import (RpcClient, RpcDropped, RpcError,
                               RpcRemoteError)
from repro.serving.tracing import BatchRecord

_BATCH_FIELDS = {f.name for f in dataclasses.fields(BatchRecord)}


class _ECfg:
    __slots__ = ("block_size",)

    def __init__(self, block_size: int):
        self.block_size = block_size


class RemoteEngine:
    """Client-side proxy speaking the engine protocol for one worker.

    The shadow queue (``self.queue`` + ``self._shadow``) mirrors every
    request this proxy believes is queued worker-side; harvest/shed/cancel
    remove mirrors, death hands them to ``drain_queue``. Probe results are
    cached for ``probe_ttl`` so router scans cost at most one RPC per
    instance per staleness window instead of three per candidate.
    """

    def __init__(self, name: str, client: RpcClient, *,
                 block_size: int = 16, step_timeout: float = 300.0,
                 submit_timeout: float = 30.0, probe_timeout: float = 5.0,
                 probe_ttl: float = 0.05):
        self.name = name
        self.rpc = client
        self.ecfg = _ECfg(block_size)
        self.step_timeout = step_timeout
        self.submit_timeout = submit_timeout
        self.probe_timeout = probe_timeout
        self.probe_ttl = probe_ttl
        self.lock = threading.RLock()
        self.queue: List[Request] = []        # shadow mirror (ordered)
        self.results: Dict[int, Dict] = {}
        self._shadow: Dict[int, Request] = {}
        self._last: List[int] = []
        self._dead = False
        self._crash_inflight: List[int] = []
        self._hb: Tuple[List[int], float, float] = ([], 0.0, 0.0)
        self._pending = 0.0
        self._pending_t = -1e9
        self._probe_cache: Dict[Tuple, Tuple] = {}
        self._stats: Dict = {}
        self._step_compiled = False
        self._metrics = None
        self._tracer = None
        self.offload = False          # set from the worker's hello
        self._host_kv: Dict = {}      # tier occupancy from the heartbeat

    # ---- engine protocol: submission ------------------------------------
    def _wire_req(self, r: Request, now: float) -> Dict:
        return {"rid": r.req_id, "tokens": list(r.tokens or []),
                "allowed_tokens": (list(r.allowed_tokens)
                                   if r.allowed_tokens else None),
                "user_id": r.user_id,
                # deltas, not absolutes: perf_counter origins differ per
                # process. Transit shrinks the remaining budget — the
                # conservative direction for deadline feasibility.
                "deadline_delta": (None if r.deadline is None
                                   else r.deadline - now),
                "arrival_age": max(0.0, now - r.arrival)}

    def submit(self, tokens: Sequence[int], allowed_tokens=None, *,
               user_id=None, now: Optional[float] = None,
               deadline: Optional[float] = None, chain=None) -> int:
        if self._dead:
            raise RpcError(f"{self.name}: worker dead")
        arrival = time.perf_counter() if now is None else now
        rid = next(_req_counter)     # frontend-assigned: unique across pool
        r = Request(n_input=len(tokens), arrival=arrival,
                    chain=tuple(chain or ()), tokens=list(tokens),
                    req_id=rid, user_id=user_id,
                    allowed_tokens=(tuple(allowed_tokens)
                                    if allowed_tokens else None),
                    deadline=deadline)
        # pre-register the mirror: a concurrent step() may harvest this rid
        # the instant the worker enqueues it, and step's shadow filter must
        # recognize it as ours. Forgotten again on every failure path.
        with self.lock:
            self.queue.append(r)
            self._shadow[rid] = r
        try:
            self.rpc.call("submit", self._wire_req(r, time.perf_counter()),
                          timeout=self.submit_timeout, retries=2)
        except RpcDropped:
            # unknown outcome: the worker may have enqueued. Best-effort
            # reclaim; if it serves anyway, step's shadow filter drops the
            # orphan result at the boundary.
            self._forget(rid)
            try:
                self.rpc.call("cancel", {"rid": rid}, timeout=1.0)
            except RpcError:
                pass
            raise
        except Exception:
            self._forget(rid)
            raise
        return rid

    def requeue(self, reqs: Sequence[Request]) -> List[int]:
        """Batch re-home from a dead peer (InstancePool._drain hook). The
        worker dedupes by rid, so connection-level retries are safe."""
        if self._dead:
            raise RpcError(f"{self.name}: worker dead")
        now = time.perf_counter()
        with self.lock:                  # pre-register: see submit()
            for r in reqs:
                self.queue.append(r)
                self._shadow[r.req_id] = r
        try:
            self.rpc.call("requeue",
                          {"requests": [self._wire_req(r, now)
                                        for r in reqs]},
                          timeout=self.submit_timeout, retries=2)
        except Exception:
            for r in reqs:
                self._forget(r.req_id)
            raise
        return [r.req_id for r in reqs]

    def cancel(self, rid: int):
        with self.lock:
            r = self._shadow.get(rid)
        if r is None or self._dead:
            return None
        try:
            out = self.rpc.call("cancel", {"rid": rid},
                                timeout=self.probe_timeout)
        except RpcError:
            return None     # unknown — assume a step owns it (tombstones
        if not out.get("found"):   # make a late result safe either way)
            return None
        self._forget(rid)
        return r

    def shed_expired(self, now: Optional[float] = None) -> List[Request]:
        with self.lock:
            if self._dead or not any(r.deadline is not None
                                     for r in self._shadow.values()):
                return []    # zero RPCs on the idle/deadline-free hot loop
        try:
            out = self.rpc.call("shed_expired", timeout=self.probe_timeout)
        except RpcError:
            return []
        shed = []
        for row in out.get("shed", []):
            r = self._forget(int(row["rid"]))
            if r is not None:
                shed.append(r)
        return shed

    def _forget(self, rid: int) -> Optional[Request]:
        with self.lock:
            r = self._shadow.pop(rid, None)
            if r is not None:
                try:
                    self.queue.remove(r)
                except ValueError:
                    pass
            return r

    # ---- engine protocol: stepping --------------------------------------
    def step(self) -> Optional[int]:
        if self._dead:
            raise RpcError(f"{self.name}: worker dead")
        try:
            out = self.rpc.call("step", timeout=self.step_timeout)
        except RpcError:
            # death mid-step (SIGKILL / freeze-then-kill / dropped
            # response): confiscate the heartbeat-known in-flight mirrors
            # so the pool drain (queued work) and the server's retry path
            # (in-flight work) each own a DISJOINT set
            self._confiscate_inflight()
            raise
        recv = time.perf_counter()
        if out.get("crashed"):
            with self.lock:
                self._crash_inflight = [
                    i for i in out.get("inflight", []) if i in self._shadow]
                for i in self._crash_inflight:
                    self._forget(i)
            raise RpcRemoteError(
                f"{self.name}: engine crashed mid-step: {out['crashed']}")
        off = recv - float(out["now"])   # worker clock -> frontend clock
        rid = out.get("rid")
        with self.lock:
            self._crash_inflight = []
            self._hb = ([], 0.0, 0.0)          # the batch is over
            self._pending = float(out.get("pending_jct", 0.0))
            self._pending_t = recv
            self._step_compiled = bool(out.get("compiled"))
            served = out.get("served") or []
            # harvest ONLY rids still in our shadow: a rid drained off this
            # instance (mark_failed while the worker was frozen mid-step —
            # its REAL queue is unreachable, so only the shadow was cleared)
            # may still execute here if a thaw races the supervisor's kill;
            # the re-homed copy owns the future now, so this result is a
            # duplicate and must die at the boundary
            dropped = [int(i) for i, _ in served
                       if int(i) not in self._shadow]
            served = [(int(i), res) for i, res in served
                      if int(i) in self._shadow]
            self._last = [i for i, _ in served]
            for i, res in served:
                self._forget(i)
                if res is not None:
                    scores = res.get("scores")
                    if scores:     # JSON stringified the int keys
                        res["scores"] = {int(k): v
                                         for k, v in scores.items()}
                    self.results[i] = res
        if dropped and self._metrics is not None:
            for _ in dropped:
                self._metrics.counter("drained_results_dropped",
                                      self.name).inc()
        self._replay_telemetry(out, off)
        return rid

    @property
    def last_step_ids(self) -> List[int]:
        with self.lock:
            return list(self._last)

    @property
    def _inflight(self) -> List[int]:
        """What the server confiscates after a step() exception."""
        with self.lock:
            return list(self._crash_inflight)

    def _confiscate_inflight(self) -> None:
        with self.lock:
            ids = [i for i in self._hb[0] if i in self._shadow]
            for i in ids:
                self._forget(i)
            self._crash_inflight = ids

    # ---- engine protocol: probes ----------------------------------------
    def probe(self, n_input: int, chain=()) -> Tuple[float, float, int]:
        chain = tuple(chain or ())
        key = (n_input, chain)
        now = time.perf_counter()
        with self.lock:
            hit = self._probe_cache.get(key)
            if hit is not None and now - hit[0] <= self.probe_ttl:
                return hit[1], hit[2], hit[3]
            if self._dead:
                return self._pending, 0.0, 0
        try:
            out = self.rpc.call("probe", {"n_input": n_input,
                                          "chain": list(chain)},
                                timeout=self.probe_timeout)
        except RpcError:
            with self.lock:
                hit = self._probe_cache.get(key)
                if hit is not None:
                    return hit[1], hit[2], hit[3]
                return self._pending, 0.0, 0
        trip = (float(out["pending_jct"]), float(out["predict_jct"]),
                int(out["cached_prefix_len"]))
        with self.lock:
            self._probe_cache[key] = (now,) + trip
            if len(self._probe_cache) > 256:
                self._probe_cache.pop(next(iter(self._probe_cache)))
            self._pending, self._pending_t = trip[0], now
        return trip

    def pending_jct(self, now: Optional[float] = None) -> float:
        t = time.perf_counter()
        with self.lock:
            if self._dead or t - self._pending_t <= self.probe_ttl:
                return self._pending
        return self.probe(0)[0]

    def predict_jct(self, n: int, chain=()) -> float:
        return self.probe(n, chain)[1]

    def cached_prefix_len(self, chain) -> int:
        return self.probe(0, chain)[2]

    # ---- heartbeat-fed state --------------------------------------------
    def on_heartbeat(self, out: Dict, recv: Optional[float] = None) -> None:
        recv = time.perf_counter() if recv is None else recv
        with self.lock:
            ids = [i for i in out.get("inflight", []) if i in self._shadow]
            if ids:
                # t0 on OUR clock: error is one-way transit, which only
                # ages the batch — the watchdog trips sooner, never later
                self._hb = (ids, float(out.get("inflight_pred", 0.0)),
                            recv - float(out.get("inflight_elapsed", 0.0)))
            else:
                self._hb = ([], 0.0, 0.0)
            self._pending = float(out.get("pending_jct", 0.0))
            self._pending_t = recv
            if out.get("stats") is not None:
                self._stats = out["stats"]
            if out.get("host_kv") is not None:
                self._host_kv = out["host_kv"]
            m = self._metrics
        rows = out.get("metrics")
        if m is not None and rows:
            # worker-emitted series (jct_*, pack_*, batch_wall_seconds, ...)
            # are disjoint from frontend series by name: overwrite-merge
            m.merge_state(rows, instance=self.name)

    def inflight_snapshot(self) -> Tuple[List[int], float, float]:
        with self.lock:
            ids, pred, t0 = self._hb
            return list(ids), pred, t0

    # ---- telemetry bridge ------------------------------------------------
    def bind_telemetry(self, metrics=None, instance: str = "",
                       tracer=None) -> None:
        self._metrics = metrics
        self._tracer = tracer

    def _replay_telemetry(self, out: Dict, off: float) -> None:
        tr = self._tracer
        if tr is None:
            return
        for row in out.get("orphans") or []:
            rid, t, name, attrs = row
            attrs = dict(attrs or {})
            if name.startswith("span:"):
                t0 = float(attrs.pop("_t0", t))
                tr.ingest_span(int(rid), name[5:], t0 + off,
                               float(t) + off, **attrs)
            else:
                tr.ingest_event(int(rid), float(t) + off, name, **attrs)
        for b in out.get("batches") or []:
            kw = {k: v for k, v in b.items() if k in _BATCH_FIELDS}
            kw["ts"] = float(kw.get("ts", 0.0)) + off
            kw["instance"] = self.name
            kw["req_ids"] = tuple(kw.get("req_ids") or ())
            kw["jit_key"] = tuple(
                tuple(x) if isinstance(x, list) else x
                for x in (kw.get("jit_key") or ()))
            tr.record_batch(BatchRecord(**kw))

    # ---- lifecycle hooks -------------------------------------------------
    def drain_queue(self) -> List[Request]:
        """InstancePool._drain hook: hand over (and clear) the shadow."""
        with self.lock:
            pending = list(self.queue)
            self.queue.clear()
            self._shadow.clear()
        return pending

    def mark_dead(self) -> None:
        with self.lock:
            self._dead = True
            self._hb = ([], 0.0, 0.0)

    def reset_for_restart(self) -> None:
        with self.lock:
            self._dead = False
            self._crash_inflight = []
            self._hb = ([], 0.0, 0.0)
            self.queue.clear()
            self._shadow.clear()
            self._probe_cache.clear()
            self._pending, self._pending_t = 0.0, -1e9
            self._step_compiled = False
            self._host_kv = {}

    def set_degraded(self, flag: bool) -> None:
        if self._dead:
            return
        try:
            self.rpc.call("set_degraded", {"flag": bool(flag)},
                          timeout=self.probe_timeout)
        except RpcError:
            pass     # brownout is advisory; a dead worker restarts fresh

    # ---- offload tier (paper §9) -----------------------------------------
    def restore_estimate(self, chain) -> Dict:
        """Restorable host-tier prefix priced by the worker. Zeros when the
        worker has no tier (hello said so — no RPC spent) or is dead."""
        zeros = {"device_blocks": 0, "blocks": 0, "bytes": 0,
                 "restore_s": 0.0}
        if not self.offload or self._dead:
            return zeros
        try:
            out = self.rpc.call("prefetch",
                                {"chain": list(chain or ()),
                                 "estimate": True},
                                timeout=self.probe_timeout)
        except RpcError:
            return zeros
        return {k: out.get(k, zeros[k]) for k in zeros}

    def prefetch_prefix(self, chain, rid: Optional[int] = None) -> int:
        """Kick the worker's async host->device prefetch. Advisory like
        set_degraded: a failed RPC means the execute path restores instead."""
        if not self.offload or self._dead:
            return 0
        try:
            out = self.rpc.call("prefetch",
                                {"chain": list(chain or ()), "rid": rid},
                                timeout=self.probe_timeout)
        except RpcError:
            return 0
        return int(out.get("blocks", 0))

    def stats(self) -> Dict:
        if not self._dead:
            try:
                out = self.rpc.call("stats", timeout=self.probe_timeout)
                with self.lock:
                    self._stats = out.get("stats") or {}
            except RpcError:
                pass
        with self.lock:
            out = dict(self._stats) if self._stats else {}
            if self._host_kv:
                out.setdefault("host_kv", self._host_kv)
            return out


class WorkerHandle:
    """One supervised worker process and its client-side plumbing."""

    def __init__(self, name: str, spec: Dict):
        self.name = name
        self.spec = spec
        self.proc: Optional[subprocess.Popen] = None
        self.pid: Optional[int] = None
        self.port: Optional[int] = None
        self.port_file: Optional[str] = None
        self.client: Optional[RpcClient] = None
        self.remote: Optional[RemoteEngine] = None
        self.misses = 0
        self.deaths = 0
        self.dead = False
        self.permafailed = False
        self.restarting = False
        self.restart_due: Optional[float] = None
        self.restart_times: List[float] = []


class WorkerSupervisor:
    """Spawns workers, beats their hearts, declares death, restarts.

    Death = ``miss_budget`` consecutive heartbeat failures OR process exit.
    The declaration sequence is ordered for correctness under SIGSTOP:
    SIGKILL first (produces the TCP reset that unblocks any frontend thread
    parked in a ``step`` RPC on the frozen worker), then ``on_death`` (the
    server re-homes the shadow queue), then a restart scheduled with
    exponential backoff — bounded by a crash-loop budget of
    ``max_restarts`` within ``restart_window`` seconds, after which the
    instance is permanently failed rather than flapping forever.
    """

    def __init__(self, *, lease: float = 3.0,
                 heartbeat_interval: float = 0.25, miss_budget: int = 4,
                 restart_backoff: float = 0.25,
                 restart_backoff_cap: float = 4.0, max_restarts: int = 5,
                 restart_window: float = 30.0, drain_grace: float = 5.0,
                 spawn_timeout: float = 120.0, step_timeout: float = 300.0,
                 log_dir: Optional[str] = None,
                 rpc_fault_hook: Optional[Callable] = None,
                 on_death: Optional[Callable[[str], None]] = None,
                 on_restart: Optional[Callable[[str], None]] = None,
                 metrics=None, verbose: bool = False):
        self.lease = lease
        self.heartbeat_interval = heartbeat_interval
        self.miss_budget = miss_budget
        self.restart_backoff = restart_backoff
        self.restart_backoff_cap = restart_backoff_cap
        self.max_restarts = max_restarts
        self.restart_window = restart_window
        self.drain_grace = drain_grace
        self.spawn_timeout = spawn_timeout
        self.step_timeout = step_timeout
        self.log_dir = log_dir or os.environ.get(
            "REPRO_WORKER_LOG_DIR") or tempfile.mkdtemp(prefix="repro-wk-")
        self.rpc_fault_hook = rpc_fault_hook
        self.on_death = on_death
        self.on_restart = on_restart
        self.metrics = metrics
        self.verbose = verbose
        # frontend health map (pool.healthy, wired by wire_supervisor): an
        # instance the SERVER marked failed — dropped/timed-out step RPC,
        # engine exception inside a live worker — is dead to the plane even
        # though the process is up; the beat loop converts that verdict
        # into a kill+restart so the instance re-enters the pool
        self.health_view: Optional[Dict[str, bool]] = None
        self.handles: Dict[str, WorkerHandle] = {}
        self._stop = threading.Event()
        self._beat_thread: Optional[threading.Thread] = None

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(f"[supervisor] {msg}", flush=True)

    # ---- spawning --------------------------------------------------------
    def _launch(self, h: WorkerHandle) -> None:
        os.makedirs(self.log_dir, exist_ok=True)
        h.port_file = os.path.join(self.log_dir, f"{h.name}.port.json")
        try:
            os.unlink(h.port_file)
        except FileNotFoundError:
            pass
        cmd = [sys.executable, "-m", "repro.serving.worker",
               "--name", h.name, "--spec", json.dumps(h.spec),
               "--port-file", h.port_file, "--lease", str(self.lease),
               "--drain-grace", str(self.drain_grace)]
        env = dict(os.environ)
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        # append mode: a restarted worker's logs continue the same files —
        # the CI chaos soak uploads these on failure
        with open(os.path.join(self.log_dir, f"{h.name}.out.log"),
                  "ab") as out, \
                open(os.path.join(self.log_dir, f"{h.name}.err.log"),
                     "ab") as err:
            h.proc = subprocess.Popen(cmd, stdout=out, stderr=err, env=env)
        deadline = time.monotonic() + self.spawn_timeout
        while True:
            rc = h.proc.poll()
            if rc is not None:
                raise RuntimeError(
                    f"worker {h.name} exited rc={rc} before listening "
                    f"(logs under {self.log_dir})")
            try:
                with open(h.port_file) as f:
                    info = json.load(f)
                h.port, h.pid = int(info["port"]), int(info["pid"])
                break
            except (FileNotFoundError, json.JSONDecodeError, KeyError,
                    ValueError):
                pass
            if time.monotonic() > deadline:
                h.proc.kill()
                raise RuntimeError(f"worker {h.name} did not listen within "
                                   f"{self.spawn_timeout}s")
            time.sleep(0.02)
        h.misses = 0

    def spawn(self, name: str, spec: Dict) -> WorkerHandle:
        h = WorkerHandle(name, spec)
        self.handles[name] = h
        self._launch(h)
        hook = None
        if self.rpc_fault_hook is not None:
            hook = (lambda op, _n=name: self.rpc_fault_hook(_n, op))
        h.client = RpcClient("127.0.0.1", h.port, fault_hook=hook)
        h.remote = RemoteEngine(name, h.client,
                                step_timeout=self.step_timeout)
        hello = h.client.call("hello", timeout=15.0)
        h.remote.ecfg.block_size = int(hello["block_size"])
        h.remote.offload = bool(hello.get("offload"))
        self._log(f"worker {name}: pid={h.pid} port={h.port} "
                  f"block_size={h.remote.ecfg.block_size} "
                  f"offload={h.remote.offload}")
        return h

    def pid_of(self, name: str) -> Optional[int]:
        h = self.handles.get(name)
        return None if h is None or h.dead else h.pid

    # ---- heartbeat loop --------------------------------------------------
    def start(self) -> "WorkerSupervisor":
        if self._beat_thread is None:
            self._beat_thread = threading.Thread(
                target=self._beat_loop, name="worker-heartbeat", daemon=True)
            self._beat_thread.start()
        return self

    def _beat_loop(self) -> None:
        while not self._stop.wait(self.heartbeat_interval):
            now = time.monotonic()
            for h in list(self.handles.values()):
                if h.dead:
                    if (not h.permafailed and not h.restarting
                            and h.restart_due is not None
                            and now >= h.restart_due):
                        h.restarting = True
                        threading.Thread(target=self._restart, args=(h,),
                                         daemon=True).start()
                    continue
                if (self.health_view is not None
                        and self.health_view.get(h.name) is False):
                    self._declare_dead(h, "frontend marked instance failed")
                    continue
                exited = h.proc.poll() is not None
                if not exited:
                    try:
                        out = h.client.call(
                            "heartbeat",
                            {"lease": self.lease, "want_stats": True},
                            timeout=max(0.5, self.heartbeat_interval * 2))
                        h.misses = 0
                        h.remote.on_heartbeat(out)
                        continue
                    except RpcError:
                        h.misses += 1
                        if self.metrics is not None:
                            self.metrics.counter("worker_heartbeat_misses",
                                                 h.name).inc()
                if exited or h.misses >= self.miss_budget:
                    why = (f"exited rc={h.proc.returncode}" if exited
                           else f"{h.misses} consecutive missed heartbeats")
                    self._declare_dead(h, why)

    def _declare_dead(self, h: WorkerHandle, why: str) -> None:
        h.dead = True
        h.deaths += 1
        self._log(f"worker {h.name} DEAD: {why}")
        if self.metrics is not None:
            self.metrics.counter("worker_deaths", h.name).inc()
            self.metrics.gauge("worker_up", h.name).set(0)
        # SIGKILL before anything else: a frozen (SIGSTOP) worker emits no
        # TCP reset until it actually dies, and that reset is what unblocks
        # a frontend thread currently parked inside a step RPC
        if h.pid is not None:
            try:
                os.kill(h.pid, signal.SIGKILL)
            except (ProcessLookupError, PermissionError):
                pass
        h.remote.mark_dead()
        if self.on_death is not None:
            # off-thread: mark_failed re-homes the shadow over RPC to
            # peers; that must not stall the other workers' heartbeats
            threading.Thread(target=self._run_on_death, args=(h.name,),
                             daemon=True).start()
        now = time.monotonic()
        h.restart_times = [t for t in h.restart_times
                           if now - t <= self.restart_window]
        if len(h.restart_times) >= self.max_restarts:
            h.permafailed = True
            h.restart_due = None
            self._log(f"worker {h.name}: crash-loop budget exhausted "
                      f"({self.max_restarts} restarts/{self.restart_window}s"
                      f") — permanently failed")
            if self.metrics is not None:
                self.metrics.counter("worker_crashloop_permafail",
                                     h.name).inc()
            return
        backoff = min(self.restart_backoff_cap,
                      self.restart_backoff * (2 ** len(h.restart_times)))
        h.restart_due = now + backoff

    def _run_on_death(self, name: str) -> None:
        try:
            self.on_death(name)
        except Exception:
            pass

    def _restart(self, h: WorkerHandle) -> None:
        try:
            if self._stop.is_set():
                return
            try:
                h.proc.wait(timeout=5.0)     # reap the corpse
            except Exception:
                pass
            self._launch(h)
            if self._stop.is_set():
                # shutdown raced the restart: don't leak the fresh process
                try:
                    h.proc.kill()
                    h.proc.wait(timeout=5.0)
                except Exception:
                    pass
                return
            h.client.retarget("127.0.0.1", h.port)
            hello = h.client.call("hello", timeout=15.0)
            h.remote.offload = bool(hello.get("offload"))
            h.remote.reset_for_restart()
            h.restart_times.append(time.monotonic())
            h.restart_due = None
            h.dead = False
            self._log(f"worker {h.name} RESTARTED: pid={h.pid} "
                      f"port={h.port}")
            if self.metrics is not None:
                self.metrics.counter("worker_restarts", h.name).inc()
                self.metrics.gauge("worker_up", h.name).set(1)
            if self.on_restart is not None:
                try:
                    self.on_restart(h.name)
                except Exception:
                    pass
        except Exception as e:
            self._log(f"worker {h.name} restart FAILED: {e}")
            h.restart_times.append(time.monotonic())
            now = time.monotonic()
            recent = [t for t in h.restart_times
                      if now - t <= self.restart_window]
            if len(recent) >= self.max_restarts:
                h.permafailed = True
                h.restart_due = None
            else:
                h.restart_due = now + min(
                    self.restart_backoff_cap,
                    self.restart_backoff * (2 ** len(recent)))
        finally:
            h.restarting = False

    # ---- shutdown --------------------------------------------------------
    def stop(self, graceful: bool = True,
             timeout: Optional[float] = None) -> None:
        self._stop.set()
        if self._beat_thread is not None:
            self._beat_thread.join(timeout=5.0)
            self._beat_thread = None
        if timeout is None:
            timeout = self.drain_grace + 2.0 if graceful else 2.0
        sig = signal.SIGTERM if graceful else signal.SIGKILL
        for h in self.handles.values():
            if h.proc is None or h.proc.poll() is not None:
                continue
            try:
                os.kill(h.pid, signal.SIGCONT)   # a frozen worker cannot
            except (ProcessLookupError, PermissionError):  # run SIGTERM
                pass
            try:
                h.proc.send_signal(sig)
            except (ProcessLookupError, OSError):
                pass
        deadline = time.monotonic() + timeout
        for h in self.handles.values():
            if h.proc is None:
                continue
            try:
                h.proc.wait(timeout=max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                h.proc.kill()
                try:
                    h.proc.wait(timeout=5.0)
                except Exception:
                    pass
            if h.remote is not None:    # spawn may have died pre-handshake
                h.remote.mark_dead()
            if h.client is not None:
                h.client.close()


def make_process_pool(specs: Dict[str, Dict], **sup_kwargs
                      ) -> Tuple[InstancePool, WorkerSupervisor]:
    """Spawn one worker per spec (in parallel — real engines pay a model
    build each) and assemble an ``InstancePool`` of RemoteEngines. The
    caller starts the supervisor's heartbeat loop (``sup.start()``) once
    the death/restart callbacks are wired (see ``wire_supervisor``)."""
    sup = WorkerSupervisor(**sup_kwargs)
    errors: Dict[str, Exception] = {}

    def _one(n: str) -> None:
        try:
            sup.spawn(n, specs[n])
        except Exception as e:      # noqa: BLE001 — surfaced below
            errors[n] = e

    threads = [threading.Thread(target=_one, args=(n,), daemon=True)
               for n in sorted(specs)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        sup.stop(graceful=False)
        raise RuntimeError(f"worker spawn failed: {errors}")

    def _fixed(name: str):
        raise RuntimeError("process pool is fixed-size; restarts are the "
                           "supervisor's job, not make_engine's")

    pool = InstancePool(_fixed)
    for n in sorted(specs):
        pool.engines[n] = sup.handles[n].remote
        pool.healthy[n] = True
    return pool, sup


def wire_supervisor(sup: WorkerSupervisor, server) -> None:
    """Connect death/restart to the AsyncServer's health machinery: death
    re-homes the shadow queue through ``mark_failed`` (exactly the path
    thread-mode crashes take); restart flips the instance healthy and
    wakes its parked worker thread."""
    sup.metrics = server.metrics

    def on_death(name: str) -> None:
        server.mark_failed(name)

    def on_restart(name: str) -> None:
        server.pool.healthy[name] = True
        server._bind_engines()
        server._start_worker(name)
        server._events.setdefault(name, threading.Event()).set()

    sup.on_death = on_death
    sup.on_restart = on_restart
    # bidirectional health: the server's own failure verdicts (step RPC
    # dropped/timed out, engine crash in a live worker) become supervisor
    # deaths, so the process is killed and restarted instead of lingering
    # outside the pool forever
    sup.health_view = server.pool.healthy
    if sup.metrics is not None:
        for h in sup.handles.values():
            sup.metrics.gauge("worker_up", h.name).set(
                0 if h.dead else 1)
