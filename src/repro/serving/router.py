"""Routing policies for the instance pool (paper §7.1 "Routing").

Two policies, both deterministic given the same pool state:

  * ``UserHashRouter`` — the paper's user-id rendezvous hash (elastic
    minimal remap on scale-up/down). Ignores load entirely.
  * ``LeastBacklogRouter`` — JCT-aware: route to the instance minimizing
    (sum of predicted JCTs of its queue) + (predicted JCT of THIS request
    given that instance's prefix cache). Only possible because prefill-only
    JCT is precisely predictable — the backlog number is trustworthy, not a
    proxy. Instances whose score ties within ``affinity_tol`` are broken by
    cache affinity (longest cached prefix wins: the near-tied instance that
    already holds this user's profile KV serves the request cheaper than the
    score difference suggests), then by rendezvous hash for determinism.

Routers see engines through three probes — ``pending_jct()``,
``predict_jct(n_input, chain)``, ``cached_prefix_len(chain)`` — all
lock-protected on the engine, so routing runs concurrently with serving.

``chain`` is the request's block-hash chain. Chains are granular in the
engine's block size, so on a heterogeneous pool a single chain cannot probe
every engine: callers pass ``chains`` (block_size -> chain) and each engine
is probed with the chain cut at ITS block size.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.runtime.fault_tolerance import rendezvous_hash


def chain_for(eng, chain: Tuple[int, ...],
              chains: Optional[Dict[int, Tuple[int, ...]]]):
    """The chain cut at ``eng``'s block size, falling back to ``chain``."""
    if not chains:
        return chain
    bs = getattr(getattr(eng, "ecfg", None), "block_size", None)
    return chains.get(bs, chain)


class UserHashRouter:
    """Rendezvous (HRW) hash on user id — stateless, cache-friendly for
    user-keyed workloads, oblivious to load."""

    name = "user_hash"

    def route(self, *, user_id: Optional[str], n_input: int,
              chain: Tuple[int, ...], instances: Dict[str, object],
              chains: Optional[Dict[int, Tuple[int, ...]]] = None) -> str:
        names = sorted(instances)
        return rendezvous_hash(user_id or "", names)


class LeastBacklogRouter:
    """JCT-aware least-backlog with cache-affinity tie-break."""

    name = "least_backlog"

    def __init__(self, affinity_tol: float = 0.15):
        # relative score window inside which cache affinity overrides backlog
        self.affinity_tol = affinity_tol

    def route(self, *, user_id: Optional[str], n_input: int,
              chain: Tuple[int, ...], instances: Dict[str, object],
              chains: Optional[Dict[int, Tuple[int, ...]]] = None) -> str:
        names = sorted(instances)
        scores = {}
        matched = {}
        for name in names:
            eng = instances[name]
            c = chain_for(eng, chain, chains)
            probe = getattr(eng, "probe", None)
            if probe is not None:
                # batched probe: all three numbers in ONE engine-lock
                # acquisition (in-process) or ONE staleness-bounded RPC
                # (cross-process RemoteEngine) per instance per scan
                pending, predict, matched[name] = probe(n_input, c)
                scores[name] = pending + predict
            else:
                scores[name] = eng.pending_jct() + eng.predict_jct(
                    n_input, c)
        best = min(scores.values())
        window = best + self.affinity_tol * max(best, 1e-9)
        close = [n for n in names if scores[n] <= window]
        if len(close) > 1:
            matched = {n: matched[n] if n in matched
                       else instances[n].cached_prefix_len(
                           chain_for(instances[n], chain, chains))
                       for n in close}
            top = max(matched.values())
            if top > 0:
                close = [n for n in close if matched[n] == top]
        if len(close) == 1:
            return close[0]
        return rendezvous_hash(user_id or "", close)


ROUTERS = {r.name: r for r in (UserHashRouter, LeastBacklogRouter)}


def get_router(name: str, **kw):
    try:
        return ROUTERS[name](**kw)
    except KeyError:
        raise KeyError(f"unknown router {name!r}; have {sorted(ROUTERS)}")
