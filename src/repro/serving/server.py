"""AsyncServer — non-blocking serving over a pool of PrefillOnly engines.

One daemon worker thread per engine instance drives the existing ``step()``
loop (Algorithm-1 pick + prepacked batch formation + hybrid prefill), so
arrival handling, routing, and admission overlap with compute instead of the
old poll-submit-step loop that interleaved them in one thread.

  submit(user_id, tokens, ...) -> Future
      routes (pluggable policy), runs admission control, enqueues on the
      chosen engine, and returns immediately. The future resolves with the
      engine's scored result dict, or with a typed ``Rejected`` — never an
      exception — so callers branch on type, not try/except.

  deadlines
      a request may carry an absolute deadline. Admission rejects requests
      that are predicted dead on arrival; workers shed queued requests whose
      deadline becomes unreachable (``engine.shed_expired``) before every
      step, and ``cancel(req_id)`` removes a queued request on demand.

  drain / shutdown
      ``drain()`` blocks until every admitted request has resolved;
      ``shutdown(drain=True)`` then stops the workers. ``shutdown(False)``
      cancels all queued work with ``Rejected("shutdown")``.

  health
      ``mark_failed(name)`` routes a dead instance's queued requests to
      healthy peers via ``InstancePool`` (futures follow the request — the
      peer that eventually serves it resolves the same future);
      ``scale_to(names)`` grows/shrinks the pool and its worker threads.

Telemetry lands in a ``MetricsRegistry`` (per-instance + global counters,
queue-depth/backlog gauges, latency and step-time histograms).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

from repro.core.prefix_cache import token_chain
from repro.runtime.fault_tolerance import InstancePool
from repro.serving.admission import AdmissionController, Rejected
from repro.serving.metrics import MetricsRegistry
from repro.serving.router import UserHashRouter


class AsyncServer:
    IDLE_WAIT = 0.02   # worker poll fallback when its queue is empty

    def __init__(self, pool: InstancePool, router=None,
                 admission: Optional[AdmissionController] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.pool = pool
        self.router = router or UserHashRouter()
        self.admission = admission
        self.metrics = metrics or MetricsRegistry()
        if admission is not None and admission.metrics is None:
            admission.metrics = self.metrics   # feedback-loop telemetry
        self._futures: Dict[int, Future] = {}
        self._early: Dict[int, object] = {}   # results that beat registration
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._outstanding = 0
        self._events: Dict[str, threading.Event] = {}
        self._threads: Dict[str, threading.Thread] = {}
        self._stop = threading.Event()
        self._accepting = False

    # ---- lifecycle -------------------------------------------------------
    def start(self) -> "AsyncServer":
        self._accepting = True
        for name in self.pool.live_names():
            self._start_worker(name)
        return self

    def _start_worker(self, name: str) -> None:
        if name in self._threads and self._threads[name].is_alive():
            return
        if name not in self._events:     # keep the event stable per name:
            self._events[name] = threading.Event()   # workers hold a ref
        t = threading.Thread(target=self._worker, args=(name,),
                             name=f"engine-{name}", daemon=True)
        self._threads[name] = t
        t.start()

    def scale_to(self, names: List[str]) -> None:
        """Elastic rebalance hook: pool.scale_to redistributes queued work
        from removed instances; workers follow the instance set. Requests
        the pool could not re-home resolve as ``Rejected`` (mirroring
        ``mark_failed``) instead of hanging their futures."""
        dropped = self.pool.scale_to(names)
        for name in self.pool.live_names():
            self._start_worker(name)
        for r in dropped:
            self._reject(r.req_id, Rejected(
                "no_instances", "instance removed with no healthy peer",
                req_id=r.req_id, user_id=r.user_id))
        self._wake_all()

    def mark_failed(self, name: str) -> None:
        """Health hook: requeue the failed instance's waiting requests onto
        healthy peers (their futures stay valid) and retire its worker.
        With no healthy peer left the stranded requests resolve as
        ``Rejected`` rather than hanging their futures."""
        for r in self.pool.mark_failed(name):
            self._reject(r.req_id, Rejected(
                "no_instances", "instance failed with no healthy peer",
                req_id=r.req_id, user_id=r.user_id))
        self._wake_all()

    def _wake_all(self) -> None:
        # snapshot: submit() may insert an event concurrently (setdefault)
        for ev in list(self._events.values()):
            ev.set()

    # ---- submission ------------------------------------------------------
    def submit(self, user_id: Optional[str], tokens: Sequence[int], *,
               allowed_tokens: Optional[Sequence[int]] = None,
               deadline: Optional[float] = None) -> "Future":
        """Non-blocking: route, admit, enqueue; resolves to a result dict or
        a typed ``Rejected``."""
        fut = Future()
        fut.set_running_or_notify_cancel()
        if not self._accepting:
            fut.set_result(Rejected("shutdown", "server not accepting",
                                    user_id=user_id))
            return fut
        live = {n: self.pool.engines[n] for n in self.pool.live_names()}
        if not live:
            rej = Rejected("no_instances", user_id=user_id)
            self._count_rejection(rej)
            fut.set_result(rej)
            return fut
        # chains are granular in the engine's block size: on a heterogeneous
        # pool, routing/admission probes and the enqueue must each see the
        # chain cut at THEIR engine's block size, or cache matching (and the
        # cache inserts keyed on the chain) silently misfire
        chains: Dict[int, tuple] = {}
        for e in live.values():
            bs = e.ecfg.block_size
            if bs not in chains:
                chains[bs] = token_chain(tokens, bs)
        name = self.router.route(user_id=user_id, n_input=len(tokens),
                                 chain=next(iter(chains.values())),
                                 instances=live, chains=chains)
        eng = live[name]
        chain = chains[eng.ecfg.block_size]
        now = time.perf_counter()
        if self.admission is not None:
            rej = self.admission.check(
                len(tokens), deadline, now, eng.pending_jct(),
                eng.predict_jct(len(tokens), chain), user_id=user_id)
            if rej is not None:
                self._count_rejection(rej)
                fut.set_result(rej)
                return fut
        rid = eng.submit(tokens, allowed_tokens, user_id=user_id,
                         deadline=deadline, chain=chain)
        with self._lock:
            early = self._early.pop(rid, None)
            if early is None:
                self._futures[rid] = fut
                self._outstanding += 1
        self.metrics.counter("requests_submitted", name).inc()
        # setdefault: the worker for an instance added via pool.scale_to()
        # directly (or racing server.scale_to) may not exist yet — the event
        # must, so _start_worker can hand it over
        self._events.setdefault(name, threading.Event()).set()
        if early is not None:        # worker finished before we registered
            fut.set_result(early)
            return fut
        # close the enqueue-vs-failure race: if the instance was failed (or
        # the server stopped accepting) while we were enqueueing, the drain
        # may have run BEFORE our append — reclaim the orphan and reject it.
        # cancel() returning None means a worker/peer already owns it.
        if not self.pool.healthy.get(name, False) or not self._accepting:
            if eng.cancel(rid) is not None:
                reason = ("shutdown" if not self._accepting
                          else "no_instances")
                self._reject(rid, Rejected(reason, "instance lost after "
                                           "enqueue", req_id=rid,
                                           user_id=user_id))
        return fut

    def cancel(self, req_id: int) -> bool:
        """Cancel a QUEUED request (no effect once its forward started)."""
        for name in self.pool.live_names():
            r = self.pool.engines[name].cancel(req_id)
            if r is not None:
                self._reject(req_id, Rejected("cancelled", req_id=req_id,
                                              user_id=r.user_id))
                return True
        return False

    # ---- completion ------------------------------------------------------
    def _count_rejection(self, rej: Rejected) -> None:
        """Single site for the rejection counter pair — every rejection
        path must keep stats() in sync with actual outcomes."""
        self.metrics.counter("requests_rejected").inc()
        self.metrics.counter(f"rejected_{rej.reason}").inc()

    def _reject(self, rid: int, rej: Rejected) -> None:
        """Resolve an already-registered request as ``Rejected``."""
        self._count_rejection(rej)
        self._resolve(rid, rej)

    def _resolve(self, rid: int, result) -> None:
        with self._lock:
            fut = self._futures.pop(rid, None)
            if fut is None:
                # submit() hasn't registered the future yet — park the result
                self._early[rid] = result
                return
            self._outstanding -= 1
            self._cond.notify_all()
        fut.set_result(result)

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every admitted request has resolved."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._outstanding > 0:
                left = (None if deadline is None
                        else max(0.0, deadline - time.monotonic()))
                if left == 0.0 or not self._cond.wait(timeout=left or 0.5):
                    if deadline is not None and time.monotonic() >= deadline:
                        return False
        return True

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        self._accepting = False
        drained = self.drain(timeout) if drain else False
        if not drained:
            # not draining, or drain timed out: every still-queued request
            # must resolve (``Rejected``) — never strand a future
            for name in list(self.pool.engines):
                eng = self.pool.engines[name]
                with eng.lock:
                    dropped = list(eng.queue)
                    eng.queue.clear()
                for r in dropped:
                    self._reject(r.req_id, Rejected(
                        "shutdown", req_id=r.req_id, user_id=r.user_id))
        self._stop.set()
        self._wake_all()
        for t in self._threads.values():
            t.join(timeout=5.0)

    # ---- worker loop -----------------------------------------------------
    def _worker(self, name: str) -> None:
        ev = self._events[name]
        m = self.metrics
        while not self._stop.is_set():
            # re-fetch per iteration: scale_to may replace the engine object
            # behind a reused instance name while we were mid-step
            eng = self.pool.engines.get(name)
            if eng is None or not self.pool.healthy.get(name, False):
                return                      # failed/removed: pool re-routed
            for r in eng.shed_expired():
                # feedback: a shed request is one admission under-estimated
                if self.admission is not None:
                    self.admission.record_outcome(shed=True)
                self._reject(r.req_id, Rejected(
                    "shed", "deadline unreachable in queue",
                    req_id=r.req_id, user_id=r.user_id))
            t0 = time.perf_counter()
            try:
                rid = eng.step()
            except Exception:
                # a dying worker must not strand futures: the mid-step batch
                # resolves Rejected, the instance is failed so queued work
                # requeues to peers (or resolves Rejected itself)
                self.metrics.counter("engine_errors", name).inc()
                for lost in list(getattr(eng, "_inflight", [])):
                    self._reject(lost, Rejected(
                        "error", "instance failed mid-step", req_id=lost))
                self.mark_failed(name)
                return
            if rid is None:
                ev.wait(timeout=self.IDLE_WAIT)
                ev.clear()
                continue
            m.histogram("step_seconds", name).observe(
                time.perf_counter() - t0)
            with eng.lock:
                # pop: the future is the delivery channel under the server;
                # leaving results behind would grow memory with every request
                served = [(i, eng.results.pop(i)) for i in eng.last_step_ids]
                depth = len(eng.queue)
            m.gauge("queue_depth", name).set(depth)
            m.gauge("backlog_seconds", name).set(eng.pending_jct())
            for rid2, res in served:
                m.counter("requests_served", name).inc()
                m.histogram("latency_seconds", name).observe(res["latency"])
                if (self.admission is not None
                        and res.get("deadline") is not None):
                    self.admission.record_outcome(shed=False)
                self._resolve(rid2, res)

    # ---- introspection ---------------------------------------------------
    def stats(self) -> Dict:
        return {
            "served": self.metrics.total("requests_served"),
            "rejected": self.metrics.total("requests_rejected"),
            "latency": self.metrics.merged_histogram(
                "latency_seconds").summary(),
            "per_instance": {n: self.pool.engines[n].stats()
                             for n in self.pool.live_names()},
        }
