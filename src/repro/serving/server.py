"""AsyncServer — non-blocking serving over a pool of PrefillOnly engines.

One daemon worker thread per engine instance drives the existing ``step()``
loop (Algorithm-1 pick + prepacked batch formation + hybrid prefill), so
arrival handling, routing, and admission overlap with compute instead of the
old poll-submit-step loop that interleaved them in one thread.

  submit(user_id, tokens, ...) -> Future
      routes (pluggable policy), runs admission control, enqueues on the
      chosen engine, and returns immediately. The future resolves with the
      engine's scored result dict, or with a typed ``Rejected`` — never an
      exception — so callers branch on type, not try/except.

  deadlines
      a request may carry an absolute deadline. Admission rejects requests
      that are predicted dead on arrival; workers shed queued requests whose
      deadline becomes unreachable (``engine.shed_expired``) before every
      step, and ``cancel(req_id)`` removes a queued request on demand.

  drain / shutdown
      ``drain()`` blocks until every admitted request has resolved;
      ``shutdown(drain=True)`` then stops the workers. ``shutdown(False)``
      cancels all queued work with ``Rejected("shutdown")``.

  health
      ``mark_failed(name)`` routes a dead instance's queued requests to
      healthy peers via ``InstancePool`` (futures follow the request — the
      peer that eventually serves it resolves the same future);
      ``scale_to(names)`` grows/shrinks the pool and its worker threads.

Robustness (chaos-hardened serving)
-----------------------------------
Prefill-only requests are idempotent — one stateless forward, one token, no
side effects — so work lost mid-step is safe to re-run anywhere. The server
exploits that end to end:

  retry (``RetryPolicy``)
      a request lost to a mid-step crash, a watchdog trip, or a corrupted
      (non-finite) score is transparently re-submitted to a healthy peer:
      chain re-cut at the peer's block size, deadline feasibility
      re-checked, bounded attempts with per-request exponential backoff.
      Only when the budget or deadline is exhausted does the future resolve
      ``Rejected("error")``. Exactly-once delivery is enforced with
      confiscation tombstones: once a request is re-homed, a late result
      from the original (hung, recovered) instance is dropped, never
      double-delivered.

  watchdog (``runtime.fault_tolerance.JCTDeadlineWatchdog``)
      a maintenance thread compares every instance's in-flight batch age
      against ``factor x`` its *predicted* JCT (plus running-p95 and
      absolute floors). Because prefill-only JCT is precisely predictable,
      an overdue batch is provably wedged: the instance is failed (queued
      work re-homes) and the in-flight batch enters retry instead of
      hanging its futures. Completed steps feed the same watchdog —
      slower-than-deadline steps that still finished count as stragglers.

  brownout (``admission.BrownoutController``)
      backlog/shed-rate overload degrades service instead of collapsing it:
      level 1 tightens admission slack, level 2 disables hit co-packing's
      expensive gather paths on every engine, level 3 rejects new work
      (``Rejected("brownout")``). The level is exported as a gauge.

Telemetry lands in a ``MetricsRegistry`` (per-instance + global counters,
queue-depth/backlog gauges, latency and step-time histograms; see the
README's metric table for the robustness series).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.prefix_cache import token_chain
from repro.runtime.fault_tolerance import InstancePool, JCTDeadlineWatchdog
from repro.serving.admission import (AdmissionController, BrownoutController,
                                     Rejected)
from repro.serving.metrics import MetricsRegistry
from repro.serving.router import UserHashRouter
from repro.serving.tracing import SpanTracer


@dataclasses.dataclass
class RetryPolicy:
    """Idempotent-retry budget for work lost in flight.

    ``budget`` bounds re-submissions per request (0 disables retry: lost
    work resolves ``Rejected("error")`` immediately). ``backoff`` sizes a
    per-request FULL-JITTER exponential backoff before each re-submit:
    attempt k waits ``uniform(0, min(backoff_cap, backoff * 2**k))`` — full
    jitter decorrelates the retry herd after a correlated failure (one dead
    instance confiscates a whole batch at once), while the un-jittered
    ladder re-synchronized every retry onto the same peer at the same
    instant. ``backoff == 0`` retries immediately. ``jitter_seed`` makes
    the draw sequence deterministic for tests. When the server runs a
    maintenance thread, the wait is served by a delayed-resubmit queue
    drained there — the harvesting worker thread never sleeps a backoff
    inline. ``tombstone_ttl`` bounds how long a confiscated request's
    drop-late-result marker (and an unclaimed early-result orphan) is kept
    when nothing ever collects it."""
    budget: int = 2
    backoff: float = 0.02
    backoff_cap: float = 0.5
    tombstone_ttl: float = 300.0
    jitter_seed: Optional[int] = None


class _Tracked:
    """Server-side copy of a submission, kept while its future is open so a
    lost execution can be transparently re-submitted (the engine-side
    Request object is unreachable once a step pops it from the queue)."""

    __slots__ = ("user_id", "tokens", "allowed_tokens", "deadline",
                 "arrival", "attempts", "prior")

    def __init__(self, user_id, tokens, allowed_tokens, deadline, arrival):
        self.user_id = user_id
        self.tokens = tokens
        self.allowed_tokens = allowed_tokens
        self.deadline = deadline
        self.arrival = arrival
        self.attempts = 0
        self.prior: List[int] = []    # confiscated former req_ids


def _result_ok(res: Dict) -> bool:
    """Delivery gate: corrupted results are quarantined, never delivered.
    Checks both the engine's own non-finite flag and the scores themselves
    (defense in depth — corruption injected past the engine still stops
    here)."""
    if res.get("corrupt"):
        return False
    scores = res.get("scores")
    if scores and not all(math.isfinite(v) for v in scores.values()):
        return False
    return True


class AsyncServer:
    IDLE_WAIT = 0.02   # worker poll fallback when its queue is empty

    def __init__(self, pool: InstancePool, router=None,
                 admission: Optional[AdmissionController] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 retry: Optional[RetryPolicy] = None,
                 watchdog: Optional[JCTDeadlineWatchdog] = None,
                 brownout: Optional[BrownoutController] = None,
                 tracer: Optional[SpanTracer] = None):
        self.pool = pool
        self.router = router or UserHashRouter()
        self.admission = admission
        self.metrics = metrics or MetricsRegistry()
        if admission is not None and admission.metrics is None:
            admission.metrics = self.metrics   # feedback-loop telemetry
        self.retry = RetryPolicy() if retry is None else retry
        self.watchdog = watchdog
        self.brownout = brownout
        # request-lifecycle tracing (None = zero overhead). Every retry /
        # watchdog / brownout / re-home decision lands as an event on the
        # affected requests' timelines; engines bound via bind_telemetry
        # add queue/execute/score spans and BatchRecords.
        self.tracer = tracer
        if tracer is not None:
            pool.on_rehome = lambda rid, src, dst: tracer.event_rid(
                rid, "rehome", src=src, dst=dst)
        self._futures: Dict[int, Future] = {}
        self._early: Dict[int, object] = {}   # results that beat registration
        self._early_ts: Dict[int, float] = {}  # ... and when they parked
        self._tracked: Dict[int, _Tracked] = {}
        self._moved: Dict[int, float] = {}    # confiscated rid -> when
        # delayed-resubmit queue: (due, seq, rid, exclude, cause) — lost
        # work waiting out its jittered backoff, drained by maintenance
        self._delayed: List[Tuple[float, int, int, Optional[str], str]] = []
        self._delayed_seq = 0
        self._retry_rng = random.Random(self.retry.jitter_seed
                                        if self.retry is not None else None)
        self._rng_lock = threading.Lock()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._outstanding = 0
        self._events: Dict[str, threading.Event] = {}
        self._threads: Dict[str, threading.Thread] = {}
        self._maint_thread: Optional[threading.Thread] = None
        self._brownout_applied = 0
        self._stop = threading.Event()
        self._accepting = False

    # ---- lifecycle -------------------------------------------------------
    def _bind_engines(self) -> None:
        """Attach registry + tracer to every live engine (idempotent; test
        fakes without bind_telemetry are skipped). ChaosEngine proxies the
        call through to the wrapped engine."""
        for name in self.pool.live_names():
            bind = getattr(self.pool.engines.get(name), "bind_telemetry",
                           None)
            if bind is not None:
                bind(metrics=self.metrics, instance=name, tracer=self.tracer)

    def start(self) -> "AsyncServer":
        self._accepting = True
        self._bind_engines()
        for name in self.pool.live_names():
            self._start_worker(name)
        # the maintenance thread also serves the delayed-resubmit queue, so
        # it must run whenever backoff retries are possible — not only when
        # a watchdog/brownout is configured
        if (self.watchdog is not None or self.brownout is not None
                or (self.retry is not None and self.retry.budget > 0
                    and self.retry.backoff > 0)) \
                and self._maint_thread is None:
            self._maint_thread = threading.Thread(
                target=self._maintenance, name="serve-watchdog", daemon=True)
            self._maint_thread.start()
        return self

    def _start_worker(self, name: str) -> None:
        if name in self._threads and self._threads[name].is_alive():
            return
        if name not in self._events:     # keep the event stable per name:
            self._events[name] = threading.Event()   # workers hold a ref
        t = threading.Thread(target=self._worker, args=(name,),
                             name=f"engine-{name}", daemon=True)
        self._threads[name] = t
        t.start()

    def scale_to(self, names: List[str]) -> None:
        """Elastic rebalance hook: pool.scale_to redistributes queued work
        from removed instances; workers follow the instance set. Requests
        the pool could not re-home resolve as ``Rejected`` (mirroring
        ``mark_failed``) instead of hanging their futures."""
        dropped = self.pool.scale_to(names)
        self._bind_engines()
        for name in self.pool.live_names():
            self._start_worker(name)
        for r in dropped:
            self._reject(r.req_id, Rejected(
                "no_instances", "instance removed with no healthy peer",
                req_id=r.req_id, user_id=r.user_id))
        self._wake_all()

    def mark_failed(self, name: str) -> None:
        """Health hook: requeue the failed instance's waiting requests onto
        healthy peers (their futures stay valid) and retire its worker.
        With no healthy peer left the stranded requests resolve as
        ``Rejected`` rather than hanging their futures."""
        for r in self.pool.mark_failed(name):
            self._reject(r.req_id, Rejected(
                "no_instances", "instance failed with no healthy peer",
                req_id=r.req_id, user_id=r.user_id))
        self._wake_all()

    def _wake_all(self) -> None:
        # snapshot: submit() may insert an event concurrently (setdefault)
        for ev in list(self._events.values()):
            ev.set()

    # ---- submission ------------------------------------------------------
    def _cut_chains(self, tokens: Sequence[int],
                    live: Dict[str, object]) -> Dict[int, tuple]:
        """Chains are granular in the engine's block size: on a
        heterogeneous pool, routing/admission probes and the enqueue must
        each see the chain cut at THEIR engine's block size, or cache
        matching (and the cache inserts keyed on the chain) silently
        misfire."""
        chains: Dict[int, tuple] = {}
        for e in live.values():
            bs = e.ecfg.block_size
            if bs not in chains:
                chains[bs] = token_chain(tokens, bs)
        return chains

    def _enqueue(self, live: Dict[str, object], first: str,
                 tokens: Sequence[int], chains: Dict[int, tuple], *,
                 user_id, allowed_tokens, deadline,
                 arrival) -> Optional[Tuple[str, int]]:
        """Enqueue on ``first``, falling back to each remaining live peer
        on a (transient) submit failure. Returns (instance, req_id), or
        None when every live instance refused the enqueue."""
        order = [first] + [n for n in sorted(live) if n != first]
        for name in order:
            eng = live[name]
            try:
                rid = eng.submit(tokens, allowed_tokens, user_id=user_id,
                                 now=arrival, deadline=deadline,
                                 chain=chains[eng.ecfg.block_size])
                return name, rid
            except Exception:
                self.metrics.counter("submit_failures", name).inc()
        return None

    def submit(self, user_id: Optional[str], tokens: Sequence[int], *,
               allowed_tokens: Optional[Sequence[int]] = None,
               deadline: Optional[float] = None) -> "Future":
        """Non-blocking: route, admit, enqueue; resolves to a result dict or
        a typed ``Rejected``. A transient enqueue failure falls back to the
        next-best live instance (admission was checked against the routed
        instance — the fallback is best-effort by design: refusing outright
        because the preferred instance hiccuped would turn a transient
        fault into a hard rejection)."""
        fut = Future()
        fut.set_running_or_notify_cancel()
        sp = self.tracer
        ctx = (sp.begin(user_id=user_id, n_input=len(tokens),
                        deadline=deadline) if sp is not None else None)

        def _early_reject(rej: Rejected, count: bool = True) -> "Future":
            if count:
                self._count_rejection(rej)
            if sp is not None:
                sp.finish(ctx, f"rejected:{rej.reason}",
                          detail=rej.detail or "")
            fut.set_result(rej)
            return fut

        if not self._accepting:
            return _early_reject(Rejected("shutdown", "server not accepting",
                                          user_id=user_id), count=False)
        if self.brownout is not None and self.brownout.level >= 3:
            return _early_reject(Rejected(
                "brownout", "pool shedding load (brownout level 3)",
                user_id=user_id))
        live = {n: self.pool.engines[n] for n in self.pool.live_names()}
        if not live:
            return _early_reject(Rejected("no_instances", user_id=user_id))
        chains = self._cut_chains(tokens, live)
        routed = self.router.route(user_id=user_id, n_input=len(tokens),
                                   chain=next(iter(chains.values())),
                                   instances=live, chains=chains)
        eng = live[routed]
        arrival = time.perf_counter()
        # routed-instance probe values: admission consumes them, and the
        # route decision is only auditable with the numbers it was made on.
        # Probe only when someone needs them — the untraced/no-admission
        # fast path must not pay two extra engine-lock acquisitions.
        pending = predicted = None
        restore_s = 0.0
        if self.admission is not None or ctx is not None:
            pending = eng.pending_jct()
            predicted = eng.predict_jct(len(tokens),
                                        chains[eng.ecfg.block_size])
            # tiered engine: the JCT probe counts a host-restorable prefix
            # as cached, but restoring it costs a PCIe transfer first —
            # price that into the bound admission checks against
            est_fn = getattr(eng, "restore_estimate", None)
            if est_fn is not None:
                try:
                    restore_s = float(est_fn(
                        chains[eng.ecfg.block_size]).get("restore_s", 0.0))
                except Exception:
                    restore_s = 0.0
            predicted += restore_s
        if ctx is not None:
            sp.event(ctx, "route", instance=routed,
                     router=type(self.router).__name__,
                     pending_jct=pending, predicted_jct=predicted,
                     restore_s=restore_s)
        if self.admission is not None:
            rej = self.admission.check(len(tokens), deadline, arrival,
                                       pending, predicted, user_id=user_id)
            if ctx is not None:
                sp.event(ctx, "admission",
                         verdict="reject" if rej is not None else "admit",
                         reason=getattr(rej, "reason", None),
                         pending_jct=pending, predicted_jct=predicted)
            if rej is not None:
                return _early_reject(rej)
        got = self._enqueue(live, routed, tokens, chains, user_id=user_id,
                            allowed_tokens=allowed_tokens, deadline=deadline,
                            arrival=arrival)
        if got is None:
            return _early_reject(Rejected(
                "error", "enqueue failed on every live instance",
                user_id=user_id))
        name, rid = got
        if ctx is not None:
            sp.bind(ctx, rid)
            sp.event(ctx, "enqueue", instance=name, req_id=rid)
        # routing-time prefetch (paper §9): start the host->device transfer
        # of this request's restorable prefix NOW, so by the time Algorithm 1
        # picks it the KV is device-resident. ``name`` is the instance that
        # actually accepted the enqueue (fallback may differ from ``routed``).
        pf = getattr(live[name], "prefetch_prefix", None)
        if pf is not None:
            try:
                nblk = pf(chains[live[name].ecfg.block_size], rid=rid)
            except Exception:
                nblk = 0
            if nblk:
                self.metrics.counter(
                    "prefetches_triggered", name,
                    help="router-time host->device KV prefetches").inc()
                if ctx is not None:
                    sp.event(ctx, "prefetch", instance=name, blocks=nblk)
        with self._lock:
            early = self._early.pop(rid, None)
            self._early_ts.pop(rid, None)
            if early is None:
                self._futures[rid] = fut
                if self.retry is not None and self.retry.budget > 0:
                    self._tracked[rid] = _Tracked(
                        user_id, list(tokens),
                        tuple(allowed_tokens) if allowed_tokens else None,
                        deadline, arrival)
                self._outstanding += 1
        self.metrics.counter("requests_submitted", name).inc()
        # setdefault: the worker for an instance added via pool.scale_to()
        # directly (or racing server.scale_to) may not exist yet — the event
        # must, so _start_worker can hand it over
        self._events.setdefault(name, threading.Event()).set()
        if early is not None:        # worker finished before we registered
            if ctx is not None:
                sp.finish(ctx, f"rejected:{early.reason}"
                          if isinstance(early, Rejected) else "delivered")
            fut.set_result(early)
            return fut
        # close the enqueue-vs-failure race: if the instance was failed (or
        # the server stopped accepting) while we were enqueueing, the drain
        # may have run BEFORE our append — reclaim the orphan and re-home it
        # to a healthy peer through the retry machinery (the common case in
        # process mode, where submits race the ~100ms failure window), else
        # reject it. cancel() returning None means a worker/peer owns it.
        if not self.pool.healthy.get(name, False) or not self._accepting:
            if eng.cancel(rid) is not None:
                peers = [n for n in self.pool.live_names() if n != name]
                if (self._accepting and peers and self.retry is not None
                        and self.retry.budget > 0):
                    self._handle_lost(rid, name, "enqueue raced failure")
                else:
                    reason = ("shutdown" if not self._accepting
                              else "no_instances")
                    self._reject(rid, Rejected(reason, "instance lost after "
                                               "enqueue", req_id=rid,
                                               user_id=user_id))
        return fut

    def cancel(self, req_id: int) -> bool:
        """Cancel a QUEUED request (no effect once its forward started)."""
        for name in self.pool.live_names():
            r = self.pool.engines[name].cancel(req_id)
            if r is not None:
                self._reject(req_id, Rejected("cancelled", req_id=req_id,
                                              user_id=r.user_id))
                return True
        return False

    # ---- completion ------------------------------------------------------
    def _count_rejection(self, rej: Rejected) -> None:
        """Single site for the rejection counter pair — every rejection
        path must keep stats() in sync with actual outcomes."""
        self.metrics.counter("requests_rejected").inc()
        self.metrics.counter(f"rejected_{rej.reason}").inc()

    def _reject(self, rid: int, rej: Rejected) -> None:
        """Resolve an already-registered request as ``Rejected``."""
        if self._resolve(rid, rej) != "dropped":
            self._count_rejection(rej)

    def _resolve(self, rid: int, result) -> str:
        """Resolve ``rid``'s future with ``result``.

        Returns the delivery status:
          "delivered"  the open future was resolved
          "parked"     submit() hasn't registered the future yet — the
                       result waits in ``_early`` and resolves at
                       registration (counts as delivered for telemetry)
          "dropped"    ``rid`` was confiscated for retry (crash/watchdog/
                       quarantine) — a late result must NOT double-resolve
                       the future its replacement now owns
        """
        with self._lock:
            if self._moved.pop(rid, None) is not None:
                if self.tracer is not None:
                    self.tracer.postmortem_rid(rid, "tombstone_drop")
                return "dropped"
            fut = self._futures.pop(rid, None)
            if fut is None:
                # submit() hasn't registered the future yet — park the result
                # (submit finishes the trace at registration). Timestamped:
                # an orphan nobody ever claims (e.g. a dropped-response
                # submit the worker enqueued anyway) is GC'd by maintenance
                self._early[rid] = result
                self._early_ts[rid] = time.perf_counter()
                return "parked"
            self._tracked.pop(rid, None)
            self._outstanding -= 1
            self._cond.notify_all()
        if self.tracer is not None:
            self.tracer.finish_rid(
                rid, f"rejected:{result.reason}"
                if isinstance(result, Rejected) else "delivered")
        fut.set_result(result)
        return "delivered"

    # ---- idempotent retry ------------------------------------------------
    def _handle_lost(self, rid: int, exclude: Optional[str],
                     cause: str) -> None:
        """An in-flight execution of ``rid`` was lost (mid-step crash,
        watchdog trip, quarantined result): re-submit it to a healthy peer
        within the retry budget, else resolve ``Rejected("error")``.

        Single-owner per rid: the first caller confiscates (the future
        moves to the replacement req_id, the old rid becomes a tombstone
        that drops its late result); concurrent callers — the watchdog and
        a dying worker can race on the same batch — see the rid gone and
        return. Safe to call for rids that already resolved."""
        with self._lock:
            if rid in self._moved or rid not in self._futures:
                return                  # already resolved or confiscated
            tr = self._tracked.get(rid)
        sp = self.tracer
        if sp is not None:
            sp.event_rid(rid, "lost", cause=cause, instance=exclude)
        pol = self.retry
        if tr is None or pol is None or pol.budget <= 0:
            self._reject(rid, Rejected("error", cause, req_id=rid,
                                       user_id=getattr(tr, "user_id", None)))
            return
        if tr.attempts >= pol.budget:
            self._reject(rid, Rejected(
                "error", f"retry budget exhausted after {tr.attempts} "
                f"attempts ({cause})", req_id=rid, user_id=tr.user_id))
            return
        if not self._accepting:
            self._reject(rid, Rejected("error", f"lost during shutdown "
                                       f"({cause})", req_id=rid,
                                       user_id=tr.user_id))
            return
        delay = 0.0
        if pol.backoff > 0:
            cap = min(pol.backoff_cap, pol.backoff * (2 ** tr.attempts))
            with self._rng_lock:        # full jitter: uniform(0, ladder)
                delay = self._retry_rng.uniform(0.0, cap)
        if delay > 0 and self._maint_thread is not None:
            # park on the delayed-resubmit queue instead of sleeping HERE:
            # this path runs on the harvesting worker thread (and on the
            # watchdog scan), where an inline backoff stalls every other
            # request on the instance for the duration
            with self._lock:
                if rid in self._moved or rid not in self._futures:
                    return
                self._delayed_seq += 1
                heapq.heappush(self._delayed,
                               (time.perf_counter() + delay,
                                self._delayed_seq, rid, exclude, cause))
            self.metrics.counter("retries_delayed").inc()
            if sp is not None:
                sp.event_rid(rid, "retry_delayed", delay=delay)
            return
        if delay > 0:
            time.sleep(delay)     # no maintenance thread: legacy inline
        self._resubmit_lost(rid, exclude, cause)

    def _resubmit_lost(self, rid: int, exclude: Optional[str],
                       cause: str) -> None:
        """Route/enqueue/re-key tail of ``_handle_lost``, entered after the
        backoff wait (inline or from the delayed queue). Re-checks
        ownership: the rid may have resolved or been confiscated while it
        waited."""
        sp = self.tracer
        with self._lock:
            if rid in self._moved or rid not in self._futures:
                return
            tr = self._tracked.get(rid)
        if tr is None:
            self._reject(rid, Rejected("error", cause, req_id=rid))
            return
        if not self._accepting:
            self._reject(rid, Rejected("error", f"lost during shutdown "
                                       f"({cause})", req_id=rid,
                                       user_id=tr.user_id))
            return
        live = {n: self.pool.engines[n] for n in self.pool.live_names()
                if n != exclude}
        if not live:
            # no *peer*: fall back to the excluded instance if it is still
            # healthy (quarantine keeps the producer alive; a transient
            # corruption can succeed on re-run even there)
            live = {n: self.pool.engines[n]
                    for n in self.pool.live_names()}
        if not live:
            self._reject(rid, Rejected(
                "error", f"no healthy instance for retry ({cause})",
                req_id=rid, user_id=tr.user_id))
            return
        now = time.perf_counter()
        chains = self._cut_chains(tr.tokens, live)
        peer = self.router.route(user_id=tr.user_id,
                                 n_input=len(tr.tokens),
                                 chain=next(iter(chains.values())),
                                 instances=live, chains=chains)
        eng = live[peer]
        if tr.deadline is not None:
            predicted = (eng.pending_jct() + eng.predict_jct(
                len(tr.tokens), chains[eng.ecfg.block_size]))
            if now + predicted > tr.deadline:
                self._reject(rid, Rejected(
                    "error", f"deadline infeasible on retry ({cause})",
                    req_id=rid, user_id=tr.user_id,
                    predicted_jct=predicted))
                return
        got = self._enqueue(live, peer, tr.tokens, chains,
                            user_id=tr.user_id,
                            allowed_tokens=tr.allowed_tokens,
                            deadline=tr.deadline, arrival=tr.arrival)
        if got is None:
            self._reject(rid, Rejected(
                "error", f"retry enqueue failed on every live instance "
                f"({cause})", req_id=rid, user_id=tr.user_id))
            return
        new_name, new_rid = got
        with self._lock:
            fut = self._futures.pop(rid, None)
            if fut is not None:
                self._tracked.pop(rid, None)
                self._moved[rid] = now    # late result from the old run:
                tr.prior.append(rid)      # drop it, never double-deliver
                tr.attempts += 1
                early = self._early.pop(new_rid, None)
                self._early_ts.pop(new_rid, None)
                if early is None:
                    self._futures[new_rid] = fut
                    self._tracked[new_rid] = tr
        if fut is not None and sp is not None:
            # the replacement rid joins the original timeline; the old rid
            # stays mapped so the confiscated attempt's late result still
            # lands here (as a tombstone_drop event)
            sp.rebind(rid, new_rid)
            sp.event_rid(new_rid, "retry", attempt=tr.attempts,
                         from_rid=rid, instance=new_name, cause=cause)
        if fut is None:
            # rid resolved while we were re-submitting (a late result won
            # the race) — the replacement is a duplicate: reclaim it, and
            # if a worker already owns it, tombstone its result instead
            if live[new_name].cancel(new_rid) is None:
                with self._lock:
                    self._moved[new_rid] = now
            return
        self.metrics.counter("requests_retried", new_name).inc()
        self._events.setdefault(new_name, threading.Event()).set()
        if early is not None:            # peer served before the re-key
            with self._lock:
                self._outstanding -= 1
                self._cond.notify_all()
            fut.set_result(early)

    # ---- watchdog + brownout maintenance ---------------------------------
    def _maintenance(self) -> None:
        interval = (self.watchdog.interval if self.watchdog is not None
                    else 0.05)
        while not self._stop.wait(interval):
            if self.watchdog is not None:
                self._watchdog_scan()
            if self.brownout is not None:
                self._brownout_tick()
            self._drain_delayed()
            self._gc_tombstones()

    def _watchdog_scan(self) -> None:
        """Trip any instance whose in-flight batch is past ``factor x`` its
        predicted JCT: the batch is provably wedged (prefill-only JCT is
        precisely predictable), so fail the instance — queued work re-homes
        — and send the in-flight batch through retry instead of letting its
        futures hang."""
        wd = self.watchdog
        now = time.perf_counter()
        for name in self.pool.live_names():
            eng = self.pool.engines.get(name)
            snap = getattr(eng, "inflight_snapshot", None)
            if eng is None or snap is None:
                continue
            try:
                ids, pred, t0 = snap()
            except Exception:
                continue
            if not ids:
                continue
            elapsed = now - t0
            deadline = wd.batch_deadline(pred)
            if elapsed <= deadline:
                continue
            wd.trips += 1
            self.metrics.counter("watchdog_trips", name).inc()
            if self.tracer is not None:
                for rid in ids:
                    self.tracer.event_rid(rid, "watchdog_trip",
                                          instance=name, elapsed=elapsed,
                                          batch_deadline=deadline)
            self.mark_failed(name)
            for rid in ids:
                self._handle_lost(rid, exclude=name,
                                  cause=f"watchdog trip: batch "
                                        f"{elapsed:.2f}s past its "
                                        f"{deadline:.2f}s JCT deadline")

    def _brownout_tick(self) -> None:
        backlog = 0.0
        for name in self.pool.live_names():
            eng = self.pool.engines.get(name)
            if eng is None:
                continue
            try:
                backlog = max(backlog, eng.pending_jct())
            except Exception:
                continue
        shed = (self.admission.shed_rate()
                if self.admission is not None else 0.0)
        self._apply_brownout(self.brownout.evaluate(backlog, shed))

    def _apply_brownout(self, level: int) -> None:
        if level == self._brownout_applied:
            return
        prev, self._brownout_applied = self._brownout_applied, level
        if self.tracer is not None:
            # a brownout transition affects every in-flight request
            self.tracer.broadcast(
                "brownout", level=level, prev=prev,
                state=BrownoutController.LEVELS[level])
        m = self.metrics
        m.gauge("brownout_level").set(level)
        m.state_gauge("brownout_state", BrownoutController.LEVELS).set(level)
        m.counter("brownout_escalations" if level > prev
                  else "brownout_deescalations").inc()
        if self.admission is not None:
            self.admission.set_pressure(self.brownout.pressure())
        degraded = level >= 2
        for name in self.pool.live_names():
            set_deg = getattr(self.pool.engines.get(name),
                              "set_degraded", None)
            if set_deg is not None:
                set_deg(degraded)

    def _drain_delayed(self) -> None:
        """Re-submit lost work whose jittered backoff has elapsed (the
        delayed-resubmit queue ``_handle_lost`` parks on when a
        maintenance thread exists)."""
        now = time.perf_counter()
        ready = []
        with self._lock:
            while self._delayed and self._delayed[0][0] <= now:
                ready.append(heapq.heappop(self._delayed))
        for _, _, rid, exclude, cause in ready:
            self._resubmit_lost(rid, exclude, cause)

    def _gc_tombstones(self) -> None:
        """Drop confiscation tombstones whose late result never arrived
        (the crashed worker died before harvesting), and early-result
        orphans no submit() ever claimed (a dropped-response submit the
        worker enqueued and served anyway) — bounds both sets."""
        ttl = self.retry.tombstone_ttl if self.retry is not None else 300.0
        cutoff = time.perf_counter() - ttl
        with self._lock:
            stale = [rid for rid, t in self._moved.items() if t < cutoff]
            for rid in stale:
                del self._moved[rid]
            orphans = [rid for rid, t in self._early_ts.items()
                       if t < cutoff]
            for rid in orphans:
                self._early.pop(rid, None)
                del self._early_ts[rid]
        for _ in orphans:
            self.metrics.counter("early_orphans_gced").inc()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every admitted request has resolved."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._outstanding > 0:
                left = (None if deadline is None
                        else max(0.0, deadline - time.monotonic()))
                if left == 0.0 or not self._cond.wait(timeout=left or 0.5):
                    if deadline is not None and time.monotonic() >= deadline:
                        return False
        return True

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        self._accepting = False
        drained = self.drain(timeout) if drain else False
        if not drained:
            # not draining, or drain timed out: every still-queued request
            # must resolve (``Rejected``) — never strand a future
            for name in list(self.pool.engines):
                eng = self.pool.engines[name]
                with eng.lock:
                    dropped = list(eng.queue)
                    eng.queue.clear()
                for r in dropped:
                    self._reject(r.req_id, Rejected(
                        "shutdown", req_id=r.req_id, user_id=r.user_id))
        self._stop.set()
        self._wake_all()
        # flush the delayed-resubmit queue: entries not yet due when the
        # maintenance thread stops must still resolve their futures
        with self._lock:
            flush, self._delayed = list(self._delayed), []
        for _, _, rid, _, cause in flush:
            self._reject(rid, Rejected(
                "shutdown", f"retry abandoned at shutdown ({cause})",
                req_id=rid))
        for t in self._threads.values():
            t.join(timeout=5.0)
        if self._maint_thread is not None:
            self._maint_thread.join(timeout=5.0)

    # ---- worker loop -----------------------------------------------------
    def _worker(self, name: str) -> None:
        ev = self._events[name]
        m = self.metrics
        while not self._stop.is_set():
            # re-fetch per iteration: scale_to may replace the engine object
            # behind a reused instance name while we were mid-step
            eng = self.pool.engines.get(name)
            if eng is None or not self.pool.healthy.get(name, False):
                # failed/removed: park instead of exiting. If the instance
                # is resurrected (scale_to remove + re-add), this thread
                # resumes as its worker — exiting here would race
                # _start_worker's is_alive() check and leave a revived
                # instance with no worker. A parked thread costs one idle
                # poll and exits at shutdown.
                if self._threads.get(name) is not threading.current_thread():
                    return                  # superseded by a newer worker
                ev.wait(timeout=self.IDLE_WAIT)
                ev.clear()
                continue
            for r in eng.shed_expired():
                # feedback: a shed request is one admission under-estimated
                if self.admission is not None:
                    self.admission.record_outcome(shed=True)
                self._reject(r.req_id, Rejected(
                    "shed", "deadline unreachable in queue",
                    req_id=r.req_id, user_id=r.user_id))
            t0 = time.perf_counter()
            try:
                rid = eng.step()
            except Exception:
                # a dying worker must not strand futures: fail the instance
                # FIRST (queued work re-homes to peers while they exclude
                # it), then send the mid-step batch through idempotent
                # retry — it resolves Rejected("error") only once the
                # budget, deadline, or pool is exhausted
                m.counter("engine_errors", name).inc()
                lost = list(getattr(eng, "_inflight", []))
                self.mark_failed(name)
                for rid2 in lost:
                    self._handle_lost(rid2, exclude=name,
                                      cause="instance crashed mid-step")
                continue                    # park above until resurrected
            if rid is None:
                ev.wait(timeout=self.IDLE_WAIT)
                ev.clear()
                continue
            step_s = time.perf_counter() - t0
            m.histogram("step_seconds", name).observe(step_s)
            # compile steps are excluded from the watchdog history for the
            # same reason the engine excludes them from the JCT fit: a
            # multi-second jit compile is neither a straggler nor a sample
            # of normal step time, and one of them would drag the p95
            # fallback deadline past real hangs
            if (self.watchdog is not None
                    and not getattr(eng, "_step_compiled", False)
                    and self.watchdog.observe(step_s)):
                # finished, but past the p95 deadline: a straggler signal
                # worth counting even though nothing needed recovery
                m.counter("straggler_steps", name).inc()
            with eng.lock:
                # pop the future's delivery payload; default None — a result
                # can be legitimately absent (request cancelled or
                # confiscated between step completion and harvest), and a
                # KeyError here would misclassify the ENGINE as failed
                served = [(i, eng.results.pop(i, None))
                          for i in eng.last_step_ids]
                depth = len(eng.queue)
            m.gauge("queue_depth", name).set(depth)
            m.gauge("backlog_seconds", name).set(eng.pending_jct())
            for rid2, res in served:
                if res is None:
                    continue
                if not _result_ok(res):
                    # non-finite score: quarantine — never deliver NaN — and
                    # re-run on a peer (the forward is idempotent; transient
                    # corruption re-runs clean, persistent corruption
                    # exhausts the budget into Rejected("error"))
                    m.counter("results_quarantined", name).inc()
                    if self.tracer is not None:
                        self.tracer.event_rid(
                            rid2, "quarantine", instance=name,
                            corrupt=res.get("corrupt") or "nan in scores")
                    self._handle_lost(
                        rid2, exclude=name,
                        cause=f"non-finite score quarantined "
                              f"({res.get('corrupt') or 'nan in scores'})")
                    continue
                status = self._resolve(rid2, res)
                if status == "dropped":
                    # this batch was confiscated (watchdog trip) while the
                    # step dawdled — its replacement owns the future now
                    m.counter("late_results_dropped", name).inc()
                    continue
                m.counter("requests_served", name).inc()
                m.histogram("latency_seconds", name).observe(res["latency"])
                if (self.admission is not None
                        and res.get("deadline") is not None):
                    self.admission.record_outcome(shed=False)

    # ---- introspection ---------------------------------------------------
    def stats(self) -> Dict:
        return {
            "served": self.metrics.total("requests_served"),
            "rejected": self.metrics.total("requests_rejected"),
            "retried": self.metrics.total("requests_retried"),
            "watchdog_trips": self.metrics.total("watchdog_trips"),
            "quarantined": self.metrics.total("results_quarantined"),
            "brownout_level": (self.brownout.level
                               if self.brownout is not None else 0),
            "latency": self.metrics.merged_histogram(
                "latency_seconds").summary(),
            "tracer": (self.tracer.stats()
                       if self.tracer is not None else None),
            "per_instance": {n: self.pool.engines[n].stats()
                             for n in self.pool.live_names()},
        }
