"""gemma2-9b [dense] — local+global alternating attention, logit softcaps.

[arXiv:2408.00118; hf] head_dim fixed at 256 (not d_model/heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=256_000,
    head_dim=256,
    local_global=True,
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
)
