"""internvl2-2b [vlm] — InternViT frontend (STUB) + InternLM2 backbone.

[arXiv:2404.16821; hf] The vision tower is a stub: ``input_specs`` ships
precomputed patch embeddings of shape (batch, seq, d_model); the backbone
(this config) consumes them directly (``embed_inputs=False``). Labels/logits
still span the full text vocab.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    embed_inputs=False,
    tie_embeddings=False,
)
