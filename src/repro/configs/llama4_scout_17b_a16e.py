"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E] The "early fusion" multimodal frontend
is outside the assigned backbone; text path only. Full attention ->
long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    num_experts=16,
    num_experts_per_tok=1,
    shared_expert=True,
    rope_theta=500_000.0,
    tie_embeddings=False,
)
