"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf] All layers MoE. SWA on every layer bounds the KV
working set -> long_500k decode runs (KV = window).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16_384,
    vocab_size=32_768,
    sliding_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)
