"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf] 54 Mamba2 layers; a single shared attention+MLP block
(one weight set) is applied every ``attn_every`` layers (9 applications).
ssm_state=64 per assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10_240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    attn_every=6,
    tie_embeddings=True,
)
