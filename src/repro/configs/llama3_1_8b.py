"""llama3.1-8b [dense] — the paper's own evaluation model (Table 3, low-end row).

[arXiv:2407.21783; hf:meta-llama/Llama-3.1-8B] Not part of the assigned 10;
included because the paper's MIL/JCT numbers are reported on it.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    rope_theta=500_000.0,
    tie_embeddings=False,
)
