"""musicgen-large [audio] — decoder-only over EnCodec tokens. [arXiv:2306.05284]

The EnCodec encoder/decoder is a STUB: inputs are precomputed codec token ids
over a 2048-entry codebook (``input_specs`` provides int32 frames).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    tie_embeddings=False,
)
