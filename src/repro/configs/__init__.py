from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    SHAPE_BY_NAME,
    shape,
    cell_is_runnable,
    long_context_capable,
)
from repro.configs.registry import (  # noqa: F401
    ASSIGNED,
    REGISTRY,
    get_config,
    list_archs,
    reduce_config,
)
