"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060] d_inner = 2*768 = 1536, headdim 64 -> 24 SSD heads,
state N=128. No KV cache exists; PrefillOnly's suffix-KV-discard is
inapplicable (see DESIGN.md §Arch-applicability) — the per-layer SSM state is
O(1) and doubles as the "prefix cache" via state checkpoints.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    tie_embeddings=True,
)
