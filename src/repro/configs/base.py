"""Model / workload configuration for the PrefillOnly reproduction.

Every assigned architecture is expressed as a ``ModelConfig``. The config is a
frozen dataclass so it can be hashed into jit caches and closed over safely.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (decoder-only LM backbone).

    ``family`` drives block selection:
      dense   - transformer blocks (attention + SwiGLU MLP)
      moe     - transformer blocks with mixture-of-experts MLP
      ssm     - Mamba2 (SSD) blocks, attention-free
      hybrid  - Mamba2 backbone + shared attention block every ``attn_every``
      vlm     - dense backbone fed precomputed patch embeddings (frontend stub)
      audio   - dense backbone over codec tokens (frontend stub)
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // num_heads

    # --- attention features ---
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: int = 0            # 0 = full attention
    local_global: bool = False         # gemma2: alternate local(SWA)/global
    attn_softcap: float = 0.0          # gemma2: tanh softcap on attn logits
    final_softcap: float = 0.0         # gemma2: tanh softcap on LM logits

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    shared_expert: bool = False        # llama4-style always-on expert
    capacity_factor: float = 1.25

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256               # SSD chunk length
    attn_every: int = 0                # hybrid: shared attn block cadence

    # --- embeddings / io ---
    embed_inputs: bool = True          # False: inputs arrive as embeddings (vlm)
    tie_embeddings: bool = True

    # --- execution ---
    packed_attention: bool = False     # exact-causal tile packing (perf)
    dtype: str = "bfloat16"            # activations / compute
    param_dtype: str = "bfloat16"      # stored weights (serving); train uses fp32 master
    hybrid_chunk: int = 2048           # PrefillOnly hybrid prefilling chunk (0 = off)
    remat: bool = True                 # activation checkpointing for train
    logits_chunk: int = 2048           # chunked LM-head/xent (0 = off)

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived quantities ----
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def param_count(self) -> int:
        """Analytic parameter count (used by roofline + MIL model)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        embed = V * D
        lm_head = 0 if self.tie_embeddings else V * D
        attn = D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D
        mlp = 3 * D * F
        if self.is_moe:
            mlp = mlp * self.num_experts + D * self.num_experts  # + router
            if self.shared_expert:
                mlp += 3 * D * F
        ssm = 0
        if self.has_ssm:
            di, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            # in_proj -> (z, x, B, C, dt), conv, A/D, norm, out_proj
            ssm = D * (2 * di + 2 * N + Hs) + self.ssm_conv_width * (di + 2 * N)
            ssm += 2 * Hs + di + di * D
        per_layer = 0
        norms = 2 * D
        if self.family == "ssm":
            per_layer = ssm + D
        elif self.family == "hybrid":
            n_attn = max(1, self.num_layers // max(self.attn_every, 1))
            per_layer = ssm + D
            # shared attention block counted once (shared weights)
            shared = attn + 3 * D * self.d_ff_shared + norms
            return embed + lm_head + L * per_layer + shared + D
        else:
            per_layer = attn + mlp + norms
        return embed + lm_head + L * per_layer + D

    @property
    def d_ff_shared(self) -> int:
        """FFN width of the shared attention block (hybrid family)."""
        return self.d_ff if self.d_ff else 4 * self.d_model

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.num_layers
        total = self.param_count()
        all_expert = L * (3 * D * F) * self.num_experts
        active_expert = L * (3 * D * F) * self.num_experts_per_tok
        return total - all_expert + active_expert

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """KV-cache bytes per token across all layers (full attention view)."""
        if self.family == "ssm":
            return 0
        n_attn_layers = self.num_layers
        if self.family == "hybrid":
            n_attn_layers = max(1, self.num_layers // max(self.attn_every, 1))
        return n_attn_layers * 2 * self.num_kv_heads * self.head_dim * bytes_per_el


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """An assigned (workload) input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape(name: str) -> ShapeConfig:
    return SHAPE_BY_NAME[name]


def long_context_capable(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (SSM / hybrid / all-SWA)."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    # all-layers sliding-window attention bounds the KV working set
    if cfg.sliding_window > 0 and not cfg.local_global:
        return True
    return False


def cell_is_runnable(cfg: ModelConfig, shp: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell runs, and the reason if not."""
    if shp.name == "long_500k" and not long_context_capable(cfg):
        return False, ("skip: pure full-attention arch (quadratic attention / "
                       "unbounded KV) — per assignment rules")
    return True, ""
