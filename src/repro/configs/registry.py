"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import ModelConfig

from repro.configs.internvl2_2b import CONFIG as _internvl2
from repro.configs.qwen1_5_0_5b import CONFIG as _qwen
from repro.configs.phi3_mini_3_8b import CONFIG as _phi3
from repro.configs.gemma2_9b import CONFIG as _gemma2
from repro.configs.granite_3_8b import CONFIG as _granite
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.mixtral_8x22b import CONFIG as _mixtral
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.llama3_1_8b import CONFIG as _llama31

ASSIGNED: Dict[str, ModelConfig] = {
    "internvl2-2b": _internvl2,
    "qwen1.5-0.5b": _qwen,
    "phi3-mini-3.8b": _phi3,
    "gemma2-9b": _gemma2,
    "granite-3-8b": _granite,
    "mamba2-130m": _mamba2,
    "musicgen-large": _musicgen,
    "zamba2-2.7b": _zamba2,
    "mixtral-8x22b": _mixtral,
    "llama4-scout-17b-a16e": _llama4,
}

EXTRA: Dict[str, ModelConfig] = {
    "llama3.1-8b": _llama31,
}

REGISTRY: Dict[str, ModelConfig] = {**ASSIGNED, **EXTRA}


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch]


def list_archs(assigned_only: bool = True) -> List[str]:
    return sorted(ASSIGNED if assigned_only else REGISTRY)


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config to a CPU-smoke-testable size, same family/features.

    Keeps every structural feature (GQA ratio, softcaps, SWA, MoE top-k, SSD
    state) while cutting width/depth/vocab so a forward+train step runs on one
    CPU core in seconds.
    """
    small = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        vocab_size=min(cfg.vocab_size, 512),
        hybrid_chunk=32,
        logits_chunk=64,
        ssm_chunk=16,
    )
    if cfg.num_heads:
        small["num_heads"] = 4
        small["num_kv_heads"] = max(1, 4 * cfg.num_kv_heads // cfg.num_heads)
        small["head_dim"] = 32
    if cfg.d_ff:
        small["d_ff"] = 256
    if cfg.sliding_window:
        small["sliding_window"] = 16
    if cfg.is_moe:
        small["num_experts"] = min(cfg.num_experts, 4)
        small["num_experts_per_tok"] = min(cfg.num_experts_per_tok, 2)
    if cfg.has_ssm:
        small["ssm_state"] = 16
        small["ssm_headdim"] = 16
    if cfg.attn_every:
        small["attn_every"] = 2
    if cfg.local_global:
        small["num_layers"] = 4  # two (local, global) pairs
    small.update(overrides)
    small["name"] = cfg.name + "-smoke"
    return dataclasses.replace(cfg, **small)
