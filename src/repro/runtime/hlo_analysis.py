"""Post-compile HLO analysis: exact FLOPs / HBM traffic / collective bytes.

Why not ``compiled.cost_analysis()`` alone? XLA's HloCostAnalysis visits a
while-loop BODY ONCE — our models scan over stacked layers, so every number
would be undercounted by the layer count. This parser rebuilds the call graph
from the optimized HLO text, reads ``known_trip_count`` off each while op,
and propagates multipliers down while bodies / called computations, giving:

  * flops              dot FLOPs x loop multipliers (per device)
  * hbm_bytes          top-level operand+result bytes x multipliers (a
                       fusion-granularity HBM-traffic model; per device)
  * collective_bytes   per collective kind, link-bytes moved per device
                       (ring formulas from replica_group size) x multipliers

All quantities are per-device (the module is the post-SPMD partitioned one).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# type group is lazy-any: tuple types embed /*index=N*/ comments
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r"known_trip_count\W+n\W+(\d+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_of(type_str: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",") if d.strip())
    return m.group(1), dims


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    line: str
    comp: str


@dataclasses.dataclass
class HLOReport:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    dot_flops_by_comp: Dict[str, float] = dataclasses.field(default_factory=dict)

    def asdict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": dict(self.collective_by_kind),
            "collective_count": self.collective_count,
        }


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)          # iota form: [ngroups,gsize]<=[N]
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)     # explicit list form: {{0,1,2,...}}
    if m:
        return max(1, len(m.group(1).split(",")))
    return total_devices


def parse_hlo(text: str, total_devices: int = 1) -> HLOReport:
    # ---- pass 1: computations, instruction defs, shapes -------------------
    comp = "__toplevel__"
    instrs: List[Instruction] = []
    shapes: Dict[str, str] = {}
    comp_of: Dict[str, str] = {}
    edges: List[Tuple[str, str, int]] = []   # (parent_comp, child_comp, mult)
    entry: Optional[str] = None

    for raw in text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line)
        if mc:
            comp = mc.group(2)
            if mc.group(1):
                entry = comp
            continue
        md = _DEF_RE.match(line)
        if not md:
            continue
        name, type_str, opcode = md.group(1), md.group(2), md.group(3)
        shapes[name] = type_str
        comp_of[name] = comp
        instrs.append(Instruction(name, type_str, opcode, line, comp))
        if opcode == "while":
            mb = _BODY_RE.search(line)
            mt = _TRIP_RE.search(line)
            trip = int(mt.group(1)) if mt else 1
            # Backend-artifact filter: no legitimate layer/microbatch/block
            # scan exceeds a few thousand iterations; XLA-CPU emulates
            # scatters (e.g. the embedding-gradient update) as vocab-length
            # loops that are single native ops on TPU. Treat those as
            # executed once.
            if trip > 4096:
                trip = 1
            if mb:
                edges.append((comp, mb.group(1), trip))
        else:
            for target in _CALLS_RE.findall(line):
                edges.append((comp, target, 1))
            mb = _BRANCH_RE.search(line)
            if mb:
                for target in mb.group(1).split(","):
                    edges.append((comp, target.strip().lstrip("%"), 1))

    # ---- pass 2: propagate multipliers down the call graph ----------------
    mult: Dict[str, float] = {entry or "__toplevel__": 1.0}
    changed = True
    iters = 0
    while changed and iters < 100:
        changed = False
        iters += 1
        for parent, child, m in edges:
            pm = mult.get(parent)
            if pm is None:
                continue
            val = pm * m
            if mult.get(child, 0.0) < val:
                mult[child] = val
                changed = True

    # ---- pass 3: account --------------------------------------------------
    rep = HLOReport()
    skip_traffic = {"parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "after-all", "partition-id", "replica-id",
                    "iota", "while", "conditional", "call"}
    for ins in instrs:
        m = mult.get(ins.comp)
        if m is None:
            continue  # unreachable (e.g. loop condition of dead code)
        if ins.opcode == "dot":
            ops = _OPERANDS_RE.search(ins.line[ins.line.index("dot("):])
            flops = 0.0
            out = _shape_of(ins.type_str)
            if ops and out:
                names = [o.strip().lstrip("%") for o in ops.group(1).split(",")]
                lhs = _shape_of(shapes.get(names[0], "")) if names else None
                mcon = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
                if lhs and mcon:
                    csize = 1
                    for d in mcon.group(1).split(","):
                        if d.strip():
                            csize *= lhs[1][int(d)]
                    nout = 1
                    for d in out[1]:
                        nout *= d
                    flops = 2.0 * nout * csize
            rep.flops += flops * m
            rep.dot_flops_by_comp[ins.comp] = (
                rep.dot_flops_by_comp.get(ins.comp, 0.0) + flops * m)
        if ins.opcode in COLLECTIVES:
            g = _group_size(ins.line, total_devices)
            nbytes = _type_bytes(ins.type_str)
            if ins.opcode == "all-reduce":
                moved = 2.0 * (g - 1) / g * nbytes
            elif ins.opcode == "all-gather":
                moved = (g - 1) / g * nbytes
            elif ins.opcode == "reduce-scatter":
                moved = (g - 1.0) * nbytes
            elif ins.opcode == "all-to-all":
                moved = (g - 1) / g * nbytes
            else:  # collective-permute
                moved = float(nbytes)
            rep.collective_bytes += moved * m
            rep.collective_by_kind[ins.opcode] = (
                rep.collective_by_kind.get(ins.opcode, 0.0) + moved * m)
            rep.collective_count += 1
        # HBM traffic: top-level ops move result + operand bytes. Inside
        # fusions everything is register/VMEM-resident, so only count ops
        # whose computation is reachable and whose opcode does real IO.
        # Slicing ops only touch the sliced region, not the whole buffer
        # (otherwise a scan's per-layer weight slice would be charged the
        # full stacked tensor every iteration).
        if ins.opcode not in skip_traffic and not ins.comp.startswith("fused"):
            out_b = _type_bytes(ins.type_str)
            if ins.opcode in ("dynamic-slice", "slice", "broadcast",
                              "reshape", "transpose", "gather", "reduce"):
                rep.hbm_bytes += 2.0 * out_b * m         # read + write slice
            elif ins.opcode in ("dynamic-update-slice", "scatter"):
                ops = _OPERANDS_RE.search(ins.line)
                upd_b = out_b
                if ops:
                    names = [o.strip().lstrip("%")
                             for o in ops.group(1).split(",")]
                    if len(names) >= 2 and names[1] in shapes:
                        upd_b = _type_bytes(shapes[names[1]])
                rep.hbm_bytes += 2.0 * upd_b * m         # read + write update
            elif ins.opcode == "copy":
                rep.hbm_bytes += 2.0 * out_b * m
            else:
                in_b = 0.0
                ops = _OPERANDS_RE.search(ins.line)
                if ops:
                    for nm in ops.group(1).split(","):
                        nm = nm.strip().lstrip("%")
                        if nm in shapes:
                            in_b += _type_bytes(shapes[nm])
                rep.hbm_bytes += (out_b + in_b) * m
    return rep
