"""Logical-axis sharding (MaxText-style) + declarative parameter definitions.

Model code never names mesh axes directly. It tags tensors/params with
*logical* axes ("batch", "heads", "d_ff", ...) and a rule table maps those to
mesh axes per workload. With no active rules (CPU smoke tests) every
constraint is a no-op, so the same model code runs unsharded.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[Optional[str], ...]
MeshAxes = Union[None, str, Tuple[str, ...]]


# --------------------------------------------------------------------------
# rule tables
# --------------------------------------------------------------------------

# Baseline rules. "batch" spans the full data-parallel extent (pod x data when
# the pod axis exists; resolve() silently drops axes absent from the mesh).
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,             # activations: sequence unsharded by default
    "attn_seq": None,        # attention q/k/v seq dim (never SP-sharded)
    "kv_seq": None,          # KV-cache sequence dim (context parallelism opt-in)
    "d_model": None,
    "heads": "model",        # attention head dim of activations / weights
    "kv_heads": "model",     # dropped automatically when not divisible
    "head_dim": None,
    "qkv": "model",          # fused q/k/v output dim of weight matrices
    "d_ff": "model",
    "vocab": "model",
    "experts": None,         # None = TP-within-expert; "model" = EP
    "expert_cap": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "state": None,
    "conv": None,
    "layers": None,          # stacked-layer leading dim: never sharded
    "shards": ("pod", "data"),  # explicit device-local token grouping (MoE)
}


def make_rules(**overrides: MeshAxes) -> Dict[str, MeshAxes]:
    rules = dict(DEFAULT_RULES)
    rules.update(overrides)
    return rules


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Dict[str, MeshAxes]] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]] = None):
    """Activate a mesh + logical rule table for model code in this thread."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = dict(rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _axis_size(mesh: Mesh, names: Tuple[str, ...]) -> int:
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def resolve_spec(axes: Axes, shape: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None,
                 rules: Optional[Dict[str, MeshAxes]] = None) -> P:
    """Logical axes -> PartitionSpec under the active (or given) rules.

    Drops any mesh axis that (a) is absent from the mesh, (b) does not divide
    the corresponding dim (when ``shape`` is given), or (c) already appears in
    an earlier dim of this spec (a mesh axis may shard at most one dim).
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules or DEFAULT_RULES
    used: set = set()
    out = []
    for i, name in enumerate(axes):
        entry: MeshAxes = rules.get(name) if name else None
        if entry is None:
            out.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        names = tuple(n for n in names
                      if mesh is not None and n in mesh.shape and n not in used)
        if not names:
            out.append(None)
            continue
        if shape is not None and mesh is not None:
            if shape[i] % _axis_size(mesh, names) != 0:
                out.append(None)
                continue
        used.update(names)
        out.append(names[0] if len(names) == 1 else names)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x: jax.Array, axes: Axes) -> jax.Array:
    """with_sharding_constraint via logical axes; no-op without active mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = resolve_spec(axes, shape=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_map(fn, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions.

    Newer jax promotes shard_map to the top level and renames the replication
    check to ``check_vma``; older releases have it under ``jax.experimental``
    as ``check_rep``. The check is disabled either way: our collective
    schedules (psum of combined partials, all-gathered K/V) are hand-pinned
    and the checker rejects them.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


# --------------------------------------------------------------------------
# declarative parameter definitions
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Shape + logical axes + initializer for one parameter tensor."""

    shape: Tuple[int, ...]
    axes: Axes
    init: str = "normal"        # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def pdef(shape: Sequence[int], axes: Sequence[Optional[str]],
         init: str = "normal", scale: float = 0.02) -> ParamDef:
    return ParamDef(tuple(int(s) for s in shape), tuple(axes), init, scale)


def is_paramdef_leaf(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _tree_map_pdef(fn: Callable[[ParamDef], Any], tree: Any) -> Any:
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_paramdef_leaf)


def materialize(rng: jax.Array, defs: Any, dtype: Any) -> Any:
    """Initialize real arrays from a ParamDef tree (smoke tests / examples)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_paramdef_leaf)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, d in zip(keys, leaves):
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dtype)
        elif d.init == "scaled":
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            arr = (jax.random.normal(key, d.shape, jnp.float32)
                   * (1.0 / np.sqrt(fan_in))).astype(dtype)
        else:
            arr = (jax.random.normal(key, d.shape, jnp.float32) * d.scale).astype(dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(defs: Any, dtype: Any) -> Any:
    """ParamDef tree -> ShapeDtypeStruct tree (dry-run: zero allocation)."""
    return _tree_map_pdef(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(dtype)), defs)


def param_shardings(defs: Any, mesh: Mesh,
                    rules: Optional[Dict[str, MeshAxes]] = None) -> Any:
    """ParamDef tree -> NamedSharding tree under the rule table."""
    return _tree_map_pdef(
        lambda d: NamedSharding(
            mesh, resolve_spec(d.axes, shape=d.shape, mesh=mesh, rules=rules)),
        defs)


def optimizer_shardings(defs: Any, mesh: Mesh,
                        rules: Optional[Dict[str, MeshAxes]] = None) -> Any:
    """ZeRO-1: master params + moments additionally sharded over the
    data-parallel axes. For each param we take its weight PartitionSpec and
    shard the first still-unsharded dim divisible by the DP extent; bf16
    compute weights are all-gathered once per step by XLA (driven by the
    sharding constraint in the train step)."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]

    def one(d: ParamDef):
        spec = list(resolve_spec(d.axes, shape=d.shape, mesh=mesh,
                                 rules=rules))
        spec += [None] * (len(d.shape) - len(spec))
        used = set()
        for s in spec:
            used.update((s,) if isinstance(s, str) else (s or ()))
        # FSDP rules may already shard a dim over dp — nothing to add then
        if dp > 1 and not used.intersection(dp_axes):
            for i in range(len(d.shape) - 1, -1, -1):  # prefer trailing dims
                if spec[i] is None and d.shape[i] % dp == 0:
                    spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                    break
        return NamedSharding(mesh, P(*spec))

    return _tree_map_pdef(one, defs)


def param_count(defs: Any) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_paramdef_leaf)
    return int(sum(int(np.prod(d.shape)) for d in leaves))
