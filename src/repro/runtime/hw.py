"""Hardware constants for the TARGET platform (TPU v5e) + roofline helpers.

This container is CPU-only; these constants drive the analytic roofline
terms, the MIL memory model, and the simulator's JCT cost model.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s
    hbm_bw: float               # bytes/s
    hbm_bytes: float            # bytes
    ici_bw: float               # bytes/s per link
    vmem_bytes: float = 128 * 2**20
    host_bw: float = 25e9       # bytes/s host<->device (PCIe/DMA)


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    hbm_bytes=16 * 2**30,
    ici_bw=50e9,
)

# Reduced-bandwidth variant for the paper's NVLink-vs-PCIe contrast (Fig 8):
# the analogue of "no NVLink" is a DCN-attached slice (~1/8 the ICI bw).
TPU_V5E_SLOW_LINKS = dataclasses.replace(TPU_V5E, name="tpu-v5e-dcn",
                                         ici_bw=6.25e9)

DEFAULT_CHIP = TPU_V5E


def compute_seconds(flops: float, chips: int = 1,
                    chip: ChipSpec = DEFAULT_CHIP, efficiency: float = 1.0) -> float:
    return flops / (chips * chip.peak_flops_bf16 * efficiency)


def memory_seconds(bytes_moved: float, chips: int = 1,
                   chip: ChipSpec = DEFAULT_CHIP) -> float:
    return bytes_moved / (chips * chip.hbm_bw)


def collective_seconds(bytes_moved: float, chips: int = 1,
                       chip: ChipSpec = DEFAULT_CHIP) -> float:
    return bytes_moved / (chips * chip.ici_bw)


def host_transfer_seconds(bytes_moved: float,
                          chip: ChipSpec = DEFAULT_CHIP) -> float:
    """Host<->device copy time over the PCIe/DMA link (offload tier)."""
    return bytes_moved / chip.host_bw
