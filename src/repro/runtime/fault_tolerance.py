"""Fault-tolerance machinery for 1000+-node deployments.

Training side:
  * StepWatchdog — straggler/hang detection: per-step deadline derived from a
    running p95; on trip, the driver checkpoints and re-shards (drain-and-
    rejoin, synchronous-SPMD's answer to stragglers)
  * NaNGuard    — skip-and-reload policy on non-finite loss
  * Preemption  — SIGTERM -> checkpoint-then-exit hook

Serving side:
  * InstancePool — health-checked engine instances, rendezvous (HRW) user
    routing that minimally remaps users on scale-up/down (elastic), and
    automatic re-dispatch of requests from dead instances.
"""
from __future__ import annotations

import contextlib
import hashlib
import signal
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np


def _engine_lock(eng):
    """The engine's queue lock when it has one (AsyncServer-driven real
    engines), else a no-op context (simulator/test fakes)."""
    return getattr(eng, "lock", None) or contextlib.nullcontext()


class StepWatchdog:
    """Flags steps slower than ``factor`` x running p95 (straggler signal)."""

    def __init__(self, window: int = 50, factor: float = 3.0,
                 min_history: int = 10):
        self.times = deque(maxlen=window)
        self.factor = factor
        self.min_history = min_history
        self.trips = 0

    def observe(self, seconds: float) -> bool:
        tripped = False
        if len(self.times) >= self.min_history:
            deadline = float(np.percentile(self.times, 95)) * self.factor
            if seconds > deadline:
                self.trips += 1
                tripped = True
        self.times.append(seconds)
        return tripped

    def deadline(self) -> Optional[float]:
        if len(self.times) < self.min_history:
            return None
        return float(np.percentile(self.times, 95)) * self.factor


class NaNGuard:
    """Counts consecutive non-finite losses; advises reload after ``limit``."""

    def __init__(self, limit: int = 3):
        self.limit = limit
        self.consecutive = 0
        self.total_skipped = 0

    def observe(self, loss: float) -> str:
        """Returns 'ok' | 'skip' | 'reload'."""
        if np.isfinite(loss):
            self.consecutive = 0
            return "ok"
        self.consecutive += 1
        self.total_skipped += 1
        return "reload" if self.consecutive >= self.limit else "skip"


class PreemptionHandler:
    """SIGTERM/SIGINT -> set a flag the train loop checks each step."""

    def __init__(self):
        self.requested = False
        self._prev = {}

    def install(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev[sig] = signal.signal(sig, self._handle)
            except ValueError:
                pass  # not main thread (tests)
        return self

    def _handle(self, signum, frame):
        self.requested = True

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


def rendezvous_hash(user_id: str, instances: List[str]) -> str:
    """Highest-random-weight routing: adding/removing an instance remaps only
    ~1/n of users (the elastic property user-id routing needs)."""
    best, best_w = instances[0], -1.0
    for inst in instances:
        h = hashlib.blake2b(f"{user_id}|{inst}".encode(),
                            digest_size=8).digest()
        w = int.from_bytes(h, "big")
        if w > best_w:
            best, best_w = inst, w
    return best


class InstancePool:
    """Elastic pool of serving engines with health checks + re-dispatch."""

    def __init__(self, make_engine: Callable[[str], object]):
        self.make_engine = make_engine
        self.engines: Dict[str, object] = {}
        self.healthy: Dict[str, bool] = {}
        self.redispatched = 0

    def scale_to(self, names: List[str]):
        for n in names:
            if n not in self.engines:
                self.engines[n] = self.make_engine(n)
                self.healthy[n] = True
        for n in list(self.engines):
            if n not in names:
                self._drain(n)
                del self.engines[n]
                del self.healthy[n]

    def mark_failed(self, name: str) -> List:
        """Node failure: re-dispatch its queued requests to healthy peers.
        Returns the requests that could NOT be re-homed (no healthy peer) —
        the caller decides their fate (AsyncServer rejects their futures)."""
        if name in self.engines:
            self.healthy[name] = False
            return self._drain(name)
        return []

    def _drain(self, name: str) -> List:
        eng = self.engines[name]
        with _engine_lock(eng):
            pending = list(getattr(eng, "queue", []))
            eng.queue and eng.queue.clear()
        dropped = []
        for r in pending:
            target = self.route(r.user_id or str(r.req_id))
            if target is not None:
                peer = self.engines[target]
                with _engine_lock(peer):
                    peer.queue.append(r)
                self.redispatched += 1
            else:
                dropped.append(r)
        return dropped

    def live_names(self) -> List[str]:
        return [n for n, ok in self.healthy.items() if ok]

    def route(self, user_id: str) -> Optional[str]:
        live = self.live_names()
        if not live:
            return None
        return rendezvous_hash(user_id, live)

    def submit(self, user_id: str, *args, **kw):
        name = self.route(user_id)
        if name is None:
            raise RuntimeError("no healthy instances")
        return name, self.engines[name].submit(*args, user_id=user_id, **kw)

    def step_all(self) -> int:
        done = 0
        for n in self.live_names():
            if getattr(self.engines[n], "queue", None):
                self.engines[n].step()
                done += 1
        return done
