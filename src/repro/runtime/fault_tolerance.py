"""Fault-tolerance machinery for 1000+-node deployments.

Training side:
  * StepWatchdog — straggler/hang detection: per-step deadline derived from a
    running p95; on trip, the driver checkpoints and re-shards (drain-and-
    rejoin, synchronous-SPMD's answer to stragglers)
  * NaNGuard    — skip-and-reload policy on non-finite loss
  * Preemption  — SIGTERM -> checkpoint-then-exit hook

Serving side:
  * InstancePool — health-checked engine instances, rendezvous (HRW) user
    routing that minimally remaps users on scale-up/down (elastic), and
    automatic re-dispatch of requests from dead instances.
"""
from __future__ import annotations

import contextlib
import hashlib
import signal
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np


def _engine_lock(eng):
    """The engine's queue lock when it has one (AsyncServer-driven real
    engines), else a no-op context (simulator/test fakes)."""
    return getattr(eng, "lock", None) or contextlib.nullcontext()


def _block_size(eng) -> Optional[int]:
    return getattr(getattr(eng, "ecfg", None), "block_size", None)


def _rechain(req, old, peer) -> None:
    """Re-cut a re-homed request's block-hash chain at the RECEIVING
    engine's block size. Chains are granular in block size; on a
    heterogeneous pool a chain cut for ``old`` would silently miss (and,
    worse, corrupt inserts into) ``peer``'s prefix cache. No-op for test
    fakes without ``ecfg``/``tokens``."""
    bs_old, bs_new = _block_size(old), _block_size(peer)
    tokens = getattr(req, "tokens", None)
    if (bs_new is None or bs_new == bs_old or tokens is None
            or getattr(req, "chain", None) is None):
        return
    from repro.core.prefix_cache import token_chain  # lazy: avoid cycle
    req.chain = token_chain(tokens, bs_new)


class StepWatchdog:
    """Flags steps slower than ``factor`` x running p95 (straggler signal)."""

    def __init__(self, window: int = 50, factor: float = 3.0,
                 min_history: int = 10):
        self.times = deque(maxlen=window)
        self.factor = factor
        self.min_history = min_history
        self.trips = 0

    def observe(self, seconds: float) -> bool:
        tripped = False
        if len(self.times) >= self.min_history:
            deadline = float(np.percentile(self.times, 95)) * self.factor
            if seconds > deadline:
                self.trips += 1
                tripped = True
        self.times.append(seconds)
        return tripped

    def deadline(self) -> Optional[float]:
        if len(self.times) < self.min_history:
            return None
        return float(np.percentile(self.times, 95)) * self.factor


class JCTDeadlineWatchdog(StepWatchdog):
    """Serving-side hang detector over *predicted* batch JCT.

    Prefill-only serving has no token-by-token progress signal — a step
    either returns or it doesn't — but it has something better: the JCT of
    the in-flight batch is precisely predictable (paper §6.3). A batch that
    has run longer than ``factor x predicted JCT`` is therefore *provably*
    wedged (hung collective, dead accelerator, runaway recompile), not
    merely slow: hang detection becomes arithmetic, not heuristic.

    ``batch_deadline(predicted)`` is the per-batch wall-clock budget:
    ``factor x predicted``, floored by the running-p95 deadline the training
    watchdog uses (``StepWatchdog.deadline()`` — covers a cold or degenerate
    JCT fit, where ``predicted`` can be ~0) and by ``min_deadline``
    (absolute floor so jitter on near-zero predictions never trips).

    Callers also feed COMPLETED step durations through ``observe`` — slower-
    than-p95 steps that still finished are stragglers worth counting, and
    the history keeps the fallback deadline calibrated.
    """

    def __init__(self, factor: float = 4.0, min_deadline: float = 1.0,
                 window: int = 50, min_history: int = 10,
                 interval: float = 0.05):
        super().__init__(window=window, factor=factor,
                         min_history=min_history)
        self.min_deadline = min_deadline
        self.interval = interval     # scan period of the watchdog thread

    def observe(self, seconds: float) -> bool:
        """Like ``StepWatchdog.observe`` but a tripped sample is NOT folded
        into the history: a step flagged as a straggler/hang is exactly the
        outlier the p95 floor must stay calibrated against. One 6s hang in
        a 100ms-step history would otherwise drag the fallback deadline to
        ~18s and blind the scan to every subsequent hang."""
        tripped = False
        if len(self.times) >= self.min_history:
            d = float(np.percentile(self.times, 95)) * self.factor
            if seconds > d:
                self.trips += 1
                tripped = True
        if not tripped:
            self.times.append(seconds)
        return tripped

    def batch_deadline(self, predicted: float) -> float:
        deadline = self.factor * max(0.0, predicted)
        hist = self.deadline()
        if hist is not None:
            deadline = max(deadline, hist)
        return max(deadline, self.min_deadline)


class NaNGuard:
    """Counts consecutive non-finite losses; advises reload after ``limit``."""

    def __init__(self, limit: int = 3):
        self.limit = limit
        self.consecutive = 0
        self.total_skipped = 0

    def observe(self, loss: float) -> str:
        """Returns 'ok' | 'skip' | 'reload'."""
        if np.isfinite(loss):
            self.consecutive = 0
            return "ok"
        self.consecutive += 1
        self.total_skipped += 1
        return "reload" if self.consecutive >= self.limit else "skip"


class PreemptionHandler:
    """SIGTERM/SIGINT -> set a flag the train loop checks each step."""

    def __init__(self):
        self.requested = False
        self._prev = {}

    def install(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev[sig] = signal.signal(sig, self._handle)
            except ValueError:
                pass  # not main thread (tests)
        return self

    def _handle(self, signum, frame):
        self.requested = True

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


def rendezvous_hash(user_id: str, instances: List[str]) -> str:
    """Highest-random-weight routing: adding/removing an instance remaps only
    ~1/n of users (the elastic property user-id routing needs)."""
    best, best_w = instances[0], -1.0
    for inst in instances:
        h = hashlib.blake2b(f"{user_id}|{inst}".encode(),
                            digest_size=8).digest()
        w = int.from_bytes(h, "big")
        if w > best_w:
            best, best_w = inst, w
    return best


class InstancePool:
    """Elastic pool of serving engines with health checks + re-dispatch."""

    def __init__(self, make_engine: Callable[[str], object]):
        self.make_engine = make_engine
        self.engines: Dict[str, object] = {}
        self.healthy: Dict[str, bool] = {}
        self.redispatched = 0
        # observability hook: called as (req_id, src, dst) for every queued
        # request re-homed off a failed/removed instance (AsyncServer wires
        # this into the request's trace timeline)
        self.on_rehome: Optional[Callable[[int, str, str], None]] = None

    def scale_to(self, names: List[str]) -> List:
        """Grow/shrink the pool. Returns the requests that could NOT be
        re-homed from removed instances (no healthy peer) — the caller
        decides their fate (AsyncServer rejects their futures)."""
        for n in names:
            if n not in self.engines:
                self.engines[n] = self.make_engine(n)
                self.healthy[n] = True
        removed = [n for n in self.engines if n not in names]
        # mark every removed instance unhealthy BEFORE draining any of them:
        # route() must not re-home queued work onto an instance that is
        # itself about to be deleted (or back onto the one being drained)
        for n in removed:
            self.healthy[n] = False
        dropped = []
        for n in removed:
            dropped.extend(self._drain(n))
            del self.engines[n]
            del self.healthy[n]
        return dropped

    def mark_failed(self, name: str) -> List:
        """Node failure: re-dispatch its queued requests to healthy peers.
        Returns the requests that could NOT be re-homed (no healthy peer) —
        the caller decides their fate (AsyncServer rejects their futures)."""
        if name in self.engines:
            self.healthy[name] = False
            return self._drain(name)
        return []

    def _drain(self, name: str) -> List:
        eng = self.engines[name]
        # cross-process engines (serving.supervisor.RemoteEngine) expose
        # drain_queue/requeue hooks: the shadow queue must be handed over
        # atomically, and a re-home must actually cross the RPC boundary —
        # a bare peer.queue.append would only mutate the client-side mirror
        drain = getattr(eng, "drain_queue", None)
        if drain is not None:
            pending = drain()
        else:
            with _engine_lock(eng):
                pending = list(getattr(eng, "queue", []))
                eng.queue and eng.queue.clear()
        dropped = []
        for r in pending:
            target = self.route(r.user_id or str(r.req_id))
            if target is None:
                dropped.append(r)
                continue
            peer = self.engines[target]
            _rechain(r, eng, peer)
            requeue = getattr(peer, "requeue", None)
            try:
                if requeue is not None:
                    requeue([r])
                else:
                    with _engine_lock(peer):
                        peer.queue.append(r)
            except Exception:
                # the chosen peer refused (draining/dead mid-scan): the
                # caller decides the request's fate, same as no-peer
                dropped.append(r)
                continue
            self.redispatched += 1
            if self.on_rehome is not None:
                self.on_rehome(r.req_id, name, target)
        return dropped

    def live_names(self) -> List[str]:
        return [n for n, ok in self.healthy.items() if ok]

    def route(self, user_id: str) -> Optional[str]:
        live = self.live_names()
        if not live:
            return None
        return rendezvous_hash(user_id, live)

    def submit(self, user_id: str, *args, **kw):
        name = self.route(user_id)
        if name is None:
            raise RuntimeError("no healthy instances")
        return name, self.engines[name].submit(*args, user_id=user_id, **kw)

    def step_all(self) -> int:
        done = 0
        for n in self.live_names():
            if getattr(self.engines[n], "queue", None):
                self.engines[n].step()
                done += 1
        return done
