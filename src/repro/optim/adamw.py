"""AdamW with mixed-precision master weights + distributed-optimization knobs.

  * fp32 master params / moments; forward-backward runs in cfg.dtype
  * optional gradient compression for the cross-replica all-reduce:
      - "bf16": cast grads to bf16 before psum (2x ICI bytes saved)
      - "int8": error-feedback int8 quantization (8x; residual carried in
        the optimizer state so the compression is unbiased over time)
  * global-norm clipping, cosine/linear schedules, NaN-step guard hook
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | linear | constant
    grad_compression: str = "none"    # none | bf16 | int8
    moment_dtype: str = "float32"     # bfloat16 halves optimizer-state HBM


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - frac
    return cfg.lr * warm * decay


def init_state(params: Any, moment_dtype: str = "float32") -> Dict[str, Any]:
    mdt = jnp.dtype(moment_dtype)
    zeros = lambda p: jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape, mdt), p)
    return {
        "params": jax.tree_util.tree_map(
            lambda a: a.astype(jnp.float32), params),
        "m": zeros(params),
        "v": zeros(params),
        "step": jnp.zeros((), jnp.int32),
        # int8 error-feedback residual (allocated lazily when enabled)
    }


def init_error_feedback(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape, jnp.float32), params)


def compress_grads(grads: Any, mode: str,
                   residual: Optional[Any] = None) -> Tuple[Any, Optional[Any]]:
    """Lossy-compress gradients BEFORE the cross-replica reduction.

    int8 uses error feedback: e_{t+1} = g + e_t - Q(g + e_t), so quantization
    error is re-injected next step (unbiased in the long run)."""
    if mode == "none":
        return grads, residual
    if mode == "bf16":
        return jax.tree_util.tree_map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads), residual

    def q(g, e):
        x = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
        qx = jnp.clip(jnp.round(x / scale), -127, 127)
        deq = qx * scale
        return deq, x - deq

    if residual is None:
        residual = init_error_feedback(grads)
    pairs = jax.tree_util.tree_map(q, grads, residual)
    deq = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_res


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(state: Dict[str, Any], grads: Any,
                  cfg: AdamWConfig) -> Dict[str, Any]:
    """One AdamW step. ``grads`` may be lower precision; upcast here."""
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-8)) \
        if cfg.clip_norm > 0 else 1.0
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                      + cfg.weight_decay * p)
        return p, m.astype(mdt), v.astype(mdt)

    def upd_leaf(p, g, m, v):
        # big stacked tensors: update layer-slice by layer-slice so the f32
        # temporaries (upcast moments, mhat/vhat) never exist for the whole
        # tensor at once — bounds optimizer-phase HBM on 100B+ models.
        # fori_loop + in-place slice writes (lax.map would double-buffer).
        if p.ndim >= 2 and p.shape[0] > 1 and p.size > 2 ** 24:
            def body(i, carry):
                P, M, V = carry
                sl = lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False)
                pi, mi, vi = upd(sl(P), sl(g), sl(M), sl(V))
                w = lambda a, x: jax.lax.dynamic_update_index_in_dim(
                    a, x.astype(a.dtype), i, 0)
                return w(P, pi), w(M, mi), w(V, vi)

            return jax.lax.fori_loop(0, p.shape[0], body, (p, m, v))
        return upd(p, g, m, v)

    out = jax.tree_util.tree_map(upd_leaf, state["params"], grads,
                                 state["m"], state["v"])
    tup = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return {"params": tup(0), "m": tup(1), "v": tup(2), "step": step}
