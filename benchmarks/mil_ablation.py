"""Fig 10: how each PrefillOnly ingredient moves MIL (Qwen-32B-fp8-on-A100 in
the paper; llama3.1-8b-fp8-on-v5e here).

Steps: paged -> +KV discard (naive, §2.6: marginal) -> +hybrid chunking ->
+output-preallocation/in-place (§4.3).
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.core.kv_policy import MemoryModel

ARCH = "llama3.1-8b"


def run(emit):
    cfg = get_config(ARCH)
    naive = MemoryModel(cfg, weight_bytes_per_param=1.0,
                        output_prealloc=False, inplace=False)
    opt = MemoryModel(cfg, weight_bytes_per_param=1.0)
    steps = [
        ("paged_baseline", naive.max_input_length("paged")),
        ("+kv_discard", naive.max_input_length("discard")),
        ("+hybrid_chunking", naive.max_input_length("hybrid")),
        ("+prealloc_inplace", opt.max_input_length("hybrid")),
    ]
    base = max(steps[0][1], 1)
    for name, mil in steps:
        emit(f"mil_ablation/{name}", 0.0, f"MIL={mil} gain={mil/base:.2f}x")
    return steps
