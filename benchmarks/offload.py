"""Hierarchical KV memory benchmark: DRAM offload tier vs device-only.

Two engines serve the SAME warm trace with a deliberately tiny device
cache (4 blocks — two 40-token requests' kept KV), so the first round of
distinct requests forces evictions:

  tiered       TieredPrefixCache: evictions demote kept KV into the
               HostKVStore; the re-submission round restores it host->device
               instead of recomputing (offload_host_bw pinned huge — the
               break-even prices the TARGET chip's recompute rate, which
               this CPU host can't approach)
  device_only  plain PrefixCache behavior: evicted KV is gone, the
               re-submission round recomputes every prefix from scratch

Reported per mode: round-2 wall time, offload-restore hit rate (restored
blocks / total prefix blocks), and per-request score parity of the tiered
round-2 results against a pure-recompute engine (acceptance: < 2e-2).

The ``memory_model`` block is the analytic headline on the TARGET chip
(llama3.1-8b, fp8 weights): pricing the layer-wise discard's PEAK-LAYER
footprint via ``kv_keep`` shrinks the profile-run reservation, so the same
HBM yields a larger effective device prefix cache.

CLI: ``python -m benchmarks.offload [--smoke] [--out FILE]`` writes
``benchmarks/results/BENCH_offload.json``.
"""
from __future__ import annotations

import argparse
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.core.kv_policy import MemoryModel
from repro.models.model import build
from repro.runtime.sharding import materialize

from benchmarks.common import bench_record, write_bench_json

ARCH = "qwen1.5-0.5b"
VOCAB = 512          # tokens must stay inside the reduced model's vocab
YES_NO = (5, 9)
LENGTH = 40          # 2 kept blocks per request (keep_aligned(40) = 32)
CACHE_TOKENS = 64    # 4-block device cache -> round 1 must evict
REPS = 3             # pass 0 warms jit (incl. the suffix hit path)


def _engine(cfg, params, offload: bool) -> PrefillOnlyEngine:
    return PrefillOnlyEngine(cfg, params, EngineConfig(
        cache_capacity_tokens=CACHE_TOKENS, prefix_bucket_blocks=1,
        max_pack_requests=1, offload=offload,
        offload_host_bw=1e18 if offload else None))


def _serve_round(eng, lists):
    ids = []
    t0 = time.perf_counter()
    for toks in lists:
        ids.append(eng.submit(toks, allowed_tokens=YES_NO))
    eng.run_until_drained()
    return time.perf_counter() - t0, ids


def run(n_requests: int):
    cfg = reduce_config(get_config(ARCH), hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    rng = np.random.default_rng(0)
    lists = [rng.integers(0, VOCAB, LENGTH).tolist()
             for _ in range(n_requests)]
    block = 16
    prefix_blocks = n_requests * ((LENGTH // block * block) // block)

    # ground truth: pure recompute, nothing cached
    cold = PrefillOnlyEngine(cfg, params,
                             EngineConfig(cache_capacity_tokens=0))
    _, cold_ids = _serve_round(cold, lists)
    ref = [cold.results[i]["scores"] for i in cold_ids]

    rows = []
    parity = None
    for mode, offload in (("tiered", True), ("device_only", False)):
        eng = _engine(cfg, params, offload)
        best, restored, hit_rate = float("inf"), 0, 0.0
        for rep in range(REPS):
            _serve_round(eng, lists)             # round 1: populate + evict
            r0 = getattr(eng.cache, "restored_blocks", 0)
            dt, ids = _serve_round(eng, lists)   # round 2: warm re-serve
            got = getattr(eng.cache, "restored_blocks", 0) - r0
            if rep == 0:
                continue                         # jit-compile pass
            if dt < best:
                best, restored = dt, got
                hit_rate = got / max(1, prefix_blocks)
            if offload:
                parity = max(abs(ref[k][t] - eng.results[i]["scores"][t])
                             for k, i in enumerate(ids) for t in ref[k])
        row = {"mode": mode, "round2_seconds": round(best, 4),
               "restored_blocks": restored,
               "restore_hit_rate": round(hit_rate, 4)}
        if offload:
            hs = eng.cache.host.stats()
            row["host_offload_blocks"] = int(hs["offloads"])
            row["score_parity_max_abs"] = round(float(parity), 6)
        rows.append(row)

    # analytic headline on the target chip: freed HBM -> larger cache
    mm = MemoryModel(get_config("llama3.1-8b"), weight_bytes_per_param=1)
    keep = 1024
    mil_all = mm.max_input_length("hybrid", kv_keep=1 << 30)
    cache_all = mm.prefix_budget_tokens(mil_all, kv_keep=mil_all)
    cache_peak = mm.prefix_budget_tokens(mil_all, kv_keep=keep)
    memory_model = {
        "target": "llama3.1-8b fp8 on default chip",
        "kv_keep_tokens": keep,
        "mil_keep_all": mil_all,
        "mil_keep_capped": mm.max_input_length("hybrid", kv_keep=keep),
        "prefix_cache_tokens_all_layers": cache_all,
        "prefix_cache_tokens_peak_layer": cache_peak,
        "effective_cache_gain_tokens": cache_peak - cache_all,
    }
    return rows, memory_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller trace for CI")
    ap.add_argument("--out", default="benchmarks/results/BENCH_offload.json")
    args = ap.parse_args()
    n = 6 if args.smoke else 12

    rows, memory_model = run(n)
    for r in rows:
        print(r, flush=True)
    tiered = next(r for r in rows if r["mode"] == "tiered")
    assert tiered["restore_hit_rate"] > 0, "tier never restored — dead code"
    assert tiered["score_parity_max_abs"] < 2e-2, \
        f"restored-prefix scores diverge: {tiered['score_parity_max_abs']}"

    record = bench_record(
        "offload",
        config={"arch": ARCH, "smoke": args.smoke, "n_requests": n,
                "length": LENGTH, "cache_capacity_tokens": CACHE_TOKENS,
                "reps": REPS},
        rows=rows, memory_model=memory_model)
    write_bench_json(record, pathlib.Path(args.out))


if __name__ == "__main__":
    main()
