"""§6.3: the JCT linear proxy. Two sources:
  (a) the analytic roofline profile grid (TPU target) — Pearson r of
      jct vs cache-miss tokens (paper: r = 0.987 on A100/Qwen-32B)
  (b) REAL measured prefills of a reduced model on this host, fit + r.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.core.jct import LinearProxyJCT, RooflineJCT, pearson
from repro.models.model import build
from repro.runtime.sharding import materialize


def run(emit):
    # (a) analytic grid, paper's middle-end analog
    cfg = get_config("llama3.1-8b")
    model = RooflineJCT(cfg)
    samples = model.samples(max_len=60_000, granularity=2_000)
    miss = [s[0] - s[1] for s in samples]
    t = [s[2] for s in samples]
    r_grid = pearson(miss, t)
    emit("jct_fit/roofline_grid", 0.0,
         f"pearson_r={r_grid:.4f} n={len(samples)} (paper: 0.987)")

    # (b) measured on-host
    rcfg = reduce_config(get_config("qwen1.5-0.5b"), hybrid_chunk=0)
    api = build(rcfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    eng = PrefillOnlyEngine(rcfg, params, EngineConfig())
    r_measured = eng.profile((64, 128, 256, 512))
    emit("jct_fit/measured_cpu", eng.jct_model.a * 1e6,
         f"pearson_r={r_measured:.4f} a={eng.jct_model.a:.2e}s/token")
    return r_grid, r_measured
