# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator — one module per paper table/figure:

  mil_table      Table 2   MIL per technique (+ WL feasibility)
  qps_latency    Fig 6/7   QPS vs mean & P99 latency, 5 engines x 2 workloads
  throughput     Fig 9     delivered throughput vs offered QPS
  interconnect   Fig 8     ICI-bandwidth sensitivity of TP vs PrefillOnly
  mil_ablation   Fig 10    hybrid prefilling MIL ablation
  fairness       Fig 11    λ sweep (mean/p50/p99)
  jct_fit        §6.3      JCT linear-proxy Pearson r (analytic + measured)
  kernels_bench  —         host-side micro-benchmarks (scheduler, cache, oracles)
  packing        —         prepacked vs bucketed-solo prefill throughput
  roofline       §Roofline dry-run derived terms (reads results/dryrun/*.json)

Run everything:   PYTHONPATH=src python -m benchmarks.run
Run a subset:     PYTHONPATH=src python -m benchmarks.run --only mil_table,fairness
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import emit

MODULES = ["mil_table", "qps_latency", "throughput", "interconnect",
           "mil_ablation", "fairness", "jct_fit", "kernels_bench",
           "packing", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of benchmark modules")
    args = ap.parse_args()
    selected = [m for m in args.only.split(",") if m] or MODULES

    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(emit)
            emit(f"_section/{name}", (time.time() - t0) * 1e6, "ok")
        except Exception as e:  # keep going; report at the end
            traceback.print_exc()
            emit(f"_section/{name}", (time.time() - t0) * 1e6,
                 f"FAILED {e!r}")
            failures += 1
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
