"""Fig 11: request-latency distribution vs the fairness parameter λ."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.simulator import EngineSpec, Simulator
from repro.data.workloads import post_recommendation

ARCH = "llama3.1-8b"


def run(emit):
    cfg = get_config(ARCH)
    trace = post_recommendation(qps=3.0, seed=5)
    rows = []
    for lam in (0.0, 0.02, 0.05, 0.2, 1.0):
        spec = EngineSpec(f"po_lam{lam}", "srjf_calibrated", lam=lam)
        sim = Simulator(cfg, spec, total_chips=2, weight_bytes_per_param=1.0,
                        user_mil=trace.max_len)
        r = sim.run(list(trace.requests), 3.0)
        emit(f"fairness/lam{lam}", r.mean_latency * 1e6,
             f"p50={r.p50_latency:.2f}s p99={r.p99_latency:.2f}s "
             f"hit={r.hit_rate:.2f}")
        rows.append((lam, r.mean_latency, r.p99_latency))
    return rows
