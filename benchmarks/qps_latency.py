"""Fig 6 + Fig 7: QPS vs mean / P99 latency — simulator AND real async serving.

``run(emit)`` (benchmarks.run entry) keeps the paper methodology (§7.2) on
the discrete-event simulator: find PrefillOnly's saturation throughput x by
pouring in all requests at once, then evaluate QPS in {x/4 .. 4x} for the 5
engine baselines.

``run_async(emit)`` (also ``python -m benchmarks.qps_latency --mode async``)
drives REAL reduced-config engines through the serving subsystem
(``repro.serving.AsyncServer``) on the post_recommendation trace:

  1. router comparison at saturation load: user-hash rendezvous routing vs
     JCT-aware least-backlog routing (2 instances, no admission control);
  2. overload behavior at 2x saturation: per-request deadlines + admission/
     shed vs no admission — the shed path keeps SERVED p99 bounded near the
     deadline while the no-admission baseline's p99 grows with the backlog
     (the longer the trace, the worse — there is no steady state past
     saturation).

One engine pool is built once and reused across runs (jit compiles and the
profile-fitted JCT model stay warm — they are host properties, not policy
properties); prefix caches and telemetry reset between runs so every policy
starts cold on cache state. Output is written to
``benchmarks/results/qps_latency_async.txt``.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

from repro.configs import get_config
from repro.core.simulator import Simulator, paper_engines
from repro.data.workloads import get_trace

ARCH = "llama3.1-8b"
CHIPS = 2

# ---- real async-serving comparison ----------------------------------------
ASYNC_ARCH = "qwen1.5-0.5b"
ASYNC_TRACE = "post_recommendation"
ASYNC_INSTANCES = 2
ASYNC_REQUESTS = 120
ASYNC_SCALE = 0.02
ASYNC_CACHE_TOKENS = 16384


def saturation_qps(trace_name: str) -> float:
    cfg = get_config(ARCH)
    spec = [s for s in paper_engines() if s.name == "prefillonly"][0]
    trace = get_trace(trace_name, qps=10_000.0, seed=0)   # all-at-once
    sim = Simulator(cfg, spec, total_chips=CHIPS, weight_bytes_per_param=1.0,
                    user_mil=trace.max_len)
    res = sim.run(list(trace.requests), 10_000.0)
    return res.throughput


def run(emit):
    cfg = get_config(ARCH)
    out = []
    for trace_name in ("post_recommendation", "credit_verification"):
        x = saturation_qps(trace_name)
        emit(f"qps_latency/{trace_name}/saturation", 0.0, f"x={x:.3f}rps")
        for mult in (0.25, 0.5, 1.0, 2.0, 3.0, 4.0):
            qps = x * mult
            trace = get_trace(trace_name, qps=qps, seed=1)
            for spec in paper_engines():
                sim = Simulator(cfg, spec, total_chips=CHIPS,
                                weight_bytes_per_param=1.0,
                                user_mil=trace.max_len)
                r = sim.run(list(trace.requests), qps)
                emit(f"qps_latency/{trace_name}/{spec.name}/q{mult}x",
                     r.mean_latency * 1e6,
                     f"p99={r.p99_latency:.2f}s thr={r.throughput:.3f}rps "
                     f"hit={r.hit_rate:.2f} rej={r.rejected}")
                out.append((trace_name, mult, spec.name, r))
    # headline check: PrefillOnly sustains the highest load
    return out


def _reset_pool(pool) -> None:
    """Cold caches/telemetry, warm compiles + JCT fit."""
    from repro.core.prefix_cache import PrefixCache
    for eng in pool.engines.values():
        with eng.lock:
            eng.queue.clear()
            eng.results.clear()
            eng.cache = PrefixCache(
                eng.ecfg.cache_capacity_tokens // eng.ecfg.block_size,
                eng.ecfg.block_size)
            eng.steps = eng.hit_tokens = eng.total_tokens = 0
            eng.packed_steps = eng.packed_requests = eng.padded_slots = 0


def _async_round(pool, qps: float, *, router: str, deadline: Optional[float],
                 admission: bool, max_requests: int = ASYNC_REQUESTS,
                 trace: str = ASYNC_TRACE, trace_kw: Optional[Dict] = None,
                 scale: float = ASYNC_SCALE) -> Dict:
    from repro.launch.serve import serve_trace
    _reset_pool(pool)
    return serve_trace(ASYNC_ARCH, trace, qps=qps,
                       scale_tokens=scale, max_requests=max_requests,
                       router=router, deadline=deadline, admission=admission,
                       pool=pool, trace_kw=trace_kw)


def run_async(emit):
    from repro.launch.serve import make_pool
    lines = []

    def note(name, us, derived=""):
        lines.append(emit(name, us, derived))

    pool = make_pool(ASYNC_ARCH, ASYNC_INSTANCES, profile=True,
                     profile_lengths=(64, 128, 256, 512),
                     cache_tokens=ASYNC_CACHE_TOKENS)
    any_eng = next(iter(pool.engines.values()))
    note("qps_latency_async/jct_fit", any_eng.jct_model.b * 1e6,
         f"a={any_eng.jct_model.a:.2e}s/tok "
         f"pack_budget={any_eng.ecfg.pack_token_budget} "
         f"max_pack={any_eng.ecfg.max_pack_requests}")

    # warm every hot jit shape + first saturation estimate: all-at-once.
    # That estimate is cold-cache pessimistic, so refine it with spread-
    # arrival probes: raise the offered rate until served throughput stops
    # following it (a queue formed — the plateau IS the capacity).
    t0 = time.time()
    warm = _async_round(pool, 10_000.0, router="least_backlog",
                        deadline=None, admission=False)
    sat = warm["served"] / warm["wall_seconds"]
    note("qps_latency_async/warmup", warm["wall_seconds"] * 1e6,
         f"all_at_once={sat:.3f}rps warm={time.time() - t0:.0f}s "
         f"hit={warm['token_hit_rate']:.2f}")
    for _ in range(3):
        offered = 1.8 * sat
        r = _async_round(pool, offered, router="least_backlog",
                         deadline=None, admission=False)
        note("qps_latency_async/probe", r["mean_latency"] * 1e6,
             f"offered={offered:.3f}rps thr={r['throughput_rps']:.3f}rps "
             f"p99={r['p99_latency']:.2f}s")
        sat = max(sat, r["throughput_rps"])
        if r["throughput_rps"] < 0.85 * offered:
            break
    note("qps_latency_async/saturation", 0.0, f"x={sat:.3f}rps")
    # prewarm the user_hash routing pattern too: each (suffix, prefix-len)
    # pair a placement produces compiles its own jit program, and a compile
    # landing inside a measured round would read as a fake latency tail
    _async_round(pool, 10_000.0, router="user_hash", deadline=None,
                 admission=False)

    # (1) router comparison at saturation load (2 instances, no admission);
    # median of 3 rounds per router — single rounds on this shared CPU box
    # swing with machine noise
    routers = {}
    for router in ("user_hash", "least_backlog"):
        rounds = [_async_round(pool, sat, router=router, deadline=None,
                               admission=False) for _ in range(3)]
        r = sorted(rounds, key=lambda x: x["p99_latency"])[1]
        r["throughput_rps"] = sorted(x["throughput_rps"]
                                     for x in rounds)[1]
        routers[router] = r
        note(f"qps_latency_async/router/{router}/q1.0x",
             r["mean_latency"] * 1e6,
             f"thr={r['throughput_rps']:.3f}rps "
             f"p50={r['p50_latency']:.2f}s p99={r['p99_latency']:.2f}s "
             f"hit={r['token_hit_rate']:.2f} (median of 3)")
    uh, lb = routers["user_hash"], routers["least_backlog"]
    note("qps_latency_async/router/verdict", 0.0,
         f"least_backlog thr {lb['throughput_rps'] / uh['throughput_rps']:.2f}x "
         f"p99 {lb['p99_latency'] / uh['p99_latency']:.2f}x of user_hash")

    # (2) overload: 2x saturation, deadline-shed vs no admission, at TWO
    # trace lengths. The workload is credit_verification — no prefix
    # sharing, so instance capacity is flat and "2x saturation" stays 2x
    # for the whole run (post_recommendation's capacity climbs as profile
    # caches warm, which dissolves the overload). Past saturation there is
    # no steady state: the no-admission p99 scales with how long the
    # overload lasts, while the shed path's served p99 stays pinned near
    # the deadline at any length.
    # scale 0.01 keeps credit requests (400-600 tokens) out of the
    # quadratic-attention regime that dominates 2048-token buckets on CPU
    over_kw = dict(trace="credit_verification", scale=0.01)
    # warm the credit-trace jit shapes, then measure its flat capacity
    _async_round(pool, 10_000.0, router="least_backlog", deadline=None,
                 admission=False, trace_kw={"num_users": 40}, **over_kw)
    cap_r = _async_round(pool, 10_000.0, router="least_backlog",
                         deadline=None, admission=False,
                         trace_kw={"num_users": 40}, **over_kw)
    cap = cap_r["throughput_rps"]
    # a few mean service times (2 instances => mean service = 2/cap):
    # binds under 2x overload, loose for on-time requests
    deadline = max(8.0 / cap, 1.0)
    note("qps_latency_async/overload2x/capacity", 0.0,
         f"credit_verification cap={cap:.3f}rps deadline={deadline:.2f}s")
    over = {}
    for n_req in (60, 180):
        for mode, dl, adm in (("shed", deadline, True),
                              ("no_admission", None, False)):
            r = _async_round(pool, 2.0 * cap, router="least_backlog",
                             deadline=dl, admission=adm,
                             max_requests=n_req,
                             trace_kw={"num_users": n_req}, **over_kw)
            over[(mode, n_req)] = r
            note(f"qps_latency_async/overload2x/{mode}/n{n_req}",
                 r["mean_latency"] * 1e6,
                 f"served={r['served']}/{r['requests']} "
                 f"thr={r['throughput_rps']:.3f}rps "
                 f"p50={r['p50_latency']:.2f}s p99={r['p99_latency']:.2f}s "
                 f"rej={r['reject_reasons']}")
    n1, n2 = 60, 180
    note("qps_latency_async/overload2x/verdict", 0.0,
         f"deadline={deadline:.2f}s "
         f"shed_p99={over[('shed', n1)]['p99_latency']:.2f}s"
         f"->{over[('shed', n2)]['p99_latency']:.2f}s (bounded) "
         f"no_admission_p99={over[('no_admission', n1)]['p99_latency']:.2f}s"
         f"->{over[('no_admission', n2)]['p99_latency']:.2f}s "
         f"(grows with trace length)")
    return lines, over, routers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="async", choices=["async", "sim"])
    ap.add_argument("--out", default="benchmarks/results/qps_latency_async.txt")
    args = ap.parse_args()
    from benchmarks.common import emit
    if args.mode == "sim":
        run(emit)
        return
    lines, over, routers = run_async(emit)
    if args.out:
        import os
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            f.write("name,us_per_call,derived\n")
            f.write("\n".join(lines) + "\n")
        print(f"wrote {args.out}")

    from benchmarks.common import bench_record, write_bench_json

    def _row(case, r, **extra):
        return {"case": case,
                "served": r["served"], "requests": r["requests"],
                "throughput_rps": round(r["throughput_rps"], 3),
                "mean_latency_s": round(r["mean_latency"], 4),
                "p50_latency_s": round(r["p50_latency"], 4),
                "p99_latency_s": round(r["p99_latency"], 4),
                "token_hit_rate": round(r["token_hit_rate"], 3),
                "reject_reasons": r.get("reject_reasons", {}), **extra}

    rows = [_row(f"router/{name}/q1.0x", r)
            for name, r in routers.items()]
    rows += [_row(f"overload2x/{mode}/n{n}", r, n_requests=n)
             for (mode, n), r in over.items()]
    record = bench_record(
        "qps_latency_async",
        config={"arch": ASYNC_ARCH, "instances": ASYNC_INSTANCES,
                "router_trace": ASYNC_TRACE,
                "overload_trace": "credit_verification"},
        rows=rows, log=lines)
    write_bench_json(record, "benchmarks/results/BENCH_qps_latency.json")


if __name__ == "__main__":
    main()
