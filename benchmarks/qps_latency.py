"""Fig 6 + Fig 7: QPS vs mean / P99 latency, 5 engines x 2 workloads.

Paper methodology (§7.2): find PrefillOnly's saturation throughput x by
pouring in all requests at once, then evaluate QPS in {x/4, x/2, x, 2x, 3x,
4x}. TPU v5e instances, fp8 weights (the paper's quantized middle-end setup).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.simulator import Simulator, paper_engines
from repro.data.workloads import get_trace

ARCH = "llama3.1-8b"
CHIPS = 2


def saturation_qps(trace_name: str) -> float:
    cfg = get_config(ARCH)
    spec = [s for s in paper_engines() if s.name == "prefillonly"][0]
    trace = get_trace(trace_name, qps=10_000.0, seed=0)   # all-at-once
    sim = Simulator(cfg, spec, total_chips=CHIPS, weight_bytes_per_param=1.0,
                    user_mil=trace.max_len)
    res = sim.run(list(trace.requests), 10_000.0)
    return res.throughput


def run(emit):
    cfg = get_config(ARCH)
    out = []
    for trace_name in ("post_recommendation", "credit_verification"):
        x = saturation_qps(trace_name)
        emit(f"qps_latency/{trace_name}/saturation", 0.0, f"x={x:.3f}rps")
        for mult in (0.25, 0.5, 1.0, 2.0, 3.0, 4.0):
            qps = x * mult
            trace = get_trace(trace_name, qps=qps, seed=1)
            for spec in paper_engines():
                sim = Simulator(cfg, spec, total_chips=CHIPS,
                                weight_bytes_per_param=1.0,
                                user_mil=trace.max_len)
                r = sim.run(list(trace.requests), qps)
                emit(f"qps_latency/{trace_name}/{spec.name}/q{mult}x",
                     r.mean_latency * 1e6,
                     f"p99={r.p99_latency:.2f}s thr={r.throughput:.3f}rps "
                     f"hit={r.hit_rate:.2f} rej={r.rejected}")
                out.append((trace_name, mult, spec.name, r))
    # headline check: PrefillOnly sustains the highest load
    return out
