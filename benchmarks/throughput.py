"""Fig 9: delivered throughput vs offered QPS (prefix-cache throttling).

Reproduces the effect that FIFO engines throttle when the prefix cache
churns under load, while continuous JCT calibration keeps harvesting hits.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.simulator import Simulator, paper_engines
from repro.data.workloads import post_recommendation

ARCH = "llama3.1-8b"


def run(emit):
    cfg = get_config(ARCH)
    rows = []
    for qps in (0.5, 1.0, 2.0, 3.0, 4.0, 6.0):
        trace = post_recommendation(qps=qps, seed=2)
        for spec in paper_engines():
            sim = Simulator(cfg, spec, total_chips=2,
                            weight_bytes_per_param=1.0,
                            user_mil=trace.max_len)
            r = sim.run(list(trace.requests), qps)
            emit(f"throughput/{spec.name}/offered{qps}", 0.0,
                 f"delivered={r.throughput:.3f}rps hit={r.hit_rate:.2f}")
            rows.append((qps, spec.name, r.throughput, r.hit_rate))
    return rows
