"""Fault-injection benchmark: retry + watchdog + brownout vs a no-retry
baseline, on REAL reduced-config engines (ISSUE 6 acceptance artifact).

Four scenarios replay the same trace through 3-instance pools:

  clean          no faults injected — the healthy reference for served%/p99
  no_retry       a deterministic schedule of all five fault kinds (step
                 crash, hang, straggler, NaN corruption, transient submit
                 failure) with ``retry_budget=0``: lost in-flight work
                 resolves ``Rejected("error")``; the JCT watchdog still
                 trips hangs so nothing blocks forever, but nothing is
                 re-served either
  retry          the same fault schedule with idempotent retry (budget 3),
                 the watchdog, and the brownout ladder armed — lost work is
                 transparently re-served on healthy peers
  process_chaos  PROCESS mode (``workers=3`` supervised engine worker
                 processes behind the RPC boundary) under a deterministic
                 SIGKILL-mid-batch + long SIGSTOP freeze schedule against
                 the real worker pids — recovery is shadow-queue re-home +
                 idempotent retry + heartbeat-lease death detection +
                 supervised restart

The committed output (``benchmarks/results/BENCH_serving_faults.json``)
records per-scenario served/rejected counts, retries, watchdog trips, the
injected-fault audit, and the served-latency tail, plus a comparison block:
under faults, retry should recover (close to) the clean scenario's served
fraction while keeping SERVED p99 bounded — the no-retry baseline simply
fails every faulted request.

Schedules are deterministic (exact per-instance operation indices, one
seed), so two runs on one host inject identically. ``--smoke`` shrinks the
trace for CI.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.launch.serve import serve_trace
from repro.serving import ChaosConfig

ARCH = "qwen1.5-0.5b"
TRACE = "post_recommendation"
INSTANCES = 3

# all five fault kinds, pinned to early per-instance operation indices so
# they fire even on the smoke-sized trace (each instance sees a handful of
# eligible steps); the hang lands late enough on inst1 to hit a warm engine
FAULT_SCHEDULE = (
    ("inst0", 0, "submit_error"),
    ("inst0", 1, "step_error"),
    ("inst1", 1, "nan_score"),
    ("inst2", 1, "straggler"),
    ("inst1", 3, "hang"),
)

# process-mode faults against REAL worker processes: a SIGKILL mid-batch
# (kernel-guaranteed, no Python cleanup) and a SIGSTOP freeze long enough
# that the supervisor must declare the lease dead (~6s at the serve-CLI
# supervision constants) and kill/restart the worker — not a transient
# stall that merely slows one RPC
PROCESS_FAULT_SCHEDULE = (
    ("inst0", 1, "kill"),
    ("inst1", 2, "freeze"),
)


def _chaos() -> ChaosConfig:
    return ChaosConfig(seed=0, schedule=FAULT_SCHEDULE,
                       hang_seconds=6.0, straggler_seconds=0.25)


def _process_chaos() -> ChaosConfig:
    return ChaosConfig(seed=0, schedule=PROCESS_FAULT_SCHEDULE,
                       freeze_seconds=10.0)


def _scenario(name: str, *, chaos, retry_budget, brownout, n_requests, qps,
              workers: int = 0):
    t0 = time.perf_counter()
    out = serve_trace(
        ARCH, TRACE, qps=qps, n_instances=INSTANCES, workers=workers,
        max_requests=n_requests, scale_tokens=0.02, deadline=None,
        profile=True,                       # warm compiles + fitted JCT
        retry_budget=retry_budget, watchdog=True, watchdog_factor=3.0,
        watchdog_min_deadline=1.0, brownout=brownout, chaos=chaos,
        drain_timeout=120.0)
    return {
        "scenario": name,
        "mode": "process" if workers else "thread",
        "requests": out["requests"],
        "served": out["served"],
        "rejected": out["rejected"],
        "reject_reasons": out["reject_reasons"],
        "retried": out["retried"],
        "watchdog_trips": out["watchdog_trips"],
        "faults_injected": out.get("faults_injected", {}),
        "p50_latency": out["p50_latency"],
        "p99_latency": out["p99_latency"],
        "mean_latency": out["mean_latency"],
        "throughput_rps": out["throughput_rps"],
        "wall_seconds": out["wall_seconds"],
        "bench_seconds": time.perf_counter() - t0,
    }


def run(n_requests: int, qps: float) -> dict:
    # jit compile caches are process-wide: whichever scenario runs first
    # would otherwise pay every packed/suffix-shape compile in its tail
    # latencies. A discarded full-trace warm-up pass levels the field.
    _scenario("warmup", chaos=None, retry_budget=0, brownout=False,
              n_requests=n_requests, qps=qps)
    rows = [
        _scenario("clean", chaos=None, retry_budget=3, brownout=False,
                  n_requests=n_requests, qps=qps),
        _scenario("no_retry", chaos=_chaos(), retry_budget=0, brownout=False,
                  n_requests=n_requests, qps=qps),
        _scenario("retry", chaos=_chaos(), retry_budget=3, brownout=True,
                  n_requests=n_requests, qps=qps),
        # same recovery stack, but the engines are supervised worker
        # PROCESSES and the faults are SIGKILL/SIGSTOP against real pids
        _scenario("process_chaos", chaos=_process_chaos(), retry_budget=3,
                  brownout=True, n_requests=n_requests, qps=qps,
                  workers=INSTANCES),
    ]
    by = {r["scenario"]: r for r in rows}
    return {
        "bench": "serving_faults",
        "arch": ARCH,
        "trace": TRACE,
        "instances": INSTANCES,
        "requests_per_scenario": n_requests,
        "qps": qps,
        "fault_schedule": [list(f) for f in FAULT_SCHEDULE],
        "process_fault_schedule": [list(f) for f in PROCESS_FAULT_SCHEDULE],
        "scenarios": rows,
        "comparison": {
            "served_frac_clean": by["clean"]["served"]
            / max(1, by["clean"]["requests"]),
            "served_frac_no_retry": by["no_retry"]["served"]
            / max(1, by["no_retry"]["requests"]),
            "served_frac_retry": by["retry"]["served"]
            / max(1, by["retry"]["requests"]),
            "p99_no_retry_over_clean": by["no_retry"]["p99_latency"]
            / max(1e-9, by["clean"]["p99_latency"]),
            "p99_retry_over_clean": by["retry"]["p99_latency"]
            / max(1e-9, by["clean"]["p99_latency"]),
            "served_frac_process_chaos": by["process_chaos"]["served"]
            / max(1, by["process_chaos"]["requests"]),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests)")
    ap.add_argument("--requests", type=int, default=None)
    # below the 3-instance pool's ~3.3 rps capacity on this trace: p99 then
    # measures service + fault recovery, not queue buildup under overload
    # (in saturation, scenarios that REJECT work look faster, inverting the
    # comparison)
    ap.add_argument("--qps", type=float, default=2.5)
    ap.add_argument("--out", default=None,
                    help="output path (default: benchmarks/results/"
                         "BENCH_serving_faults.json)")
    args = ap.parse_args()
    n = args.requests or (18 if args.smoke else 60)
    result = run(n, args.qps)
    result["smoke"] = bool(args.smoke)
    out_path = pathlib.Path(args.out) if args.out else (
        pathlib.Path(__file__).parent / "results"
        / "BENCH_serving_faults.json")
    from benchmarks.common import bench_record, write_bench_json
    result.pop("bench", None)
    record = bench_record(
        "serving_faults",
        config={k: result.pop(k) for k in
                ("arch", "trace", "instances", "requests_per_scenario",
                 "qps", "fault_schedule", "process_fault_schedule")
                if k in result},
        rows=result.pop("scenarios", []),
        **result)
    write_bench_json(record, out_path)
    print(json.dumps(result["comparison"], indent=2))


if __name__ == "__main__":
    main()
