"""Table 2 analog: maximum input length (MIL) per technique on TPU v5e-16GB.

The paper's table covers L4/A100/H100 x {PagedAttention, chunked prefill,
PP-2, TP-2, PrefillOnly}; our hardware rows are v5e with bf16 and fp8
weights. WL1 = post recommendation (max ~19k tokens), WL2 = credit
verification (max 60k tokens); ✗ = workload infeasible for that engine.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.kv_policy import MemoryModel

WL1_MAX = 19_000
WL2_MAX = 60_000

TECHS = ("paged", "chunked", "pp", "tp", "hybrid")
LABEL = {"paged": "PagedAttention", "chunked": "Chunked Prefill",
         "pp": "Pipeline Parallel-2", "tp": "Tensor Parallel-2",
         "hybrid": "PrefillOnly (ours)", "discard": "naive KV discard"}


def run(emit):
    rows = []
    for arch, wbytes in (("llama3.1-8b", 1.0), ("llama3.1-8b", 2.0),
                         ("qwen1.5-0.5b", 2.0), ("granite-3-8b", 1.0)):
        cfg = get_config(arch)
        mm = MemoryModel(cfg, weight_bytes_per_param=wbytes)
        mil = mm.mil_table()
        for t in TECHS:
            wl1 = "Y" if mil[t] >= WL1_MAX else "x"
            wl2 = "Y" if mil[t] >= WL2_MAX else "x"
            name = f"mil/{arch}-{'fp8' if wbytes == 1 else 'bf16'}/{t}"
            emit(name, 0.0, f"MIL={mil[t]} WL1={wl1} WL2={wl2}")
            rows.append((arch, wbytes, t, mil[t], wl1, wl2))
        ours, paged = mil["hybrid"], max(mil["paged"], 1)
        emit(f"mil/{arch}-{'fp8' if wbytes == 1 else 'bf16'}/gain",
             0.0, f"hybrid_vs_paged={ours / paged:.1f}x")
    return rows
