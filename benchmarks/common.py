"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, List


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3,
              **kw) -> float:
    """Median wall-time of fn in microseconds."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line
