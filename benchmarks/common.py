"""Shared benchmark helpers."""
from __future__ import annotations

import json
import pathlib
import platform
import subprocess
import time
from typing import Callable, Dict, List, Optional


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3,
              **kw) -> float:
    """Median wall-time of fn in microseconds."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def bench_record(bench: str, *, config: Optional[Dict] = None,
                 rows: Optional[List[Dict]] = None, **extra) -> Dict:
    """Uniform machine-readable benchmark record (the per-PR longitudinal
    trajectory the ROADMAP asks for — BENCH_*.json all share this shape).

    ``config`` is the knobs the run was taken under, ``rows`` the measured
    results; ``extra`` top-level keys hold comparisons/derived numbers.
    ``host``/``commit`` stamp where the numbers came from, so a regression
    hunt can tell a code change from a host change.
    """
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=5).stdout.strip() or None
    except Exception:
        commit = None
    return {
        "bench": bench,
        "created_unix": round(time.time(), 1),
        "host": {"machine": platform.machine(),
                 "python": platform.python_version()},
        "commit": commit,
        "config": config or {},
        "rows": rows or [],
        **extra,
    }


def write_bench_json(record: Dict, path) -> pathlib.Path:
    """Write one benchmark record as stable, diff-friendly JSON."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(record, indent=2, sort_keys=False) + "\n")
    print(f"wrote {p}", flush=True)
    return p
