"""Prepacked vs bucketed-solo prefill throughput (real forwards, CPU host).

Bucketing rounds every suffix up to the next shape in ``suffix_buckets``; on
short-request workloads a large share of those slots is padding. Prepacking
(segment-restricted attention, engine batch formation) turns that slack into
served tokens. Two workload shapes from data/workloads.py, CPU-scaled:

  short_noshare   credit_verification  — short requests, no prefix sharing:
                  the pure packing win (acceptance: >= 1.5x tokens/sec)
  short_shared    post_recommendation  — short requests sharing per-user
                  profile prefixes: prefix sharers are never co-packed, so
                  the cache-hit path must be no worse than solo

Each engine serves the trace REPS times (pass 0 warms the per-engine jit
caches; the prefix cache and counters are reset between passes) and the best
warm pass is timed. Emits tokens/sec, padding-waste ratio, and the speedup.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.core.prefix_cache import PrefixCache
from repro.data.workloads import credit_verification, post_recommendation
from repro.models.model import build
from repro.runtime.sharding import materialize

ARCH = "qwen1.5-0.5b"
REPS = 4


def _serve(cfg, params, trace, ecfg):
    """Serve ``trace`` REPS times on one engine; return (best pass seconds,
    stats of the last pass). Pass 0 warms the jit caches; the best of the
    remaining passes is reported (host-noise floor)."""
    eng = PrefillOnlyEngine(cfg, params, ecfg)
    times = []
    for _ in range(REPS):
        eng.cache = PrefixCache(ecfg.cache_capacity_tokens // ecfg.block_size,
                                ecfg.block_size)
        eng.hit_tokens = eng.total_tokens = eng.padded_slots = 0
        eng.packed_steps = eng.packed_requests = eng.steps = 0
        for r in trace.requests:
            eng.submit(list(r.tokens), now=0.0)
        t0 = time.perf_counter()
        eng.run_until_drained()
        times.append(time.perf_counter() - t0)
    return min(times[1:]), eng.stats()


def run(emit):
    cfg = reduce_config(get_config(ARCH), hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)

    # ~32-47 token requests against a 64-token bucket: the paper's short
    # discriminative regime, where ~40% of every solo forward is padding
    noshare = credit_verification(qps=0.0, num_users=48, scale_tokens=0.0008,
                                  materialize_tokens=True, seed=0)
    shared = post_recommendation(qps=0.0, num_users=6, posts_per_user=4,
                                 scale_tokens=0.01, materialize_tokens=True,
                                 seed=0)
    cases = [
        # (trace name, trace, solo config, packed config)
        ("short_noshare", noshare,
         EngineConfig(max_pack_requests=1, cache_capacity_tokens=0,
                      kv_keep_tokens=0),
         EngineConfig(cache_capacity_tokens=0, kv_keep_tokens=0,
                      pack_token_budget=1024, max_pack_requests=24)),
        ("short_shared", shared,
         EngineConfig(max_pack_requests=1),
         EngineConfig(pack_token_budget=1024, max_pack_requests=16)),
    ]
    rows = []
    for name, trace, solo_cfg, pack_cfg in cases:
        tot = trace.total_tokens
        t_solo, s_solo = _serve(cfg, params, trace, solo_cfg)
        t_pack, s_pack = _serve(cfg, params, trace, pack_cfg)
        tps_solo = tot / t_solo
        tps_pack = tot / t_pack
        emit(f"packing/{name}/solo_bucketed", t_solo * 1e6,
             f"{tps_solo:.0f}tok/s waste={s_solo['padding_waste']:.3f} "
             f"hit={s_solo['hit_rate']:.2f}")
        emit(f"packing/{name}/prepacked", t_pack * 1e6,
             f"{tps_pack:.0f}tok/s waste={s_pack['padding_waste']:.3f} "
             f"hit={s_pack['hit_rate']:.2f} "
             f"packed_reqs={s_pack['packed_requests']}/{len(trace.requests)}")
        emit(f"packing/{name}/speedup", 0.0,
             f"{tps_pack / tps_solo:.2f}x tokens/sec")
        rows.append((name, tps_solo, tps_pack, s_solo["padding_waste"],
                     s_pack["padding_waste"]))
    return rows
