"""Prepacked vs bucketed-solo prefill throughput (real forwards, CPU host).

Bucketing rounds every suffix up to the next shape in ``suffix_buckets``; on
short-request workloads a large share of those slots is padding. Prepacking
(segment-restricted attention, engine batch formation) turns that slack into
served tokens. Three workload shapes from data/workloads.py, CPU-scaled:

  short_noshare   credit_verification  — short requests, no prefix sharing:
                  the pure packing win (acceptance: >= 1.5x tokens/sec)
  short_shared    post_recommendation  — short requests sharing per-user
                  profile prefixes, COLD cache each pass: misses pack,
                  sharers run sequentially so later ones hit
  prefix_hit      post_recommendation, cache retained across passes — every
                  request is a cache HIT on a long per-user profile prefix.
                  The packed prefix-hit path co-packs the suffixes over a
                  gathered prefix-KV buffer; baseline is the solo suffix
                  fallback (acceptance: >= 1.3x tokens/sec, per-request
                  scores match the solo path within tolerance)

Each engine serves the trace REPS times (pass 0 warms the per-engine jit
caches — and, for prefix_hit, the prefix cache) and the best warm pass is
timed. Emits tokens/sec, padding-waste ratio, and the speedup.

CLI: ``python -m benchmarks.packing [--smoke] [--out FILE]`` runs the
prefix_hit case standalone (``--smoke``: smaller trace for CI) and
writes the emitted rows to FILE (default benchmarks/results/packing_*.txt)
so the perf trajectory is tracked per PR.
"""
from __future__ import annotations

import argparse
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.engine import EngineConfig, PrefillOnlyEngine
from repro.core.prefix_cache import PrefixCache
from repro.data.workloads import credit_verification, post_recommendation
from repro.models.model import build
from repro.runtime.sharding import materialize

ARCH = "qwen1.5-0.5b"
REPS = 4
YES_NO = (5, 9)
# traces must be generated inside the reduced model's vocab: out-of-range
# token ids turn the embedding take into NaN fill (jnp.take mode="fill")
VOCAB = 512


def _serve(cfg, params, trace, ecfg, reps=REPS, reset_cache=True,
           allowed=None, tracer=None, metrics=None):
    """Serve ``trace`` ``reps`` times on one engine; return (best pass
    seconds, stats of the last pass, last pass's per-request score dicts).
    Pass 0 warms the jit caches (and, with ``reset_cache=False``, the
    prefix cache — making every later pass a cache hit); the best of the
    remaining passes is reported (host-noise floor). Early passes also
    CALIBRATE the JCT fit — the engine's packing cost model needs a real
    per-step overhead estimate (b) before it accepts the larger packs that
    win; the pass count must leave several converged passes for the min.

    ``tracer``/``metrics`` bind the observability plane to the engine and
    open/close a full trace per request — the traced configuration of the
    tracing-overhead case."""
    eng = PrefillOnlyEngine(cfg, params, ecfg)
    if tracer is not None or metrics is not None:
        eng.bind_telemetry(metrics=metrics, instance="bench", tracer=tracer)
    times = []
    ids = []
    for _ in range(reps):
        if reset_cache:
            eng.cache = PrefixCache(
                ecfg.cache_capacity_tokens // ecfg.block_size,
                ecfg.block_size)
        eng.hit_tokens = eng.total_tokens = eng.padded_slots = 0
        eng.packed_steps = eng.packed_requests = eng.steps = 0
        eng.packed_hit_requests = eng.pack_skew_splits = 0
        eng.results.clear()
        ids = []
        for r in trace.requests:
            rid = eng.submit(list(r.tokens), allowed_tokens=allowed, now=0.0)
            ids.append(rid)
            if tracer is not None:
                tracer.begin(rid=rid, n_input=len(r.tokens))
        t0 = time.perf_counter()
        eng.run_until_drained()
        times.append(time.perf_counter() - t0)
        if tracer is not None:
            for rid in ids:
                tracer.finish_rid(rid, "delivered")
    scores = ([eng.results[i].get("scores") for i in ids]
              if allowed else None)
    return min(times[1:]), eng.stats(), scores


def _prefix_hit_case(smoke=False):
    """Prefix-heavy trace (every timed pass is >= 100% cache-hit requests):
    per-user profile prefixes ~256 tokens, ~27-token computed suffixes."""
    users, posts = (6, 4) if smoke else (8, 6)
    trace = post_recommendation(qps=0.0, num_users=users,
                                posts_per_user=posts, scale_tokens=0.02,
                                materialize_tokens=True, vocab=VOCAB, seed=0)
    solo = EngineConfig(max_pack_requests=1, cache_capacity_tokens=8192)
    # budget/cap from the host sweep: ~7-request batches (S=256) beat both
    # smaller packs (step overhead back) and bigger ones (jit-shape churn)
    pack = EngineConfig(pack_token_budget=256, max_pack_requests=8,
                        pack_prefix_budget=8192,
                        cache_capacity_tokens=8192)
    return trace, solo, pack


def run_prefix_hit(emit, smoke=False, cfg=None, params=None):
    """The packed prefix-hit case: solo-suffix fallback vs co-packed hits,
    plus a per-request score-parity check against the solo path."""
    if cfg is None:
        cfg = reduce_config(get_config(ARCH), hybrid_chunk=0)
        api = build(cfg)
        params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    trace, solo_cfg, pack_cfg = _prefix_hit_case(smoke)
    # extra passes: pass 0 warms jit + cache; the next few still compile
    # fresh shapes while the JCT fit converges and batch compositions
    # settle; the min is taken over the remaining warm passes
    reps = 10
    tot = trace.total_tokens
    t_solo, s_solo, sc_solo = _serve(cfg, params, trace, solo_cfg,
                                     reps=reps, reset_cache=False,
                                     allowed=YES_NO)
    t_pack, s_pack, sc_pack = _serve(cfg, params, trace, pack_cfg,
                                     reps=reps, reset_cache=False,
                                     allowed=YES_NO)
    # per-request constrained scores must match the solo-suffix path
    max_dev = max(abs(a[t] - b[t])
                  for a, b in zip(sc_solo, sc_pack) for t in a)
    assert max_dev < 2e-2, f"packed-hit scores diverge: {max_dev}"
    tps_solo = tot / t_solo
    tps_pack = tot / t_pack
    emit(f"packing/prefix_hit/solo_suffix", t_solo * 1e6,
         f"{tps_solo:.0f}tok/s waste={s_solo['padding_waste']:.3f} "
         f"hit={s_solo['hit_rate']:.2f}")
    emit(f"packing/prefix_hit/packed_hit", t_pack * 1e6,
         f"{tps_pack:.0f}tok/s waste={s_pack['padding_waste']:.3f} "
         f"hit={s_pack['hit_rate']:.2f} "
         f"hit_reqs={s_pack['packed_hit_requests']}/{len(trace.requests)}")
    emit(f"packing/prefix_hit/speedup", 0.0,
         f"{tps_pack / tps_solo:.2f}x tokens/sec "
         f"(max score dev {max_dev:.2e})")
    return [("prefix_hit", tps_solo, tps_pack, s_solo["padding_waste"],
             s_pack["padding_waste"])]


def _skewed_case(smoke=False):
    """Skew-heavy mixed hit/miss trace (ISSUE 10 acceptance workload).

    Per-user profile prefixes (~192 tokens, warmed on pass 0) carry MIXED
    suffixes: mostly short (~18-26 tokens) plus a long tail (~176-208
    tokens), with a few unshared pure-miss requests in between. The batched
    hit path pads every co-packed row to (smax, pmax), so one long-suffix
    hit admitted into a short-suffix pack re-prices every row ~8x — the
    token-linear cost model can't see that (computed tokens barely move);
    the shape-aware model prices the padding externality and skew-splits.
    """
    from repro.core.prefix_cache import token_chain
    from repro.core.scheduler import Request
    from repro.data.workloads import Trace

    rng = np.random.default_rng(7)
    users, shorts, longs = (4, 3, 1) if smoke else (6, 5, 2)
    requests = []
    for u in range(users):
        profile = rng.integers(0, VOCAB, size=192).tolist()
        sufs = ([int(rng.integers(18, 27)) for _ in range(shorts)]
                + [int(rng.integers(176, 209)) for _ in range(longs)])
        rng.shuffle(sufs)
        for L in sufs:
            tokens = profile + rng.integers(0, VOCAB, size=L).tolist()
            requests.append(Request(n_input=len(tokens), arrival=0.0,
                                    chain=token_chain(tokens, 16),
                                    tokens=tokens))
        # one unshared miss per user keeps mixed-kind packs in play
        tokens = rng.integers(0, VOCAB, size=int(rng.integers(40, 61))).tolist()
        requests.append(Request(n_input=len(tokens), arrival=0.0,
                                chain=token_chain(tokens, 16),
                                tokens=tokens))
    return Trace(name="skewed_mixed", requests=requests)


def run_pack_shape(emit, smoke=False, cfg=None, params=None):
    """Shape-aware vs token-linear batch formation on the skewed trace.

    Three arms over the identical trace: solo (max_pack_requests=1, the
    score-parity reference), token-linear marginal admission
    (``shape_cost_model=False`` — the legacy rule), and shape-aware marginal
    admission + skew-split (the default). Gates: per-request score parity
    < 2e-2 vs solo for BOTH packed arms; in full (non-smoke) runs the shape
    arm must beat the linear arm on tokens/sec AND mean padding waste.
    """
    if cfg is None:
        cfg = reduce_config(get_config(ARCH), hybrid_chunk=0)
        api = build(cfg)
        params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    trace = _skewed_case(smoke)
    tot = trace.total_tokens
    reps = 8 if smoke else 10
    solo_cfg = EngineConfig(max_pack_requests=1, cache_capacity_tokens=8192)
    # generous budgets so admission is decided by the COST MODEL, not the
    # hard gates — the arms differ only in shape_cost_model
    linear_cfg = EngineConfig(pack_token_budget=512, max_pack_requests=8,
                              pack_prefix_budget=8192,
                              cache_capacity_tokens=8192,
                              shape_cost_model=False)
    shape_cfg = EngineConfig(pack_token_budget=512, max_pack_requests=8,
                             pack_prefix_budget=8192,
                             cache_capacity_tokens=8192,
                             shape_cost_model=True)
    t_solo, s_solo, sc_solo = _serve(cfg, params, trace, solo_cfg,
                                     reps=reps, reset_cache=False,
                                     allowed=YES_NO)
    t_lin, s_lin, sc_lin = _serve(cfg, params, trace, linear_cfg,
                                  reps=reps, reset_cache=False,
                                  allowed=YES_NO)
    t_shape, s_shape, sc_shape = _serve(cfg, params, trace, shape_cfg,
                                        reps=reps, reset_cache=False,
                                        allowed=YES_NO)
    dev_lin = max(abs(a[t] - b[t])
                  for a, b in zip(sc_solo, sc_lin) for t in a)
    dev_shape = max(abs(a[t] - b[t])
                    for a, b in zip(sc_solo, sc_shape) for t in a)
    assert dev_lin < 2e-2, f"token-linear arm scores diverge: {dev_lin}"
    assert dev_shape < 2e-2, f"shape-aware arm scores diverge: {dev_shape}"
    tps_solo, tps_lin, tps_shape = tot / t_solo, tot / t_lin, tot / t_shape
    emit("packing/pack_shape/solo", t_solo * 1e6,
         f"{tps_solo:.0f}tok/s waste={s_solo['padding_waste']:.3f}")
    emit("packing/pack_shape/token_linear", t_lin * 1e6,
         f"{tps_lin:.0f}tok/s waste={s_lin['padding_waste']:.3f} "
         f"packed={s_lin['packed_requests']}/{len(trace.requests)}")
    emit("packing/pack_shape/shape_aware", t_shape * 1e6,
         f"{tps_shape:.0f}tok/s waste={s_shape['padding_waste']:.3f} "
         f"packed={s_shape['packed_requests']}/{len(trace.requests)} "
         f"skew_splits={s_shape['pack_skew_splits']}")
    emit("packing/pack_shape/speedup_vs_linear", 0.0,
         f"{tps_shape / tps_lin:.2f}x tokens/sec, waste "
         f"{s_lin['padding_waste']:.3f} -> {s_shape['padding_waste']:.3f} "
         f"(score dev lin={dev_lin:.2e} shape={dev_shape:.2e})")
    if not smoke:
        assert tps_shape > tps_lin, (
            f"shape-aware formation must beat token-linear: "
            f"{tps_shape:.0f} <= {tps_lin:.0f} tok/s")
        assert s_shape["padding_waste"] < s_lin["padding_waste"], (
            f"shape-aware formation must waste less padding: "
            f"{s_shape['padding_waste']:.3f} >= {s_lin['padding_waste']:.3f}")
    return {"trace": {"name": trace.name, "requests": len(trace.requests),
                      "total_tokens": tot},
            "arms": {
                "solo": {"tokens_per_sec": round(tps_solo, 1),
                         "padding_waste": round(s_solo["padding_waste"], 4)},
                "token_linear": {
                    "tokens_per_sec": round(tps_lin, 1),
                    "padding_waste": round(s_lin["padding_waste"], 4),
                    "packed_requests": s_lin["packed_requests"],
                    "score_dev_vs_solo": float(f"{dev_lin:.3e}")},
                "shape_aware": {
                    "tokens_per_sec": round(tps_shape, 1),
                    "padding_waste": round(s_shape["padding_waste"], 4),
                    "packed_requests": s_shape["packed_requests"],
                    "pack_skew_splits": s_shape["pack_skew_splits"],
                    "score_dev_vs_solo": float(f"{dev_shape:.3e}"),
                    "shape_fit": s_shape["jct"].get("shape", {})}},
            "speedup_shape_vs_linear": round(tps_shape / tps_lin, 3)}


def run_traced_overhead(emit, smoke=False, cfg=None, params=None):
    """Always-on-cheap check: the packed prefix-hit workload with the full
    observability plane bound (SpanTracer + MetricsRegistry + per-request
    trace open/close) vs the bare engine. Acceptance: traced throughput
    within 3% of untraced.

    PAIRED design on ONE engine, alternating traced/untraced passes: the
    jit caches, prefix cache, and — critically — the JCT-fit trajectory are
    shared by both arms. Two separate engines would fit different JCT
    coefficients from their different warm-up timing, converge on different
    batch plans (different steps/pass), and report that plan delta as fake
    "tracing overhead" (observed: 8 vs 14 steps/pass, a ~10% swing dwarfing
    the real instrumentation cost)."""
    from repro.serving import SpanTracer
    from repro.serving.metrics import MetricsRegistry

    if cfg is None:
        cfg = reduce_config(get_config(ARCH), hybrid_chunk=0)
        api = build(cfg)
        params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)
    trace, _, pack_cfg = _prefix_hit_case(smoke)
    tot = trace.total_tokens
    eng = PrefillOnlyEngine(cfg, params, pack_cfg)
    tracer = SpanTracer(capacity=4096)
    registry = MetricsRegistry()

    def one_pass(traced):
        if traced:
            eng.bind_telemetry(metrics=registry, instance="bench",
                               tracer=tracer)
        else:
            eng.bind_telemetry()             # unbind: the bare engine
        eng.results.clear()
        ids = []
        for r in trace.requests:
            rid = eng.submit(list(r.tokens), allowed_tokens=YES_NO, now=0.0)
            ids.append(rid)
            if traced:
                tracer.begin(rid=rid, n_input=len(r.tokens))
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        if traced:
            for rid in ids:
                tracer.finish_rid(rid, "delivered")
        return dt

    import statistics

    for _ in range(4):                       # compiles + fit convergence
        one_pass(False)
    t_on, t_off = [], []
    # per-pass noise on a shared CPU host is ~+-10% — far above the real
    # instrumentation cost — so compare MEDIANS over many interleaved
    # pairs, not minima of a few passes (a min-of-few estimator reported
    # this same workload anywhere from -2% to +7% run to run)
    for k in range(28):
        (t_on if k % 2 == 0 else t_off).append(one_pass(k % 2 == 0))
    med_off = statistics.median(t_off)
    med_on = statistics.median(t_on)
    tps_off, tps_on = tot / med_off, tot / med_on
    overhead = med_on / med_off - 1.0
    emit("packing/traced_overhead/untraced", med_off * 1e6,
         f"{tps_off:.0f}tok/s")
    emit("packing/traced_overhead/traced", med_on * 1e6,
         f"{tps_on:.0f}tok/s traces={tracer.stats()['finished']}")
    emit("packing/traced_overhead/overhead", 0.0,
         f"{overhead * 100:+.2f}% wall ({tps_on / tps_off:.4f}x tok/s, "
         f"median of {len(t_on)} paired passes)")
    return {"untraced_tokens_per_sec": round(tps_off, 1),
            "traced_tokens_per_sec": round(tps_on, 1),
            "overhead_frac": round(overhead, 4),
            "method": "paired interleaved passes, one engine, "
                      f"median of {len(t_on)} per arm",
            "traces_recorded": tracer.stats()["finished"],
            "batches_recorded": tracer.stats()["batches"]}


def run(emit):
    cfg = reduce_config(get_config(ARCH), hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)

    # ~32-47 token requests against a 64-token bucket: the paper's short
    # discriminative regime, where ~40% of every solo forward is padding
    noshare = credit_verification(qps=0.0, num_users=48, scale_tokens=0.0008,
                                  materialize_tokens=True, vocab=VOCAB,
                                  seed=0)
    shared = post_recommendation(qps=0.0, num_users=6, posts_per_user=4,
                                 scale_tokens=0.01, materialize_tokens=True,
                                 vocab=VOCAB, seed=0)
    cases = [
        # (trace name, trace, solo config, packed config)
        ("short_noshare", noshare,
         EngineConfig(max_pack_requests=1, cache_capacity_tokens=0,
                      kv_keep_tokens=0),
         EngineConfig(cache_capacity_tokens=0, kv_keep_tokens=0,
                      pack_token_budget=1024, max_pack_requests=24)),
        # since the packed prefix-hit path, sharers CAN co-pack once their
        # prefix is cached — same tuned operating point as prefix_hit
        # (wide packs lose to per-step overhead on this host)
        ("short_shared", shared,
         EngineConfig(max_pack_requests=1),
         EngineConfig(pack_token_budget=256, max_pack_requests=8)),
    ]
    rows = []
    for name, trace, solo_cfg, pack_cfg in cases:
        tot = trace.total_tokens
        t_solo, s_solo, _ = _serve(cfg, params, trace, solo_cfg)
        t_pack, s_pack, _ = _serve(cfg, params, trace, pack_cfg)
        tps_solo = tot / t_solo
        tps_pack = tot / t_pack
        emit(f"packing/{name}/solo_bucketed", t_solo * 1e6,
             f"{tps_solo:.0f}tok/s waste={s_solo['padding_waste']:.3f} "
             f"hit={s_solo['hit_rate']:.2f}")
        emit(f"packing/{name}/prepacked", t_pack * 1e6,
             f"{tps_pack:.0f}tok/s waste={s_pack['padding_waste']:.3f} "
             f"hit={s_pack['hit_rate']:.2f} "
             f"packed_reqs={s_pack['packed_requests']}/{len(trace.requests)}")
        emit(f"packing/{name}/speedup", 0.0,
             f"{tps_pack / tps_solo:.2f}x tokens/sec")
        rows.append((name, tps_solo, tps_pack, s_solo["padding_waste"],
                     s_pack["padding_waste"]))
    rows += run_prefix_hit(emit, cfg=cfg, params=params)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small prefix-hit trace (CI); pass count is unchanged — the cost model needs the calibration passes either way")
    ap.add_argument("--out", default=None,
                    help="write emitted rows to this file (default "
                         "benchmarks/results/packing_[smoke|prefix_hit].txt)")
    ap.add_argument("--pack-shape", action="store_true",
                    help="run ONLY the skewed-trace shape-aware-vs-linear "
                         "formation case; writes BENCH_pack_shape.json "
                         "(pack_shape_smoke.json with --smoke)")
    args = ap.parse_args()
    lines = ["name,us_per_call,derived"]

    def emit(name, us, derived=""):
        line = f"{name},{us:.1f},{derived}"
        print(line)
        lines.append(line)

    from benchmarks.common import bench_record, write_bench_json

    cfg = reduce_config(get_config(ARCH), hybrid_chunk=0)
    api = build(cfg)
    params = materialize(jax.random.PRNGKey(0), api.defs(), jnp.float32)

    if args.pack_shape:
        result = run_pack_shape(emit, smoke=args.smoke, cfg=cfg,
                                params=params)
        out = args.out or (
            "benchmarks/results/pack_shape_smoke.txt" if args.smoke
            else "benchmarks/results/pack_shape.txt")
        path = pathlib.Path(out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text("\n".join(lines) + "\n")
        print(f"wrote {path}")
        record = bench_record(
            "pack_shape",
            config={"arch": ARCH, "smoke": args.smoke,
                    "reps": 8 if args.smoke else 10,
                    "trace": "skewed_mixed"},
            **result)
        jpath = ("benchmarks/results/pack_shape_smoke.json" if args.smoke
                 else "benchmarks/results/BENCH_pack_shape.json")
        write_bench_json(record, jpath)
        return

    rows = run_prefix_hit(emit, smoke=args.smoke, cfg=cfg, params=params)
    overhead = run_traced_overhead(emit, smoke=args.smoke, cfg=cfg,
                                   params=params)
    out = args.out or (
        "benchmarks/results/packing_smoke.txt" if args.smoke
        else "benchmarks/results/packing_prefix_hit.txt")
    path = pathlib.Path(out)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text("\n".join(lines) + "\n")
    print(f"wrote {path}")

    record = bench_record(
        "packing",
        config={"arch": ARCH, "smoke": args.smoke, "reps": 10,
                "trace": "post_recommendation/prefix_hit"},
        rows=[{"case": name,
               "tokens_per_sec_solo": round(tps_solo, 1),
               "tokens_per_sec_packed": round(tps_pack, 1),
               "speedup": round(tps_pack / tps_solo, 3),
               "padding_waste_solo": round(w_solo, 4),
               "padding_waste_packed": round(w_pack, 4)}
              for name, tps_solo, tps_pack, w_solo, w_pack in rows],
        tracing_overhead=overhead)
    jpath = ("benchmarks/results/packing_smoke.json" if args.smoke
             else "benchmarks/results/BENCH_packing.json")
    write_bench_json(record, jpath)


if __name__ == "__main__":
    main()
