"""§Roofline: the per-(arch x shape x mesh) table from the dry-run artifacts.

Reads results/dryrun/*.json (produced by ``python -m repro.launch.dryrun``)
and prints the three roofline terms, dominant bottleneck, MODEL_FLOPS /
HLO_FLOPs ratio, and the roofline fraction:

    fraction = ideal_time / bound_time
    ideal    = max(MODEL_FLOPS/(chips·peak),  one-sweep HBM floor)
    bound    = max(compute_s, memory_s, collective_s)

The HBM floor (argument+output bytes / bw) is what makes decode cells
meaningful: a decode step is ideally ONE sweep of weights+cache.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.runtime.hw import TPU_V5E

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_cells(tag: str = "baseline") -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(f"{DRYRUN_DIR}/*__{tag}.json")):
        cells.append(json.load(open(f)))
    return cells


def fraction(cell: Dict) -> float:
    r = cell["roofline"]
    m = cell["memory"]
    chip = TPU_V5E
    compute_ideal = r["model_flops"] / (cell["devices"]
                                        * chip.peak_flops_bf16)
    hbm_floor = (m["argument_bytes"] + m["output_bytes"]
                 - m["alias_bytes"]) / chip.hbm_bw
    ideal = max(compute_ideal, hbm_floor)
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"], 1e-12)
    return min(1.0, ideal / bound)


def run(emit, tag: str = "baseline"):
    cells = load_cells(tag)
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skip"]
    rows = []
    for c in ok:
        r = c["roofline"]
        frac = fraction(c)
        name = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        emit(name, r["step_time_bound_s"] * 1e6,
             f"dom={r['dominant']} frac={frac:.3f} "
             f"useful={r['useful_ratio']:.2f} "
             f"comp={r['compute_s']*1e3:.2f}ms "
             f"mem={r['memory_s']*1e3:.2f}ms "
             f"coll={r['collective_s']*1e3:.2f}ms "
             f"fits={c['memory']['fits']}")
        rows.append((c["arch"], c["shape"], c["mesh"], frac, r["dominant"]))
    for c in skipped:
        emit(f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}", 0.0, "SKIP")
    if ok:
        worst = sorted(rows, key=lambda x: x[3])[:3]
        emit("roofline/worst3", 0.0,
             " | ".join(f"{a}/{s}/{m}={f:.3f}" for a, s, m, f, _ in worst))
    return rows
